"""Batched serving example: continuous batching over a slot pool, with the
audio-frontend arch exercising the stub-embedding path.

    PYTHONPATH=src python examples/serve_lm.py --arch starcoder2-7b --requests 8
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serving import EngineConfig, Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(
        cfg, params,
        EngineConfig(slots=args.slots, max_seq=128,
                     temperature=args.temperature),
    )
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        eng.submit(
            Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=6 + i % 5),
                    max_new_tokens=args.new_tokens)
        )
    done = eng.run_until_drained()
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    for r in done[:4]:
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")
    ttft = np.mean([r.t_first - r.t_submit for r in done])
    print(
        f"{len(done)} requests, {n_tok} tokens in {dt:.2f}s "
        f"({n_tok/dt:.1f} tok/s, {args.slots} slots, "
        f"{eng.decode_steps} batched decode steps, mean TTFT {ttft*1e3:.0f}ms)"
    )


if __name__ == "__main__":
    main()
