"""Quickstart: the paper's programming model end-to-end (Fig. 2 analog).

ONE application program (define data, partition, call utp_cholesky, wait)
runs unchanged under every task-flow graph — sequential leaves (G1),
wave-batched multicore-analog (G2), Pallas tile kernels (G2'), and the
two-level hierarchical DuctTeip-over-SuperGlue plan (G3, on whatever
devices exist).

    PYTHONPATH=src python examples/quickstart.py [N] [b1] [b2]
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import Dispatcher, GData, GTask, spd_matrix, utp_get_parameters
from repro.linalg import POTRF, utp_cholesky


def main():
    n, b1, b2 = utp_get_parameters(defaults=(256, 4, 2))
    a = spd_matrix(n)
    want = jnp.linalg.cholesky(a)
    print(f"Cholesky of {n}x{n} SPD matrix, partitions {b1}x{b1} then {b2}x{b2}")

    for graph, parts in [
        ("g1", ((b1, b1),)),
        ("g2", ((b1, b1),)),
        ("g2p", ((b1, b1),)),
        ("g3", ((b1, b1), (b2, b2))),
    ]:
        mesh = None
        if graph == "g3":
            nd = jax.device_count()
            mesh = jax.make_mesh((nd, 1), ("data", "model"))
        # ---- the application program (identical for every graph) --------
        d = Dispatcher(graph=graph, mesh=mesh)
        A = GData(a.shape, partitions=parts, dtype=a.dtype, value=a)
        utp_cholesky(d, A)
        n_leaf = d.run()
        # ------------------------------------------------------------------
        err = float(jnp.abs(jnp.tril(A.value) - want).max())
        print(
            f"  graph {graph:6s} [{d.graph.describe():47s}] "
            f"leaf_tasks={n_leaf:4d} waves={d.stats['waves']:3d} max_err={err:.2e}"
        )
    print("same program, four execution plans — the paper's portability claim.")


if __name__ == "__main__":
    main()
