"""Distributed Cholesky on a real multi-device mesh (paper Fig. 3(b)).

Re-execs itself with 8 forced host devices, then runs the SAME application
program under the hierarchical G3 graph on a (8, 1) mesh — the DuctTeip
analog places level-1 block rows over the data axis; panel movement shows
up as XLA collectives instead of MPI messages.

    PYTHONPATH=src python examples/distributed_cholesky.py
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import Dispatcher, GData, spd_matrix
from repro.linalg import utp_cholesky


def main():
    n = 1024
    a = spd_matrix(n)
    print(f"devices: {jax.device_count()}")
    mesh = jax.make_mesh((8, 1), ("data", "model"))

    d = Dispatcher(graph="g3", mesh=mesh)
    A = GData(a.shape, partitions=((8, 8), (2, 2)), dtype=a.dtype, value=a)
    utp_cholesky(d, A)
    leafs = d.run()

    err = float(jnp.abs(jnp.tril(A.value) - jnp.linalg.cholesky(a)).max())
    shard_shapes = {str(s.data.shape) for s in A.value.addressable_shards}
    print(
        f"g3 on (8,1) mesh: {leafs} leaf tasks, {d.stats['waves']} waves, "
        f"max_err={err:.2e}"
    )
    print(f"result stays sharded across devices: shard shapes {shard_shapes}")


if __name__ == "__main__":
    main()
