"""Walkthrough: solve ``A x = b`` end-to-end in ONE dispatcher drain.

Executable documentation for the composed factor+solve pipeline
(DESIGN.md §4).  The program below is the paper's Fig. 2 shape — define
data, partition, submit one root task, wait — but the root is the composed
LUSOLVE operation, whose expansion emits LU panel tasks, forward-
substitution (TRSML) tasks, and backward-substitution (TRSMUL) tasks into
one scope.  The dispatcher versions all of them into a single task DAG and
compiles the whole pipeline into ONE WaveProgram, so:

  * there is one launch per drain (not three barrier-separated drains),
  * the cross-wave fusion pass overlaps solve groups with late factor
    groups (watch ``groups < groups_prefusion`` below — single-root LU
    alone cannot fuse anything, the solve slack is what fusion exploits),
  * a structurally repeated drain replays via the drain memo with zero
    recompiles (watch ``compiles`` stay 0 on the second call).

    PYTHONPATH=src python examples/lu_solve.py [N] [b1] [b2]
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import Dispatcher, GData, dd_matrix, utp_get_parameters
from repro.core.executors import clear_compile_cache
from repro.linalg import run_inv, run_lu_solve
from repro.linalg.lu import utp_lu_solve


def main():
    n, b1, b2 = utp_get_parameters(defaults=(256, 4, 2))
    a = dd_matrix(n)  # column-diagonally dominant -> pivot-free LU is exact
    key = jax.random.PRNGKey(0)
    b = jax.random.normal(key, (n, n), jnp.float32)
    want = jax.scipy.linalg.lu_solve(jax.scipy.linalg.lu_factor(a), b)
    print(f"Solve A x = b for {n}x{n} A, partitions {b1}x{b1} then {b2}x{b2}")

    # ---- one program, every task-flow graph ------------------------------
    for graph, parts in [
        ("g1", ((b1, b1),)),
        ("g2", ((b1, b1),)),
        ("g2p", ((b1, b1),)),
        ("g3", ((b1, b1), (b2, b2))),
    ]:
        mesh = None
        if graph == "g3":
            nd = jax.device_count()
            mesh = jax.make_mesh((nd, 1), ("data", "model"))
        x = run_lu_solve(a, b, graph=graph, partitions=parts, mesh=mesh)
        err = float(jnp.abs(x - want).max())
        print(f"  graph {graph:4s} max_err={err:.2e}")

    # ---- the single-drain claim, witnessed by the counters ---------------
    def drain(seed):
        d = Dispatcher(graph="g2")
        A = GData(a.shape, partitions=((b1, b1),), dtype=a.dtype,
                  value=dd_matrix(n, seed=seed))
        B = GData(b.shape, partitions=((b1, b1),), dtype=b.dtype,
                  value=jax.random.normal(jax.random.PRNGKey(seed), b.shape))
        utp_lu_solve(d, A, B)
        n_leaf = d.run()
        s = d.executor.stats
        print(
            f"  drain(seed={seed}): leaf_tasks={n_leaf} "
            f"launches={s['launches']} compiles={s['compiles']} "
            f"groups={s['groups']} (prefusion {s['groups_prefusion']})"
        )

    print("factor + L-solve + U-solve in ONE WaveProgram:")
    clear_compile_cache()  # forget the runs above: show a cold first drain
    drain(seed=1)  # compiles=1: one program for the whole pipeline
    drain(seed=2)  # compiles=0: structurally repeated drain -> memo replay

    # ---- second application of the same ops: matrix inverse --------------
    inv = run_inv(a, partitions=((b1, b1),))
    err = float(jnp.abs(inv @ a - jnp.eye(n)).max())
    print(f"run_inv (A X = I through the same pipeline): |inv(a)@a - I| = {err:.2e}")


if __name__ == "__main__":
    main()
