"""End-to-end LM training driver: data pipeline -> sharded train step ->
async checkpoints -> fault-tolerant loop, on any of the ten assigned
architectures (reduced or full preset).

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-32b --steps 300
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Presets:
    reduced  the arch's CPU smoke config (default; runs anywhere)
    100m     a ~100M-param qwen3-family config (the deliverable-scale run;
             a few hundred steps is hours on 1 CPU core, minutes on a TPU
             host — start it with --steps 300 where you have silicon)

The loop itself is the production Trainer: resumable (re-run the same
command after killing it and it continues from the last checkpoint),
failure-injectable (--inject-failure N kills step N once), straggler-
tracked.
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax

from repro import optim
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.train import Trainer, TrainerConfig


def preset_100m(base):
    """~100M-param qwen3-family config (exact count printed at start)."""
    return dataclasses.replace(
        base,
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv=4,
        head_dim=64,
        d_ff=2048,
        vocab=32768,
        compute_dtype=jax.numpy.float32,
        remat="none",
        scan_layers=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--preset", default="reduced", choices=["reduced", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", type=int, default=-1)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    cfg = preset_100m(cfg) if args.preset == "100m" else cfg.reduced()
    from repro.models.model import param_counts

    n = param_counts(cfg)["total"]
    print(f"arch={cfg.name} preset={args.preset}: {n/1e6:.1f}M params")

    shape = ShapeConfig("train", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    trainer = Trainer(
        cfg, shape, mesh,
        TrainerConfig(steps=args.steps, ckpt_every=max(args.steps // 4, 10),
                      ckpt_dir=args.ckpt_dir, log_every=10),
        opt_cfg=optim.AdamWConfig(
            lr=optim.warmup_cosine(args.lr, warmup=20, total=args.steps)
        ),
    )
    fail = {args.inject_failure} if args.inject_failure >= 0 else set()

    def inject(step):
        if step in fail:
            fail.discard(step)
            return True
        return False

    out = trainer.train(inject_failure=inject)
    first = out["metrics"][0]["loss"] if out["metrics"] else float("nan")
    last = out["metrics"][-1]["loss"] if out["metrics"] else float("nan")
    print(
        f"done: {out['step']} steps, loss {first:.3f} -> {last:.3f}, "
        f"stragglers={out['stragglers']} failures={out['failures']}"
    )


if __name__ == "__main__":
    main()
