"""Error taxonomy for drains and batched serving (DESIGN.md §10).

Every failure the runtime can surface to a caller is an instance of
``ServeError`` (or a plain exception wrapped into one at the serving
boundary), so application code can catch one base class and branch on the
concrete type:

    ServeError
    ├── DrainError        a dispatcher drain raised (compile/launch/capture
    │                     failure); ``__cause__`` carries the original
    ├── NumericalError    a drain completed but produced non-finite values
    │                     (singular pivot, overflow) — deterministic, so
    │                     NEVER retried
    ├── DeadlineExceeded  the request's deadline passed before it was
    │                     drained; the request was failed WITHOUT draining
    └── RejectedError     admission control shed the request (queue at
                          ``max_pending``) — it was never queued/drained

The taxonomy lives at the top level (not under ``serve/``) because the
drain-side surfaces raise it too: ``run_lu(check_finite=True)`` raises
``NumericalError`` directly, with no serving stack involved.
"""

from __future__ import annotations


class ServeError(Exception):
    """Base class for every runtime-surfaced drain/serving failure."""


class DrainError(ServeError):
    """A dispatcher drain raised; the original exception is ``__cause__``.

    Transient by assumption (executor hiccup, injected fault): the serving
    layer retries these within the request's retry budget.
    """


class NumericalError(ServeError):
    """A drain completed but the result contains non-finite values.

    Deterministic (re-running the same request reproduces it), so the
    serving layer fails the request immediately, never retries.
    """


class DeadlineExceeded(ServeError):
    """The request's deadline expired before it was drained."""


class RejectedError(ServeError):
    """Admission control rejected the request (overload shedding)."""


__all__ = [
    "DeadlineExceeded",
    "DrainError",
    "NumericalError",
    "RejectedError",
    "ServeError",
]
