"""Error taxonomy for drains and batched serving (DESIGN.md §10).

Every failure the runtime can surface to a caller is an instance of
``ServeError`` (or a plain exception wrapped into one at the serving
boundary), so application code can catch one base class and branch on the
concrete type:

    ServeError
    ├── DrainError        a dispatcher drain raised (compile/launch/capture
    │                     failure); ``__cause__`` carries the original
    │   ├── InflightError the drain dispatched but FAILED before its
    │   │                 in-flight results materialized (overlapped
    │   │                 execution, DESIGN.md §12) — detected at the
    │   │                 deferred resolution fence; retryable like any
    │   │                 DrainError
    │   ├── DrainStalledError
    │   │                 the hung-drain watchdog's wall-clock budget
    │   │                 expired before the drain's fence became ready
    │   │                 (DESIGN.md §14) — the drain's memo entries were
    │   │                 invalidated; NEVER retried (a re-drain would
    │   │                 race the same hung computation)
    │   └── ResourceExhausted
    │                     the device ran out of memory launching a stacked
    │                     program (XLA RESOURCE_EXHAUSTED); the serving
    │                     layer degrades the bucket's batch cap and
    │                     re-drains split halves (DESIGN.md §14) — only a
    │                     request that OOMs ALONE lands this on its
    │                     future, so it is never retried at full size
    ├── NumericalError    a drain completed but produced non-finite values
    │                     (singular pivot, overflow) — deterministic, so
    │                     NEVER retried
    ├── DeadlineExceeded  the request's deadline passed before it was
    │                     drained; the request was failed WITHOUT draining
    ├── RejectedError     admission control shed the request (queue at
    │                     ``max_pending``) — it was never queued/drained
    ├── CircuitOpenError  the request's signature bucket has its circuit
    │                     breaker OPEN (persistent drain failures,
    │                     DESIGN.md §14): failed fast WITHOUT draining;
    │                     the bucket half-opens after a cooldown
    └── ScheduleVerificationError
                          the static verifier (DESIGN.md §11) proved a
                          schedule invariant violated — a race the
                          versioning missed or an illegal plan; the message
                          names the site and the offending task pair.
                          Deterministic (structural), NEVER retried.

``LintError`` (operation-algebra linter, DESIGN.md §11) sits outside the
``ServeError`` tree: it is raised by static tooling over the Operation
registry, never by a drain.

The taxonomy lives at the top level (not under ``serve/``) because the
drain-side surfaces raise it too: ``run_lu(check_finite=True)`` raises
``NumericalError`` directly, with no serving stack involved.
"""

from __future__ import annotations


class ServeError(Exception):
    """Base class for every runtime-surfaced drain/serving failure."""


class DrainError(ServeError):
    """A dispatcher drain raised; the original exception is ``__cause__``.

    Transient by assumption (executor hiccup, injected fault): the serving
    layer retries these within the request's retry budget.
    """


class InflightError(DrainError):
    """An overlapped drain failed AFTER dispatch, at deferred resolution.

    Under async drain overlap (DESIGN.md §12) a program launch returns
    before device execution completes; a failure surfacing at the deferred
    fence (end-of-tick validation, a touched future, an injected
    ``drain.inflight`` fault) lands here.  The drain's memo entries were
    already invalidated by the handle.  A ``DrainError`` subclass: transient
    by assumption, retried within the request's budget.
    """


class DrainStalledError(DrainError):
    """The hung-drain watchdog fired: the drain's fence did not become
    ready within its wall-clock budget (DESIGN.md §14).

    The stalled drain's memo entries were invalidated before this raised.
    NOT retried despite being a ``DrainError``: the hung computation still
    owns its device resources (XLA fences are not interruptible-by-value),
    so a retry would queue behind — or deadlock with — the very
    computation that stalled.  Only process restart reclaims the device.
    """


class ResourceExhausted(DrainError):
    """A launch failed with device OOM (XLA ``RESOURCE_EXHAUSTED``).

    The serving layer treats this as *pressure*, not poison: the bucket's
    batch cap is halved, drain-memo entries are shed, and the chunk
    re-drains as split halves (DESIGN.md §14).  It lands on a future only
    when a SINGLE request still OOMs, which re-running at the same size
    deterministically reproduces — so it is never retried.
    """


class CircuitOpenError(ServeError):
    """The request's signature bucket is circuit-broken (DESIGN.md §14).

    A bucket whose drains keep failing trips its breaker OPEN: queued and
    incoming requests of that signature fail fast, without draining, so a
    persistently poisoned workload class cannot starve the tick loop or
    burn the retry budget of healthy buckets.  After a cooldown the
    breaker half-opens and a single probe request tests recovery.
    """


class NumericalError(ServeError):
    """A drain completed but the result contains non-finite values.

    Deterministic (re-running the same request reproduces it), so the
    serving layer fails the request immediately, never retries.
    """


class DeadlineExceeded(ServeError):
    """The request's deadline expired before it was drained."""


class RejectedError(ServeError):
    """Admission control rejected the request (overload shedding)."""


class ScheduleVerificationError(ServeError):
    """A schedule invariant failed static verification (DESIGN.md §11).

    Raised by the hazard analysis (a dependence the versioning DAG does not
    order — a race) or by the plan verifier (an illegal fused group, slot
    order, scatter overlap, or lane aliasing).  The message carries the
    verification *site* and the offending task pair / block coordinates so
    the failure is actionable without re-running.  Deterministic for a
    given schedule structure, so the serving layer never retries it.
    """

    def __init__(self, site: str, detail: str, pair: tuple = ()):
        self.site = site
        self.pair = tuple(pair)
        msg = f"[{site}] {detail}"
        if self.pair:
            msg += f" (tasks: {', '.join(str(p) for p in self.pair)})"
        super().__init__(msg)


class LintError(Exception):
    """The operation-algebra linter found contract violations (DESIGN.md
    §11): an impure ``split`` on a memoizable Operation, access modes
    inconsistent with the leaf's write positions, or incoherent
    leaf/batched-leaf signatures.  Static tooling only — never raised by a
    drain."""

    def __init__(self, issues):
        self.issues = list(issues)
        super().__init__(
            f"{len(self.issues)} operation lint issue(s):\n  "
            + "\n  ".join(str(i) for i in self.issues)
        )


__all__ = [
    "CircuitOpenError",
    "DeadlineExceeded",
    "DrainError",
    "DrainStalledError",
    "InflightError",
    "LintError",
    "NumericalError",
    "RejectedError",
    "ResourceExhausted",
    "ScheduleVerificationError",
    "ServeError",
]
