"""Version-compatibility shims over moving JAX APIs.

One shared helper per API break so call sites never branch on jax versions
themselves.  Currently: ``shard_map``, which graduated from
``jax.experimental.shard_map.shard_map`` (kwarg ``check_rep``) to the top
level ``jax.shard_map`` (kwarg ``check_vma``).
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new JAX, experimental fallback on old.

    ``check_vma`` is the new-API name for replication/varying-manual-axes
    checking; it maps onto the old API's ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=check_vma,
    )
