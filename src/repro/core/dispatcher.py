"""The central dispatcher (paper §2.1): orchestrates task flow between the
program and the framework wrappers according to a task-flow graph.

Program-facing API is the paper's:  ``dispatcher.submit_task(t)`` during
program execution, ``dispatcher.run()`` (== ``utp_finalize``) to drain.

Semantics: tasks are expanded level by level.  A wave of ready tasks at
level ``l`` is split (each task's Operation creates children on the next
partition level, paper Fig. 2b); the union of their children forms the next
scope whose DAG is built by data versioning.  At ``graph.split_levels`` the
leaf executor runs the waves.  This is the AOT realization of the paper's
"ready tasks at w1 split and are submitted to w2" edge (DESIGN.md §2).
"""

from __future__ import annotations

from typing import Dict, List, Optional

from .executors.base import Executor
from .executors.inline import InlineExecutor
from .executors.jit_wave import JitWaveExecutor, PallasExecutor
from .executors.sharded import ShardExecutor
from .graph import TaskFlowGraph, get_graph
from .task import GTask, TaskState
from .versioning import DepTracker


def _make_executor(graph: TaskFlowGraph, mesh, on_finished) -> Executor:
    backend = "pallas" if graph.leaf_executor == "pallas" else "jnp"
    if graph.distributed:
        if mesh is None:
            raise ValueError(f"graph {graph.name} is distributed but mesh is None")
        return ShardExecutor(
            mesh, backend=backend, shard_axes=graph.shard_axes,
            on_task_finished=on_finished,
        )
    if graph.leaf_executor == "inline":
        return InlineExecutor(on_task_finished=on_finished)
    if graph.leaf_executor == "pallas":
        return PallasExecutor(on_task_finished=on_finished)
    return JitWaveExecutor(on_task_finished=on_finished)


class Dispatcher:
    def __init__(self, graph="g2", mesh=None):
        self.graph = get_graph(graph) if isinstance(graph, str) else graph
        self.mesh = mesh
        self.executor = _make_executor(self.graph, mesh, self._on_finished)
        self._pending_roots: List[GTask] = []
        self.finished_count = 0
        self.stats: Dict[str, int] = {"submitted": 0, "split": 0, "waves": 0}

    # -- paper-facing API ------------------------------------------------------
    def submit_task(self, task: GTask) -> None:
        task.state = TaskState.SUBMITTED
        self.stats["submitted"] += 1
        if task.parent is not None:
            task.parent.add_child(task)
        self._pending_roots.append(task)

    def task_finished(self, task: GTask) -> None:
        """Paper Fig. 2(a) line 36 — completion report from a leaf wrapper."""
        task.state = TaskState.FINISHED
        self._on_finished(task)

    def run(self) -> int:
        """Drain all submitted tasks; returns number of leaf tasks executed."""
        roots, self._pending_roots = self._pending_roots, []
        before = self.finished_count
        self._process_scope(roots, level=0)
        return self.finished_count - before

    # -- internal --------------------------------------------------------------
    def _on_finished(self, task: GTask) -> None:
        self.finished_count += 1
        parent = task.parent
        while parent is not None and parent.child_finished():
            parent.state = TaskState.FINISHED
            parent = parent.parent

    def _process_scope(self, tasks: List[GTask], level: int) -> None:
        if not tasks:
            return
        tracker = DepTracker()
        for t in tasks:
            tracker.add(t)
        waves = tracker.waves()
        self.stats["waves"] += len(waves)
        leaf_level = self.graph.split_levels
        if level >= leaf_level:
            self.executor.execute_waves(waves)
            return
        for wave in waves:
            children: List[GTask] = []

            def collect(child: GTask) -> None:
                if child.parent is not None:
                    child.parent.add_child(child)
                child.state = TaskState.SUBMITTED
                children.append(child)

            for t in wave:
                if t.op.can_split(t):
                    t.state = TaskState.SPLIT
                    self.stats["split"] += 1
                    t.op.split(t, collect)
                    if not t.children:
                        # degenerate split (e.g. 1x1 partition): run as leaf
                        children.append(t)
                else:
                    children.append(t)
            self._process_scope(children, level + 1)
