"""The central dispatcher (paper §2.1): orchestrates task flow between the
program and the framework wrappers according to a task-flow graph.

Program-facing API is the paper's:  ``dispatcher.submit_task(t)`` during
program execution, ``dispatcher.run()`` (== ``utp_finalize``) to drain.

Semantics: tasks are expanded level by level.  A wave of ready tasks at
level ``l`` is split (each task's Operation creates children on the next
partition level, paper Fig. 2b); the union of their children forms the next
scope whose DAG is built by data versioning.  At ``graph.split_levels`` the
leaf executor runs the waves.  This is the AOT realization of the paper's
"ready tasks at w1 split and are submitted to w2" edge (DESIGN.md §2).
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax.numpy as jnp

from .executors.base import Executor
from .executors.inline import InlineExecutor
from .executors.jit_wave import _DRAIN_MEMO, JitWaveExecutor, PallasExecutor
from .executors.sharded import ShardExecutor
from .graph import TaskFlowGraph, get_graph
from .task import GTask, TaskState
from .versioning import DepTracker


def _make_executor(graph: TaskFlowGraph, mesh, on_finished) -> Executor:
    backend = "pallas" if graph.leaf_executor == "pallas" else "jnp"
    if graph.distributed:
        if mesh is None:
            raise ValueError(f"graph {graph.name} is distributed but mesh is None")
        return ShardExecutor(
            mesh, backend=backend, shard_axes=graph.shard_axes,
            on_task_finished=on_finished,
        )
    if graph.leaf_executor == "inline":
        return InlineExecutor(on_task_finished=on_finished)
    if graph.leaf_executor == "pallas":
        return PallasExecutor(on_task_finished=on_finished)
    return JitWaveExecutor(on_task_finished=on_finished)


class Dispatcher:
    def __init__(self, graph="g2", mesh=None, memoize_drains: bool = True):
        self.graph = get_graph(graph) if isinstance(graph, str) else graph
        self.mesh = mesh
        self.executor = _make_executor(self.graph, mesh, self._on_finished)
        self.memoize_drains = memoize_drains
        self._pending_roots: List[GTask] = []
        self._capture_valid = True
        self.finished_count = 0
        self.stats: Dict[str, int] = {"submitted": 0, "split": 0, "waves": 0}

    # -- paper-facing API ------------------------------------------------------
    def submit_task(self, task: GTask) -> None:
        task.state = TaskState.SUBMITTED
        self.stats["submitted"] += 1
        if task.parent is not None:
            task.parent.add_child(task)
        self._pending_roots.append(task)

    def task_finished(self, task: GTask) -> None:
        """Paper Fig. 2(a) line 36 — completion report from a leaf wrapper."""
        task.state = TaskState.FINISHED
        self._on_finished(task)

    def run(self) -> int:
        """Drain all submitted tasks; returns number of leaf tasks executed.

        Drain memo (DESIGN.md §2): task splitting is a pure function of the
        root tasks' operations and argument geometry, so a drain whose root
        stream structurally matches a previous one must produce the same
        leaf schedule.  The first such drain is captured (the sequence of
        compiled WaveProgram executions); repeats skip Python re-splitting/
        re-versioning entirely and replay the programs on the fresh data —
        this is what makes repeated drains (training steps, iterative
        solvers, benchmark sweeps) cost one compiled-program dispatch.
        """
        roots, self._pending_roots = self._pending_roots, []
        before = self.finished_count
        key = self._drain_memo_key(roots)
        memo = _DRAIN_MEMO.get(key) if key is not None else None
        if memo is not None:
            self._replay_drain(memo, roots)
            return self.finished_count - before
        capturing = key is not None
        if capturing:
            slot_of = {
                d.id: i for i, d in enumerate(self._root_datas(roots))
            }
            self.executor.begin_capture(slot_of)
            stats_before = (self.stats["split"], self.stats["waves"])
            self._capture_valid = True
        self._process_scope(roots, level=0)
        if capturing:
            records, ok = self.executor.end_capture()
            if ok and self._capture_valid:
                _DRAIN_MEMO[key] = {
                    "records": records,
                    "leaf_total": self.finished_count - before,
                    "split": self.stats["split"] - stats_before[0],
                    "waves": self.stats["waves"] - stats_before[1],
                }
        return self.finished_count - before

    @staticmethod
    def _root_datas(roots: List[GTask]) -> List:
        """Root-argument data handles in first-appearance order — THE slot
        order; memo key, capture, and replay must all derive from this."""
        datas = []
        seen = set()
        for t in roots:
            for v in t.args:
                if v.data.id not in seen:
                    seen.add(v.data.id)
                    datas.append(v.data)
        return datas

    def _drain_memo_key(self, roots: List[GTask]) -> Optional[tuple]:
        """Structural key of a root-task stream, or None if not memoizable.

        Captures everything task expansion depends on: graph config,
        executor identity, and per root task the operation plus each
        argument's (data slot, region, level, root shape/dtype/partitions,
        access mode).  Data *identity* is slot-relative, so a fresh GData
        with the same geometry hits the memo.  Relies on ``Operation.split``
        being a pure function of that geometry (the Operation contract)."""
        if not self.memoize_drains or not roots:
            return None
        if not hasattr(self.executor, "begin_capture"):
            return None
        if not all(t.op.memoizable for t in roots):
            return None
        slot_of = {d.id: i for i, d in enumerate(self._root_datas(roots))}
        parts: List[tuple] = [
            (self.graph.name, self.graph.split_levels),
            self.executor.memo_key_extra(),
        ]
        for t in roots:
            args = []
            for v, m in zip(t.args, t.modes):
                d = v.data
                slot = slot_of[d.id]
                r = v.region
                args.append(
                    (
                        slot,
                        (r.r0, r.c0, r.rows, r.cols),
                        v.level,
                        d.shape,
                        str(jnp.dtype(d.dtype)),
                        tuple(d.partitions),
                        m.value,
                    )
                )
            parts.append((t.op.name, tuple(args)))
        return tuple(parts)

    def _replay_drain(self, memo: dict, roots: List[GTask]) -> None:
        datas = self._root_datas(roots)
        for rec in memo["records"]:
            self.executor.replay_program(rec, [datas[s] for s in rec.root_slots])
        for t in roots:
            t.state = TaskState.FINISHED
        self.stats["split"] += memo["split"]
        self.stats["waves"] += memo["waves"]
        self.finished_count += memo["leaf_total"]

    # -- internal --------------------------------------------------------------
    def _on_finished(self, task: GTask) -> None:
        self.finished_count += 1
        parent = task.parent
        while parent is not None and parent.child_finished():
            parent.state = TaskState.FINISHED
            parent = parent.parent

    def _process_scope(self, tasks: List[GTask], level: int) -> None:
        if not tasks:
            return
        tracker = DepTracker()
        for t in tasks:
            tracker.add(t)
        waves = tracker.waves()
        self.stats["waves"] += len(waves)
        leaf_level = self.graph.split_levels
        if level >= leaf_level:
            # hand over the exact task DAG, not just the level schedule:
            # the executor's scheduling pass issues dependency-exactly and
            # fuses groups across former wave boundaries (DESIGN.md §2)
            self.executor.execute_schedule(waves, tracker.dag())
            return
        for wave in waves:
            children: List[GTask] = []

            def collect(child: GTask) -> None:
                if child.parent is not None:
                    child.parent.add_child(child)
                child.state = TaskState.SUBMITTED
                children.append(child)

            for t in wave:
                if t.op.can_split(t):
                    if not t.op.memoizable:
                        # value-dependent expansion somewhere below a
                        # memoizable root: this drain must not be replayed
                        self._capture_valid = False
                    t.state = TaskState.SPLIT
                    self.stats["split"] += 1
                    t.op.split(t, collect)
                    if not t.children:
                        # degenerate split (e.g. 1x1 partition): run as leaf
                        children.append(t)
                else:
                    children.append(t)
            self._process_scope(children, level + 1)
