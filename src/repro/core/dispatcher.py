"""The central dispatcher (paper §2.1): orchestrates task flow between the
program and the framework wrappers according to a task-flow graph.

Program-facing API is the paper's:  ``dispatcher.submit_task(t)`` during
program execution, ``dispatcher.run()`` (== ``utp_finalize``) to drain.

Semantics: tasks are expanded level by level.  A wave of ready tasks at
level ``l`` is split (each task's Operation creates children on the next
partition level, paper Fig. 2b); the union of their children forms the next
scope whose DAG is built by data versioning.  At ``graph.split_levels`` the
leaf executor runs the waves.  This is the AOT realization of the paper's
"ready tasks at w1 split and are submitted to w2" edge (DESIGN.md §2).
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Optional

import jax.numpy as jnp

from ..analysis.hazards import analyze_hazards
from ..analysis.verify import verify_stacked_members
from ..errors import DrainStalledError
from ..testing import faults
from .executors.base import Executor
from .executors.inline import InlineExecutor
from .executors.jit_wave import _DRAIN_MEMO, JitWaveExecutor, PallasExecutor
from .executors.sharded import ShardExecutor
from .graph import TaskFlowGraph, get_graph
from .task import GTask, TaskState
from .versioning import DepTracker, InFlightEpoch


def _make_executor(graph: TaskFlowGraph, mesh, on_finished) -> Executor:
    backend = "pallas" if graph.leaf_executor == "pallas" else "jnp"
    if graph.distributed:
        if mesh is None:
            raise ValueError(f"graph {graph.name} is distributed but mesh is None")
        return ShardExecutor(
            mesh, backend=backend, shard_axes=graph.shard_axes,
            on_task_finished=on_finished,
        )
    if graph.leaf_executor == "inline":
        return InlineExecutor(on_task_finished=on_finished)
    if graph.leaf_executor == "pallas":
        return PallasExecutor(on_task_finished=on_finished)
    return JitWaveExecutor(on_task_finished=on_finished)


class DrainHandle:
    """Handle over one overlapped (asynchronously launched) drain
    (DESIGN.md §12).

    ``run_async`` returns it immediately after the drain's programs have
    been DISPATCHED — device execution continues in the background while
    the host plans the next drain.  ``wait()`` is the optional fence; it
    also carries the in-flight extension of the capture-window hardening:
    a drain that fails AFTER dispatch (device-side error, injected
    ``drain.inflight`` fault) may have stored drain-memo entries this
    execution can no longer vouch for, so a failing ``wait`` discards
    exactly the keys this drain wrote before re-raising — the next healthy
    occurrence simply re-captures them.
    """

    def __init__(
        self,
        leaves: int,
        epochs: List[InFlightEpoch],
        memo_keys: List[tuple],
    ):
        self.leaves = leaves
        self.epochs = epochs
        self._memo_keys = memo_keys

    def is_ready(self) -> bool:
        """Non-blocking: True iff every launch has materialized on device."""
        return all(ep.is_ready() for ep in self.epochs)

    def invalidate_memo(self) -> None:
        """Discard the drain-memo entries this drain stored (idempotent)."""
        keys, self._memo_keys = self._memo_keys, []
        for key in keys:
            _DRAIN_MEMO.discard(key)

    def wait(self, timeout: Optional[float] = None) -> float:
        """Fence: block until every launch's live outputs materialize;
        returns host seconds spent blocked.  Epochs are fenced in launch
        order and donated buffers are skipped (the donation handshake,
        DESIGN.md §12), so overlapped re-drains over the same data are safe
        to fence even after their grids were donated forward.

        ``timeout`` (seconds) arms the hung-drain watchdog (DESIGN.md §14):
        XLA fences are not interruptible-by-value, so the budget is a
        polling deadline — readiness is polled until the wall clock expires,
        at which point this drain's memo keys are invalidated and a
        ``DrainStalledError`` raised.  The hung computation's device
        resources are NOT reclaimed (only a process restart does that); the
        watchdog bounds how long the host-side tick loop can be held
        hostage, nothing more.
        """
        try:
            if timeout is not None:
                deadline = time.monotonic() + timeout
                # The stall site fires BEFORE the first readiness poll so an
                # injected delay_s fault deterministically blows the budget
                # even when results are already materialized.
                faults.fire(
                    "drain.stall", epochs=len(self.epochs), leaves=self.leaves
                )
                while not self.is_ready():
                    if time.monotonic() >= deadline:
                        raise DrainStalledError(
                            f"drain fence not ready within {timeout:.3f}s "
                            f"budget ({len(self.epochs)} epoch(s), "
                            f"{self.leaves} leaves)"
                        )
                    time.sleep(min(0.001, timeout / 10))
                if time.monotonic() >= deadline:
                    raise DrainStalledError(
                        f"drain fence blew its {timeout:.3f}s budget "
                        f"({len(self.epochs)} epoch(s), {self.leaves} leaves)"
                    )
            faults.fire(
                "drain.inflight", epochs=len(self.epochs), leaves=self.leaves
            )
            return sum(ep.wait() for ep in self.epochs)
        except BaseException:
            self.invalidate_memo()
            raise


class _StackedAbort(Exception):
    """Raised when a collect-mode expansion hits a value-dependent
    (non-memoizable) split: such an expansion may read values that earlier
    leaf scopes would have computed, and in collect mode nothing has
    executed yet — the stacked path must abort BEFORE that split runs and
    redo the drain through the normal interleaved expand/execute path."""


class Dispatcher:
    def __init__(
        self,
        graph="g2",
        mesh=None,
        memoize_drains: bool = True,
        stack_roots: bool = True,
        verify: Optional[bool] = None,
    ):
        self.graph = get_graph(graph) if isinstance(graph, str) else graph
        self.mesh = mesh
        # Static verification (DESIGN.md §11): when on, every non-replay
        # scope is hazard-cross-checked and every planned schedule proven
        # legal before launch.  Default comes from REPRO_VERIFY ("" / "0"
        # = off) so whole test/bench runs can opt in without code changes.
        if verify is None:
            verify = os.environ.get("REPRO_VERIFY", "") not in ("", "0")
        self.verify = bool(verify)
        self.executor = _make_executor(self.graph, mesh, self._on_finished)
        self.executor.verify = self.verify
        self.memoize_drains = memoize_drains
        # Homogeneous-root stacking (DESIGN.md §7): a drain whose root
        # stream is N structurally identical, data-disjoint tasks runs as
        # ONE batched program over a pow2-padded batch axis instead of N
        # fused per-root segments.  ``stack_roots=False`` pins the PR-3
        # segment-fusion behaviour (the comparison baseline).
        self.stack_roots = stack_roots
        self._pending_roots: List[GTask] = []
        self._capture_valid = True
        # drain-memo keys stored by the CURRENT drain — handed to the
        # DrainHandle so an in-flight failure can invalidate exactly them
        self._drain_keys: List[tuple] = []
        self.finished_count = 0
        self.stats: Dict[str, int] = {
            "submitted": 0,
            "split": 0,
            "waves": 0,
            "memo_hits": 0,
            "memo_misses": 0,
            "stacked_drains": 0,
            "verified_scopes": 0,
        }

    # -- paper-facing API ------------------------------------------------------
    def submit_task(self, task: GTask) -> None:
        task.state = TaskState.SUBMITTED
        self.stats["submitted"] += 1
        if task.parent is not None:
            task.parent.add_child(task)
        self._pending_roots.append(task)

    def task_finished(self, task: GTask) -> None:
        """Paper Fig. 2(a) line 36 — completion report from a leaf wrapper."""
        task.state = TaskState.FINISHED
        self._on_finished(task)

    def run(self) -> int:
        """Drain all submitted tasks; returns number of leaf tasks executed.

        Drain memo (DESIGN.md §2): task splitting is a pure function of the
        root tasks' operations and argument geometry, so a drain whose root
        stream structurally matches a previous one must produce the same
        leaf schedule.  The first such drain is captured (the sequence of
        compiled WaveProgram executions); repeats skip Python re-splitting/
        re-versioning entirely and replay the programs on the fresh data —
        this is what makes repeated drains (training steps, iterative
        solvers, benchmark sweeps) cost one compiled-program dispatch.
        """
        # Homogeneous-root stacking (DESIGN.md §7): N structurally identical
        # roots drain as ONE batched program over a pow2-bucketed batch
        # axis; the returned leaf count is then the TEMPLATE's (each leaf
        # computes all N lanes at once).  Heterogeneous streams keep the
        # PR-3 path: per-root expansion + cross-root segment fusion.
        roots, self._pending_roots = self._pending_roots, []
        before = self.finished_count
        self._drain_keys = []
        if self.stack_roots and self._stackable(roots):
            if self._run_stacked(roots):
                return self.finished_count - before
        key = self._drain_memo_key(roots)
        memo = _DRAIN_MEMO.get(key) if key is not None else None
        if memo is not None:
            self.stats["memo_hits"] += 1
            self._replay_drain(memo, roots)
            return self.finished_count - before
        if key is not None:
            self.stats["memo_misses"] += 1
        capturing = key is not None
        if capturing:
            slot_of = {
                d.id: i for i, d in enumerate(self._root_datas(roots))
            }
            self.executor.begin_capture(slot_of)
            stats_before = (self.stats["split"], self.stats["waves"])
            self._capture_valid = True
        try:
            self._process_scope(roots, level=0)
        except BaseException:
            # failed drain hardening (DESIGN.md §10): discard the partial
            # capture so no half-captured entry can reach the drain memo
            # and the executor's capture window is closed for the retry
            if capturing:
                self.executor.end_capture()
            raise
        if capturing:
            records, ok = self.executor.end_capture()
            if ok and self._capture_valid:
                _DRAIN_MEMO[key] = {
                    "records": records,
                    "leaf_total": self.finished_count - before,
                    "split": self.stats["split"] - stats_before[0],
                    "waves": self.stats["waves"] - stats_before[1],
                }
                self._drain_keys.append(key)
        return self.finished_count - before

    def run_async(self) -> DrainHandle:
        """Drain all submitted tasks WITHOUT fencing device execution.

        Identical host-side work to ``run()`` — expansion, versioning,
        planning, memoization, and program dispatch all happen now — but
        the compiled programs execute asynchronously: the returned
        ``DrainHandle`` carries the drain's in-flight epochs so the caller
        can overlap the next drain's host work with this one's device work
        and fence later (or never: touching a result's ``.value`` blocks
        exactly like any lazy jax array).  Synchronous executors return an
        already-complete handle, so callers need no capability check
        (DESIGN.md §12)."""
        leaves = self.run()
        return DrainHandle(
            leaves, self.executor.take_inflight(), list(self._drain_keys)
        )

    # -- homogeneous-root stacking (DESIGN.md §7) ------------------------------
    def _stackable(self, roots: List[GTask]) -> bool:
        """True iff the root stream is a batch of structurally identical,
        data-disjoint tasks the executor can stack (DESIGN.md §7): same
        operation singleton, same per-arg geometry (region, level, shape,
        dtype, partitions, mode), every argument datum private to its root,
        and a local (non-distributed, capture-capable) executor."""
        if len(roots) < 2:
            return False
        if self.graph.distributed or not hasattr(
            self.executor, "execute_stacked"
        ):
            return False
        t = roots[0]
        if not t.op.memoizable:
            return False
        seen_ids = set()
        for r in roots:
            if r.op is not t.op or len(r.args) != len(t.args):
                return False
            for v, tv, m, tm in zip(r.args, t.args, r.modes, t.modes):
                d, td = v.data, tv.data
                if (
                    m is not tm
                    or v.region != tv.region
                    or v.level != tv.level
                    or d.shape != td.shape
                    or jnp.dtype(d.dtype) != jnp.dtype(td.dtype)
                    or tuple(d.partitions) != tuple(td.partitions)
                ):
                    return False
                if d.id in seen_ids or not d.has_value:
                    return False
                seen_ids.add(d.id)
        return True

    def _stacked_members(self, roots: List[GTask]) -> List[List]:
        """Per template root slot, the member data handles across requests
        (template = roots[0]; slot order = first-appearance arg order)."""
        arg_pos: List[int] = []
        seen = set()
        for j, v in enumerate(roots[0].args):
            if v.data.id not in seen:
                seen.add(v.data.id)
                arg_pos.append(j)
        return [[r.args[j].data for r in roots] for j in arg_pos]

    def _run_stacked(self, roots: List[GTask]) -> bool:
        """Drain a homogeneous root stream as ONE batched program set.

        Only the TEMPLATE root (roots[0]) is expanded — splitting is a pure
        function of geometry, and all roots share it.  The batch count is
        padded to a pow2 bucket, so any N hits one of O(log N) compiled
        programs and the drain-memo key is independent of the exact N.
        Falls back internally (template schedules as plain programs +
        remaining roots as a normal sub-drain) when the executor cannot
        take the whole-program stacked path; always returns True once the
        drain has been handled."""
        template = roots[0]
        n = len(roots)
        bucket = 1
        while bucket < n:
            bucket *= 2
        before = self.finished_count
        base_key = self._drain_memo_key([template])
        key = None if base_key is None else base_key + (("stacked", bucket),)
        memo = _DRAIN_MEMO.get(key) if key is not None else None
        members = self._stacked_members(roots)
        if faults.fires("plan.alias_lane", n_lanes=n):
            # corrupt the lane map BEFORE the memo branch so both the
            # capture and the replay path see the aliased lanes
            members = [[ms[0], ms[0], *ms[2:]] for ms in members]
        if self.verify:
            # V5 runs on every stacked drain (replays included): lane
            # membership is per-drain data identity, not plan structure,
            # so it cannot ride the structural verdict cache — but it is
            # one O(lanes) set walk, not a re-verification of the plan.
            verify_stacked_members(members)
        if memo is not None:
            self.stats["memo_hits"] += 1
            self.stats["stacked_drains"] += 1
            for rec in memo["records"]:
                self.executor.replay_program(
                    rec, [members[s] for s in rec.root_slots]
                )
            for t in roots:
                t.state = TaskState.FINISHED
            self.stats["split"] += memo["split"]
            self.stats["waves"] += memo["waves"]
            self.finished_count += memo["leaf_total"]
            return True
        capturing = key is not None
        stats_before = (self.stats["split"], self.stats["waves"])
        if capturing:
            self.stats["memo_misses"] += 1
            slot_of = {
                d.id: i for i, d in enumerate(self._root_datas([template]))
            }
            self.executor.begin_capture(slot_of)
            self._capture_valid = True
        schedules: List[tuple] = []
        try:
            self._process_scope([template], level=0, collect=schedules)
        except _StackedAbort:
            done = None
        except BaseException:
            if capturing:
                self.executor.end_capture()
            raise
        else:
            slot_datas = self._root_datas([template])
            member_of = {d.id: ms for d, ms in zip(slot_datas, members)}
            try:
                done = self.executor.execute_stacked(
                    schedules, member_of, bucket
                )
            except BaseException:
                # failed drain hardening (DESIGN.md §10): close the capture
                # window so no half-captured entry survives into the memo
                if capturing:
                    self.executor.end_capture()
                raise
        if done is None:
            # stacked path unavailable (non-grid-uniform schedule, or a
            # value-dependent split aborted the collect): discard the
            # template pre-expansion (its orphaned children never execute)
            # and redo the WHOLE drain through the normal path — all roots
            # in one scope, so cross-root segment fusion is kept.  No memo
            # for this drain (the template stats were rolled back, and the
            # root-level capture window has already been consumed).
            if capturing:
                self.executor.end_capture()
            self.stats["split"], self.stats["waves"] = stats_before
            self._process_scope(roots, level=0)
            for t in roots:
                t.state = TaskState.FINISHED
            return True
        self.stats["stacked_drains"] += 1
        if capturing:
            records, ok = self.executor.end_capture()
            if ok and self._capture_valid:
                _DRAIN_MEMO[key] = {
                    "records": records,
                    "leaf_total": self.finished_count - before,
                    "split": self.stats["split"] - stats_before[0],
                    "waves": self.stats["waves"] - stats_before[1],
                }
                self._drain_keys.append(key)
        for t in roots:
            t.state = TaskState.FINISHED
        return True

    @staticmethod
    def _root_datas(roots: List[GTask]) -> List:
        """Root-argument data handles in first-appearance order — THE slot
        order; memo key, capture, and replay must all derive from this."""
        datas = []
        seen = set()
        for t in roots:
            for v in t.args:
                if v.data.id not in seen:
                    seen.add(v.data.id)
                    datas.append(v.data)
        return datas

    def _drain_memo_key(self, roots: List[GTask]) -> Optional[tuple]:
        """Structural key of a root-task stream, or None if not memoizable.

        Captures everything task expansion depends on: graph config,
        executor identity, and per root task the operation plus each
        argument's (data slot, region, level, root shape/dtype/partitions,
        access mode).  Data *identity* is slot-relative, so a fresh GData
        with the same geometry hits the memo.  Relies on ``Operation.split``
        being a pure function of that geometry (the Operation contract)."""
        if not self.memoize_drains or not roots:
            return None
        if not hasattr(self.executor, "begin_capture"):
            return None
        if not all(t.op.memoizable for t in roots):
            return None
        slot_of = {d.id: i for i, d in enumerate(self._root_datas(roots))}
        parts: List[tuple] = [
            (self.graph.name, self.graph.split_levels),
            self.executor.memo_key_extra(),
        ]
        for t in roots:
            args = []
            for v, m in zip(t.args, t.modes):
                d = v.data
                slot = slot_of[d.id]
                r = v.region
                args.append(
                    (
                        slot,
                        (r.r0, r.c0, r.rows, r.cols),
                        v.level,
                        d.shape,
                        str(jnp.dtype(d.dtype)),
                        tuple(d.partitions),
                        m.value,
                    )
                )
            parts.append((t.op.name, tuple(args)))
        return tuple(parts)

    def _replay_drain(self, memo: dict, roots: List[GTask]) -> None:
        datas = self._root_datas(roots)
        for rec in memo["records"]:
            self.executor.replay_program(rec, [datas[s] for s in rec.root_slots])
        for t in roots:
            t.state = TaskState.FINISHED
        self.stats["split"] += memo["split"]
        self.stats["waves"] += memo["waves"]
        self.finished_count += memo["leaf_total"]

    # -- internal --------------------------------------------------------------
    def _on_finished(self, task: GTask) -> None:
        self.finished_count += 1
        parent = task.parent
        while parent is not None and parent.child_finished():
            parent.state = TaskState.FINISHED
            parent = parent.parent

    def _process_scope(
        self, tasks: List[GTask], level: int, collect: Optional[List] = None
    ) -> None:
        if not tasks:
            return
        tracker = DepTracker()
        for t in tasks:
            tracker.add(t)
        waves = tracker.waves()
        self.stats["waves"] += len(waves)
        leaf_level = self.graph.split_levels
        if level >= leaf_level:
            # hand over the exact task DAG, not just the level schedule:
            # the executor's scheduling pass issues dependency-exactly and
            # fuses groups across former wave boundaries (DESIGN.md §2).
            # ``collect`` gathers the leaf schedules instead of executing
            # (the stacked drain path plans them all before running any)
            dag = tracker.dag()
            if faults.fires(
                "plan.drop_edge", level=level, n_tasks=len(tasks)
            ):
                faults.mutate_drop_edges(dag)
            if self.verify:
                analyze_hazards(tasks, dag)
                self.stats["verified_scopes"] += 1
            if collect is not None:
                collect.append((waves, dag))
            else:
                self.executor.execute_schedule(waves, dag)
            return
        if self.verify:
            # inner scopes carry dependences too (a wrong inner-level wave
            # order reorders whole subtree expansions) — cross-check every
            # scope, not just the leaf one (DESIGN.md §11)
            analyze_hazards(tasks, tracker.dag())
            self.stats["verified_scopes"] += 1
        for wave in waves:
            children: List[GTask] = []

            def submit_child(child: GTask) -> None:
                if child.parent is not None:
                    child.parent.add_child(child)
                child.state = TaskState.SUBMITTED
                children.append(child)

            for t in wave:
                if t.op.can_split(t):
                    # the fault site makes a matched split behave exactly
                    # like a value-dependent (non-memoizable) one, so the
                    # _StackedAbort fallback and the capture opt-out are
                    # exercisable without a bespoke Operation (DESIGN.md §10)
                    if not t.op.memoizable or faults.fires(
                        "split.value_dependent", op=t.op.name, level=level
                    ):
                        if collect is not None:
                            # collect mode defers all execution, but a
                            # value-dependent split may read values earlier
                            # leaf scopes produce — abort BEFORE it runs
                            raise _StackedAbort()
                        # value-dependent expansion somewhere below a
                        # memoizable root: this drain must not be replayed
                        self._capture_valid = False
                    t.state = TaskState.SPLIT
                    self.stats["split"] += 1
                    t.op.split(t, submit_child)
                    if not t.children:
                        # degenerate split (e.g. 1x1 partition): run as leaf
                        children.append(t)
                else:
                    children.append(t)
            self._process_scope(children, level + 1, collect)
