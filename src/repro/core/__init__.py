"""UTP core: the paper's unified task-based programming model in JAX.

Public surface mirrors the paper's programming interface (Fig. 2):
``GData`` / ``GTask`` / ``Operation`` / ``Dispatcher`` plus the external
task-flow graph configuration (G1-G4 analogs).
"""

from .api import dispatcher, utp_finalize, utp_get_parameters, utp_initialize
from .data import GData, GView, Region, dd_matrix, spd_matrix
from .dispatcher import Dispatcher, DrainHandle
from .graph import GRAPHS, TaskFlowGraph, get_graph
from .operation import Operation, OpRegistry
from .task import Access, GTask, TaskState
from .versioning import DepTracker, InFlightEpoch, TaskDag

__all__ = [
    "Access",
    "DepTracker",
    "Dispatcher",
    "DrainHandle",
    "GData",
    "GRAPHS",
    "GTask",
    "GView",
    "InFlightEpoch",
    "Operation",
    "OpRegistry",
    "Region",
    "TaskDag",
    "TaskFlowGraph",
    "TaskState",
    "dd_matrix",
    "dispatcher",
    "get_graph",
    "spd_matrix",
    "utp_finalize",
    "utp_get_parameters",
    "utp_initialize",
]
