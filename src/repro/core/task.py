"""Generic tasks (paper §2.2): operation + parent + data args with access modes."""

from __future__ import annotations

import enum
import itertools
from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple

from .data import GView

if TYPE_CHECKING:  # pragma: no cover
    from .operation import Operation

_uid = itertools.count()


class Access(enum.Enum):
    READ = "r"
    WRITE = "w"
    READWRITE = "rw"

    @property
    def writes(self) -> bool:
        return self is not Access.READ

    @property
    def reads(self) -> bool:
        return self is not Access.WRITE


class TaskState(enum.Enum):
    CREATED = 0
    SUBMITTED = 1
    READY = 2
    RUNNING = 3
    SPLIT = 4
    FINISHED = 5


class GTask:
    """The paper's ``GTask``: constructor takes an Operation object, a parent
    task (or None), and the data arguments (Fig. 2(a) lines 22-23)."""

    __slots__ = (
        "id",
        "op",
        "parent",
        "args",
        "modes",
        "state",
        "children",
        "_unfinished_children",
        "level",
    )

    def __init__(
        self,
        op: "Operation",
        parent: Optional["GTask"],
        args: Sequence[GView],
        modes: Optional[Sequence[Access]] = None,
    ):
        self.id = next(_uid)
        self.op = op
        self.parent = parent
        self.args: List[GView] = list(args)
        self.modes: List[Access] = (
            list(modes) if modes is not None else list(op.default_modes(len(args)))
        )
        if len(self.modes) != len(self.args):
            raise ValueError("modes/args length mismatch")
        self.state = TaskState.CREATED
        self.children: List[GTask] = []
        self._unfinished_children = 0
        self.level = 0 if parent is None else parent.level + 1

    # -- dependency bookkeeping ---------------------------------------------
    def accesses(self) -> List[Tuple[GView, Access]]:
        return list(zip(self.args, self.modes))

    def outputs(self) -> List[GView]:
        return [v for v, m in zip(self.args, self.modes) if m.writes]

    def inputs(self) -> List[GView]:
        return [v for v, m in zip(self.args, self.modes) if m.reads]

    def add_child(self, child: "GTask") -> None:
        self.children.append(child)
        self._unfinished_children += 1

    def child_finished(self) -> bool:
        """Returns True when the last child finished (parent completes)."""
        self._unfinished_children -= 1
        return self._unfinished_children == 0

    def __repr__(self) -> str:  # pragma: no cover
        return f"GTask#{self.id}({self.op.name}, lvl={self.level}, {self.args})"
