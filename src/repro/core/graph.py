"""Task-flow graph configurations (paper §2.1, Fig. 1a).

A ``TaskFlowGraph`` describes how tasks flow from the program through the
dispatcher to framework wrappers: how many hierarchy levels tasks are split
into, and which executor acts at the leaf level.  The paper's G1-G4 map to:

    g1  -> no split, inline leaf          (program -> D -> cpuBLAS)
    g2  -> 1 level,  jit_wave leaf        (program -> D -> SuperGlue -> cpuBLAS)
    g2p -> 1 level,  pallas leaf          (SuperGlue -> cuBLAS analog)
    g3  -> 2 levels, shard + jit_wave     (D -> DuctTeip -> SuperGlue -> cpuBLAS)
    g4  -> 2 levels, shard + pallas       (D -> DuctTeip -> StarPU/GPU analog)

The configuration is *external* to the program (paper abstract: "the
cooperation between frameworks is configured externally with no need to
modify the programs"): the same ``utp_cholesky`` runs under any graph.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class TaskFlowGraph:
    name: str
    split_levels: int  # hierarchy depth: 0 = run root tasks directly
    leaf_executor: str  # 'inline' | 'jit_wave' | 'pallas'
    distributed: bool = False  # insert the shard (DuctTeip) stage on top
    shard_axes: Tuple[Optional[str], ...] = ("data", None)

    def describe(self) -> str:
        stages = ["program", "D"]
        if self.distributed:
            stages.append("DT(shard)")
        if self.split_levels >= 1:
            stages.append({"jit_wave": "SG(jit_wave)", "pallas": "SG(jit_wave)"}.get(
                self.leaf_executor, self.leaf_executor
            ))
        stages.append(
            {"inline": "CB(jnp)", "jit_wave": "CB(jnp)", "pallas": "GB(pallas)"}[
                self.leaf_executor
            ]
        )
        return " -> ".join(stages)


GRAPHS = {
    "g1": TaskFlowGraph("g1", split_levels=0, leaf_executor="inline"),
    "g2": TaskFlowGraph("g2", split_levels=1, leaf_executor="jit_wave"),
    "g2p": TaskFlowGraph("g2p", split_levels=1, leaf_executor="pallas"),
    "g3": TaskFlowGraph(
        "g3", split_levels=2, leaf_executor="jit_wave", distributed=True
    ),
    "g4": TaskFlowGraph("g4", split_levels=2, leaf_executor="pallas", distributed=True),
    # single-level distributed (DuctTeip without inner SuperGlue)
    "g3flat": TaskFlowGraph(
        "g3flat", split_levels=1, leaf_executor="jit_wave", distributed=True
    ),
}


def get_graph(name: str) -> TaskFlowGraph:
    try:
        return GRAPHS[name]
    except KeyError:
        raise KeyError(f"unknown task-flow graph {name!r}; have {sorted(GRAPHS)}")
