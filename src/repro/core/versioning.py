"""SuperGlue-style data versioning -> dependency edges (paper refs [19, 22]).

The paper's frameworks discover dependencies at runtime from the order of
task submissions and the access modes of their data arguments.  We do the
same, but ahead of execution: the program's sequential submission order is
the *program order*, and the classic last-writer / readers-since-write
algorithm produces the task DAG edges.

Fast path: within one dispatcher scope all accessed regions share a uniform
block grid (hierarchical splitting always produces aligned equal blocks), so
exact-region hashing suffices.  If a program mixes region granularities on
one root datum we fall back to rectangle-overlap scanning, which is exact.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Set, Tuple

from .data import Region
from .task import Access, GTask


class DepTracker:
    """Builds WAR/RAW/WAW edges from sequential task submission order."""

    def __init__(self):
        # (data_id, region) -> state
        self._last_writer: Dict[Tuple[int, Region], GTask] = {}
        self._readers: Dict[Tuple[int, Region], List[GTask]] = defaultdict(list)
        # data_id -> set of region shapes seen (uniformity check)
        self._shapes: Dict[int, Set[Tuple[int, int]]] = defaultdict(set)
        # data_id -> all access keys (for the overlap fallback)
        self._regions: Dict[int, List[Region]] = defaultdict(list)
        self.edges: Dict[int, Set[int]] = defaultdict(set)  # pred id -> succ ids
        self.preds: Dict[int, Set[int]] = defaultdict(set)  # succ id -> pred ids
        self.tasks: Dict[int, GTask] = {}

    def _add_edge(self, pred: GTask, succ: GTask) -> None:
        if pred.id == succ.id:
            return
        if succ.id not in self.edges[pred.id]:
            self.edges[pred.id].add(succ.id)
            self.preds[succ.id].add(pred.id)

    def _conflicting_keys(self, data_id: int, region: Region):
        """Keys on this datum whose region overlaps ``region``."""
        shapes = self._shapes[data_id]
        if len(shapes) <= 1:
            # uniform grid -> overlap iff exact match
            yield (data_id, region)
            return
        for other in self._regions[data_id]:
            if other.overlaps(region):
                yield (data_id, other)

    def add(self, task: GTask) -> None:
        """Register ``task``'s accesses; creates edges from earlier tasks."""
        self.tasks[task.id] = task
        for view, mode in task.accesses():
            data_id = view.data.id
            region = view.region
            self._shapes[data_id].add(region.shape)
            for key in list(self._conflicting_keys(data_id, region)):
                lw = self._last_writer.get(key)
                if mode.writes:
                    # WAW + WAR: after last writer and all readers since
                    if lw is not None:
                        self._add_edge(lw, task)
                    for r in self._readers.get(key, ()):
                        self._add_edge(r, task)
                else:
                    # RAW: after last writer
                    if lw is not None:
                        self._add_edge(lw, task)
            key = (data_id, region)
            if region not in self._regions[data_id]:
                self._regions[data_id].append(region)
            if mode.writes:
                self._last_writer[key] = task
                self._readers[key] = []
            else:
                self._readers[key].append(task)

    # -- scheduling ----------------------------------------------------------
    def waves(self) -> List[List[GTask]]:
        """Kahn level schedule: wave k = tasks whose preds are all in waves <k."""
        indeg = {tid: len(self.preds.get(tid, ())) for tid in self.tasks}
        frontier = sorted(tid for tid, d in indeg.items() if d == 0)
        out: List[List[GTask]] = []
        done = 0
        while frontier:
            out.append([self.tasks[tid] for tid in frontier])
            done += len(frontier)
            nxt: List[int] = []
            for tid in frontier:
                for succ in self.edges.get(tid, ()):
                    indeg[succ] -= 1
                    if indeg[succ] == 0:
                        nxt.append(succ)
            frontier = sorted(nxt)
        if done != len(self.tasks):  # pragma: no cover - cycle = bug
            raise RuntimeError("cycle in task DAG (versioning bug)")
        return out

    def sequential_order(self) -> List[GTask]:
        """Program (submission) order — the reference semantics."""
        return [self.tasks[tid] for tid in sorted(self.tasks)]
