"""SuperGlue-style data versioning -> dependency edges (paper refs [19, 22]).

The paper's frameworks discover dependencies at runtime from the order of
task submissions and the access modes of their data arguments.  We do the
same, but ahead of execution: the program's sequential submission order is
the *program order*, and the classic last-writer / readers-since-write
algorithm produces the task DAG edges.

Fast path: within one dispatcher scope all accessed regions share a uniform
block grid (hierarchical splitting always produces aligned equal blocks), so
exact-region hashing suffices.  If a program mixes region granularities on
one root datum we fall back to rectangle-overlap scanning, which is exact.

The tracker does not discard its edge DAG after ``waves()``: ``dag()``
exports the leaf-level task DAG (edges, predecessors, reachability) so the
WaveProgram scheduling pass can issue dependency-exactly and answer
fusion-legality queries (two task groups may share one batched launch iff
no path connects them — ``TaskDag.independent``; DESIGN.md §2).
"""

from __future__ import annotations

import time
from collections import defaultdict
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .data import Region
from .task import GTask


class TaskDag:
    """The leaf-level task DAG a scope's versioning discovered.

    Handed from the dispatcher to the executor alongside the Kahn level
    schedule (``Executor.execute_schedule``).  Provides the ground-truth
    fusion-legality query for the dependency-exact scheduling pass:
    reachability is computed once (bitsets over a topological order) and
    cached, so per-query cost is a few big-int ANDs.
    """

    def __init__(
        self,
        tasks: Dict[int, GTask],
        edges: Dict[int, Set[int]],
        preds: Dict[int, Set[int]],
    ):
        self.tasks = tasks
        self.edges = edges
        self.preds = preds
        self._pos: Optional[Dict[int, int]] = None
        self._reach: Optional[Dict[int, int]] = None
        self._height: Optional[Dict[int, int]] = None

    def _toposort(self) -> List[int]:
        indeg = {tid: len(self.preds.get(tid, ())) for tid in self.tasks}
        frontier = sorted(tid for tid, d in indeg.items() if d == 0)
        order: List[int] = []
        while frontier:
            order.extend(frontier)
            nxt: List[int] = []
            for tid in frontier:
                for succ in self.edges.get(tid, ()):
                    indeg[succ] -= 1
                    if indeg[succ] == 0:
                        nxt.append(succ)
            frontier = sorted(nxt)
        if len(order) != len(self.tasks):  # pragma: no cover - cycle = bug
            raise RuntimeError("cycle in task DAG (versioning bug)")
        return order

    def _closure(self) -> None:
        """Strict-descendant bitsets per task, over one topological order."""
        order = self._toposort()
        pos = {tid: i for i, tid in enumerate(order)}
        reach: Dict[int, int] = {}
        for tid in reversed(order):
            r = 0
            for succ in self.edges.get(tid, ()):
                r |= reach[succ] | (1 << pos[succ])
            reach[tid] = r
        self._pos, self._reach = pos, reach

    def independent(self, ids_a: Iterable[int], ids_b: Iterable[int]) -> bool:
        """Fusion legality: True iff NO path connects the two task sets.

        Two same-signature groups may be fused into one batched launch only
        when this holds in both directions — any connecting path means some
        third task must execute between them, so one launch cannot contain
        both ends (DESIGN.md §2, fusion legality rule).
        """
        if self._reach is None:
            self._closure()
        pos, reach = self._pos, self._reach
        mask_a = reach_a = 0
        for t in ids_a:
            mask_a |= 1 << pos[t]
            reach_a |= reach[t]
        mask_b = reach_b = 0
        for t in ids_b:
            mask_b |= 1 << pos[t]
            reach_b |= reach[t]
        return not (reach_a & mask_b) and not (reach_b & mask_a)

    def path(self, a: int, b: int) -> bool:
        """True iff a directed path ``a -> b`` exists (strict: no trivial
        self-path).  The static verifier's primitive (DESIGN.md §11): the
        hazard analysis asks it for every recomputed dependence pair, and
        ``verify_plan`` for every intra-group member pair — both O(1) once
        the reachability bitsets are built."""
        if self._reach is None:
            self._closure()
        return bool(self._reach[a] & (1 << self._pos[b]))

    def heights(self) -> Dict[int, int]:
        """Longest path (in tasks) from each task to a sink — the critical-
        path priority used for lookahead ordering (panel factorizations sit
        on long chains, trailing updates on short ones)."""
        if self._height is None:
            order = self._toposort()
            h: Dict[int, int] = {}
            for tid in reversed(order):
                succs = self.edges.get(tid, ())
                h[tid] = 1 + max((h[s] for s in succs), default=-1)
            self._height = h
        return self._height


class DepTracker:
    """Builds WAR/RAW/WAW edges from sequential task submission order."""

    def __init__(self):
        # (data_id, region) -> state
        self._last_writer: Dict[Tuple[int, Region], GTask] = {}
        self._readers: Dict[Tuple[int, Region], List[GTask]] = {}
        # data_id -> set of region shapes seen (uniformity check)
        self._shapes: Dict[int, Set[Tuple[int, int]]] = defaultdict(set)
        # data_id -> all access keys (for the overlap fallback)
        self._regions: Dict[int, List[Region]] = defaultdict(list)
        self.edges: Dict[int, Set[int]] = defaultdict(set)  # pred id -> succ ids
        self.preds: Dict[int, Set[int]] = defaultdict(set)  # succ id -> pred ids
        self.tasks: Dict[int, GTask] = {}

    def _add_edge(self, pred: GTask, succ: GTask) -> None:
        if pred.id == succ.id:
            return
        if succ.id not in self.edges[pred.id]:
            self.edges[pred.id].add(succ.id)
            self.preds[succ.id].add(pred.id)

    def add(self, task: GTask) -> None:
        """Register ``task``'s accesses; creates edges from earlier tasks.

        This is the first-drain hot loop (80-155 us/task of pure Python at
        seed), so the uniform-grid fast path avoids every avoidable
        allocation: no ``accesses()`` tuple list, no generator + ``list``
        materialization for the single conflicting key, and the readers
        list is cleared in place instead of replaced on each write.
        """
        self.tasks[task.id] = task
        last_writer = self._last_writer
        readers = self._readers
        for view, mode in zip(task.args, task.modes):
            data_id = view.data.id
            region = view.region
            shapes = self._shapes[data_id]
            shapes.add(region.shape)
            regions = self._regions[data_id]
            writes = mode.writes
            if len(shapes) <= 1:
                keys = ((data_id, region),)
            else:
                keys = [(data_id, o) for o in regions if o.overlaps(region)]
            for key in keys:
                lw = last_writer.get(key)
                if lw is not None:
                    # RAW for reads, WAW for writes: always after last writer
                    self._add_edge(lw, task)
                if writes:
                    # WAR: after all readers since that write
                    rs = readers.get(key)
                    if rs:
                        for r in rs:
                            self._add_edge(r, task)
            if region not in regions:
                regions.append(region)
            key = (data_id, region)
            if writes:
                last_writer[key] = task
                rs = readers.get(key)
                if rs:
                    rs.clear()
            else:
                rs = readers.get(key)
                if rs is None:
                    readers[key] = [task]
                else:
                    rs.append(task)

    # -- scheduling ----------------------------------------------------------
    def waves(self) -> List[List[GTask]]:
        """Kahn level schedule: wave k = tasks whose preds are all in waves <k."""
        indeg = {tid: len(self.preds.get(tid, ())) for tid in self.tasks}
        frontier = sorted(tid for tid, d in indeg.items() if d == 0)
        out: List[List[GTask]] = []
        done = 0
        while frontier:
            out.append([self.tasks[tid] for tid in frontier])
            done += len(frontier)
            nxt: List[int] = []
            for tid in frontier:
                for succ in self.edges.get(tid, ()):
                    indeg[succ] -= 1
                    if indeg[succ] == 0:
                        nxt.append(succ)
            frontier = sorted(nxt)
        if done != len(self.tasks):  # pragma: no cover - cycle = bug
            raise RuntimeError("cycle in task DAG (versioning bug)")
        return out

    def dag(self) -> TaskDag:
        """Export the leaf task DAG for dependency-exact scheduling.

        Shares (not copies) the tracker's edge structures: the tracker is
        per-scope and is dropped right after scheduling, while the DAG lives
        on through ``Executor.execute_schedule``."""
        return TaskDag(self.tasks, self.edges, self.preds)

    def sequential_order(self) -> List[GTask]:
        """Program (submission) order — the reference semantics."""
        return [self.tasks[tid] for tid in sorted(self.tasks)]


class InFlightEpoch:
    """One launched program's not-yet-materialized device results
    (DESIGN.md §12).

    JAX dispatch is asynchronous: a compiled WaveProgram launch returns
    array futures immediately while XLA executes in the background, so the
    host is free to plan/trace/dispatch the NEXT drain.  ``InFlightEpoch``
    is the handle the executor records per launch so callers that need a
    fence (deferred ``check_finite`` resolution, fault-containment
    boundaries, benchmarks) can block *once*, at a point of their choosing,
    instead of the runtime fencing on the critical path.

    Donation-safety handshake: the stacked repeat-tick fast path
    (DESIGN.md §7) donates epoch N's result grid straight into epoch N+1's
    program while N may still be in flight.  XLA orders the transfer on
    device; host-side the donated ``jax.Array`` is invalidated, and calling
    ``block_until_ready`` on it raises.  Both ``is_ready`` and ``wait``
    therefore SKIP deleted buffers — a donated output's completion is
    subsumed by the consuming epoch's, which the caller fences separately
    (drains hand their epochs forward in launch order, so fencing the
    newest epoch transitively covers every donated ancestor).
    """

    __slots__ = ("outputs", "label")

    def __init__(self, outputs: Sequence[object], label: str = ""):
        self.outputs = tuple(outputs)
        self.label = label

    @staticmethod
    def _deleted(arr) -> bool:
        is_deleted = getattr(arr, "is_deleted", None)
        return bool(is_deleted()) if is_deleted is not None else False

    def is_ready(self) -> bool:
        """Non-blocking: True iff every live (non-donated) output has
        materialized on device."""
        for arr in self.outputs:
            if self._deleted(arr):
                continue
            is_ready = getattr(arr, "is_ready", None)
            if is_ready is not None and not is_ready():
                return False
        return True

    def wait(self) -> float:
        """Block until every live output materializes; returns the seconds
        the host spent blocked (the pipeline's ``host_idle`` contribution).
        Device-side execution errors surface here, not at launch."""
        t0 = time.perf_counter()
        for arr in self.outputs:
            if self._deleted(arr):
                continue
            block = getattr(arr, "block_until_ready", None)
            if block is not None:
                block()
        return time.perf_counter() - t0
