"""Operation objects (paper §2.3): ``split`` into child tasks or ``run`` a leaf.

An ``Operation`` is stateless and shared by all tasks of its kind (the
paper's ``upotrfo``/``ugemmo``/... singletons).  Executors obtain the pure
leaf computation through ``leaf_fn(backend)`` so the *same* operation can be
executed by jnp on CPU (the cpuBLAS wrapper analog) or by a Pallas TPU tile
kernel (the cuBLAS wrapper analog) — the unified-interface point of the
paper.

Leaf function convention (vmap-able):
    ``fn(*arrays) -> tuple(updated arrays, one per WRITE/READWRITE arg)``
where ``arrays`` are the task's argument blocks in order.
"""

from __future__ import annotations

from typing import Callable, List, Sequence

import jax

from .task import Access, GTask


class Operation:
    """One registered operation kind — the unit the whole system speaks.

    Hook contract (everything the dispatcher/executors ever call):

    ``name``                 process-unique registry key; also the wave-
                             batching signature component.
    ``default_modes(n)``     per-argument access intents (READ/WRITE/
                             READWRITE) used by data versioning.
    ``can_split``/``split``  hierarchical expansion into child tasks on the
                             next partition level (pure in geometry when
                             ``memoizable``); a *composed* operation may
                             expand a whole pipeline of family members
                             into one scope (DESIGN.md §4).
    ``leaf_fn(backend)``     pure block computation, one updated array per
                             write-mode argument (tuple if several).
    ``batched_leaf_fn``      stacked-blocks form; defaults to ``vmap`` of
                             ``leaf_fn`` so new ops ride the wave
                             executors with no extra code.
    ``grid_fused_fn``        optional fused gather/compute/scatter kernel
                             over resident grids (Pallas backend).

    Executors never special-case an op name — implementing these hooks is
    the entire integration surface (DESIGN.md §6).
    """

    name: str = "op"

    # Drain-memo contract (DESIGN.md §2): True asserts that ``split`` is a
    # pure function of the task's operation + argument *geometry* (regions,
    # levels, partitions) — never of data values or external state — so a
    # structurally repeated drain may replay the captured schedule.  Ops
    # with value-dependent expansion (e.g. adaptive factorizations) must
    # set this False to keep every drain through them unmemoized.
    memoizable: bool = True

    def default_modes(self, n_args: int) -> Sequence[Access]:
        """Override for op-specific access intents."""
        return [Access.READWRITE] * n_args

    # -- hierarchy ------------------------------------------------------------
    def can_split(self, task: GTask) -> bool:
        """True if the task's args have another partition level to split into."""
        return all(v.level + 1 < v.data.n_levels for v in task.args)

    def split(self, task: GTask, submit: Callable[[GTask], None]) -> None:
        """Create child tasks on partitions of ``task``'s args (paper Fig 2b).

        Must be a pure function of the args' geometry when ``memoizable``
        is left True — see the class attribute above."""
        raise NotImplementedError(f"{self.name} cannot split")

    # -- leaf execution ---------------------------------------------------------
    def leaf_fn(self, backend: str) -> Callable:
        """Pure function implementing this op on raw blocks for ``backend``.

        ``backend`` is one of {'jnp', 'pallas'}.
        """
        raise NotImplementedError(self.name)

    def batched_leaf_fn(self, backend: str) -> Callable:
        """Batched leaf over stacked blocks ``(n, *block_shape)`` per arg.

        Default: ``vmap`` of ``leaf_fn`` — every Operation rides the wave
        executors with no extra code.  Override to launch a natively batched
        kernel instead (e.g. one Pallas grid over the whole stack).
        """
        return jax.vmap(self.leaf_fn(backend))

    def grid_fused_fn(self, backend: str):
        """Optional fused gather/compute/scatter kernel over resident grids.

        Returns ``(call, write_arg)`` where ``call(idxs, grids)`` consumes
        scalar-prefetched ``(n, 2)`` block-index arrays plus one grid per
        argument and returns the updated grid of ``write_arg`` — or ``None``
        when the backend has no fused path (the WaveProgram compiler then
        falls back to gather -> batched leaf -> scatter; DESIGN.md §2).
        """
        return None

    def __repr__(self) -> str:  # pragma: no cover
        return f"Operation({self.name})"


class OpRegistry:
    """Name -> Operation singleton registry (used by config/serialization)."""

    _ops = {}

    @classmethod
    def register(cls, op: Operation) -> Operation:
        """Register a singleton; names are unique across the process.

        A silent overwrite would split the algebra in two — tasks created
        with the old singleton and configs resolving the new one would no
        longer group/batch together — so a colliding name is an error.
        """
        prev = cls._ops.get(op.name)
        if prev is not None and prev is not op:
            raise ValueError(
                f"operation name {op.name!r} already registered by {prev!r}"
            )
        cls._ops[op.name] = op
        return op

    @classmethod
    def get(cls, name: str) -> Operation:
        return cls._ops[name]

    @classmethod
    def names(cls) -> List[str]:
        return sorted(cls._ops)
