"""WaveProgram: whole-schedule compiled execution (DESIGN.md §2).

The dispatcher hands the leaf executor a complete level schedule — an
ordered list of waves of independent tasks.  At seed every wave group was a
separate Python-dispatched ``jit`` call that re-laid the root matrices out
into grid form and back: O(waves x groups) dispatches and O(N^2) transpose
traffic per drain.  The WaveProgram compiler instead traces the *entire*
schedule into ONE jitted XLA program over grid-resident roots:

    plan   = plan_schedule(waves)      # structural key + per-group indices
    fn     = build_program(plan, ...)  # one traced fn, cached on plan.key
    grids' = fn(grids, idx_arrays)     # one dispatch per drain

Roots stay in ``(nr, nc, br, bc)`` grid-major layout for the duration (the
``GData`` grid-resident epoch), so gather/scatter is direct fancy indexing
with no per-launch reshape/transpose.  Block indices are traced arguments:
two drains whose schedules share a structure (op sequence, group sizes, arg
slots, shapes, dtypes) hit the same compiled program — the repeated-drain
case (training steps, iterative solvers, benchmark sweeps) costs one
compile total.

Per group the compiler emits either the operation's fused grid kernel
(``Operation.grid_fused_fn`` — Pallas scalar-prefetch gather/compute/
scatter with the output aliased to the written grid, so no gathered tile
stacks materialize in HBM) or the generic gather -> batched leaf -> scatter
sequence.  Group sizes are exact, never padded: every group is traced
inline into one program, so pow2 bucketing would buy no compile savings,
and duplicate trailing indices are unsound for read-write fused kernels.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..data import GData
from ..task import GTask
from .base import group_wave


@dataclass(frozen=True)
class GroupPlan:
    """One same-signature task group inside a wave (static + index data)."""

    op: object  # Operation
    arg_slots: Tuple[int, ...]  # per-arg index into the plan's roots order
    write_pos: Tuple[int, ...]  # arg positions with write access
    size: int  # exact group size (no padding)
    idxs: Tuple[np.ndarray, ...]  # per-arg (size, 2) int32 block coords

    @property
    def sig(self) -> tuple:
        return (self.op.name, self.arg_slots, self.write_pos, self.size)


@dataclass
class SchedulePlan:
    """A fully analyzed level schedule, ready to compile/execute."""

    roots_order: Tuple[int, ...]  # data ids, stable by first appearance
    datas: Dict[int, GData]
    blocks: Tuple[Tuple[int, int], ...]  # per-slot leaf block shape (br, bc)
    waves: List[List[GroupPlan]]
    tasks: List[GTask]  # all tasks in wave order
    key: tuple  # structural cache key (no data identity)

    def groups(self):
        for wave in self.waves:
            yield from wave

    def flat_idxs(self) -> jnp.ndarray:
        """All block-index rows concatenated into ONE (total, 2) int32 array
        (a single host->device transfer per drain; the program slices it at
        static offsets in trace order)."""
        parts = [ix for g in self.groups() for ix in g.idxs]
        return jnp.asarray(np.concatenate(parts, axis=0))


def plan_schedule(waves: Sequence[Sequence[GTask]]) -> Optional[SchedulePlan]:
    """Analyze a level schedule for whole-program compilation.

    Returns None (caller falls back to per-wave launches) when the schedule
    is not grid-uniform: some root lacks a value, or a task's region is not
    one aligned block of that root's uniform leaf grid.
    """
    roots_order: List[int] = []
    datas: Dict[int, GData] = {}
    blocks: Dict[int, Tuple[int, int]] = {}
    tasks: List[GTask] = []
    for wave in waves:
        for t in wave:
            tasks.append(t)
            for v in t.args:
                d = v.data
                if d.id not in datas:
                    if not d.in_grid_epoch and d._value is None:
                        return None
                    roots_order.append(d.id)
                    datas[d.id] = d
                    blocks[d.id] = v.region.shape
                br, bc = blocks[d.id]
                r = v.region
                if (
                    r.shape != (br, bc)
                    or r.r0 % br
                    or r.c0 % bc
                    or d.shape[0] % br
                    or d.shape[1] % bc
                ):
                    return None
    if not tasks:
        return None
    slot_of = {d: i for i, d in enumerate(roots_order)}

    plan_waves: List[List[GroupPlan]] = []
    for wave in waves:
        groups: List[GroupPlan] = []
        for _, group_tasks in group_wave(wave).items():
            rep = group_tasks[0]
            arg_slots = tuple(slot_of[v.data.id] for v in rep.args)
            write_pos = tuple(i for i, m in enumerate(rep.modes) if m.writes)
            idxs = tuple(
                np.array(
                    [t.args[a].block_index() for t in group_tasks],
                    dtype=np.int32,
                )
                for a in range(len(rep.args))
            )
            groups.append(
                GroupPlan(rep.op, arg_slots, write_pos, len(group_tasks), idxs)
            )
        plan_waves.append(groups)

    roots = tuple(roots_order)
    blocks_t = tuple(blocks[d] for d in roots)
    key = (
        tuple(
            (datas[d].shape, str(jnp.dtype(datas[d].dtype)), blocks[d])
            for d in roots
        ),
        tuple(tuple(g.sig for g in wave) for wave in plan_waves),
    )
    return SchedulePlan(roots, datas, blocks_t, plan_waves, tasks, key)


def build_program(
    plan: SchedulePlan,
    backend: str,
    donate: bool,
    out_shardings=None,
):
    """Trace ``plan`` into one jitted fn: (grids, idx_arrays) -> grids'."""
    dtypes = tuple(plan.datas[d].dtype for d in plan.roots_order)

    # copy only the static fields out of each GroupPlan: the closure (and
    # thus the process-global program cache) must not retain the per-task
    # numpy index arrays, which reach the program as a traced argument
    steps = []
    for g in plan.groups():
        fused = g.op.grid_fused_fn(backend)
        if fused is not None and g.write_pos == (fused[1],):
            kind, fn = "fused", fused[0]
        else:
            kind = "gather"
            fn = g.op.batched_leaf_fn(backend)
        steps.append((kind, fn, g.arg_slots, g.write_pos, g.size))

    def program(grids: Tuple[jnp.ndarray, ...], idxs: jnp.ndarray):
        grids = list(grids)
        cur = 0
        for kind, fn, arg_slots, write_pos, size in steps:
            # static-offset slices of the single flat index array (trace
            # order matches SchedulePlan.flat_idxs)
            gidx = []
            for _ in arg_slots:
                gidx.append(idxs[cur : cur + size])
                cur += size
            if kind == "fused":
                wslot = arg_slots[write_pos[0]]
                grids[wslot] = fn(
                    gidx, tuple(grids[s] for s in arg_slots)
                )
            else:
                blocks = [
                    grids[s][ix[:, 0], ix[:, 1]]
                    for s, ix in zip(arg_slots, gidx)
                ]
                outs = fn(*blocks)
                if not isinstance(outs, (tuple, list)):
                    outs = (outs,)
                for out, a in zip(outs, write_pos):
                    s = arg_slots[a]
                    ix = gidx[a]
                    grids[s] = grids[s].at[ix[:, 0], ix[:, 1]].set(
                        out.astype(dtypes[s])
                    )
        return tuple(grids)

    jit_kwargs = {}
    if out_shardings is not None:
        jit_kwargs["out_shardings"] = out_shardings
    return jax.jit(
        program, donate_argnums=(0,) if donate else (), **jit_kwargs
    )
