"""WaveProgram: dependency-exact whole-schedule compiled execution (DESIGN.md §2).

The dispatcher hands the leaf executor a complete level schedule — an
ordered list of waves of independent tasks — plus the exact task DAG behind
it (``versioning.TaskDag``).  At seed every wave group was a separate
Python-dispatched ``jit`` call; PR 1 compiled the whole barrier-wave
schedule into ONE jitted XLA program over grid-resident roots.  This pass
goes further: the barrier between waves is replaced by a **dependency-exact
group schedule**:

    plan   = plan_schedule(waves, dag)  # fusion + issue slots + indices
    fn     = build_program(plan, ...)   # one traced fn, cached on plan.key
    grids' = fn(grids, plan.flat_idxs)  # one dispatch per drain

Scheduling pass (``dag`` present):

1. **Exact issue.**  Initial groups (same signature within one Kahn wave)
   are re-scheduled by their *actual* predecessor groups: a group's issue
   slot is its longest-path depth in the fused-group DAG, not its Kahn wave
   index.  Groups sharing a slot are mutually independent — that is the
   precondition both for fusing them (below) and for ordering them freely
   (lookahead) without consulting the barrier structure.
2. **Cross-wave fusion.**  Two groups fuse into one larger batched launch —
   one bigger vmap batch — iff they have the same signature (operation,
   write positions, per-arg block shapes and dtypes) and NO path connects
   their tasks (``TaskDag.independent``; the planner uses the conservative
   quotient-graph form of the query, which implies it).  Fusion works
   across roots: a fused group carries per-segment argument slots and the
   program concatenates the per-segment gathers, so independent workloads
   (e.g. LU of A and LU of B in one drain) share launches.
3. **Lookahead.**  Within a slot, groups are ordered by critical-path
   height (longest chain of dependent tasks below them), so the next panel
   factorization (GETRF/POTRF) is traced before independent trailing
   updates that happen to share its slot — the order XLA's scheduler sees
   through the donated in-place grids follows the critical path.

Roots stay in ``(nr, nc, br, bc)`` grid-major layout for the duration (the
``GData`` grid-resident epoch).  Block indices are traced arguments, built
ONCE at plan time into a single ``(total, 2)`` device array
(``SchedulePlan.flat_idxs``); drain replay reuses the device-resident array
untouched.  Two drains whose schedules share a structure (slot/group/
segment signatures, shapes, dtypes) hit the same compiled program.

Per single-segment group the compiler can still emit the operation's fused
grid kernel (``Operation.grid_fused_fn`` — Pallas scalar-prefetch gather/
compute/scatter aliased to the written grid).  Group sizes are exact, never
padded — also after fusion: every group is traced inline into one program,
so pow2 bucketing would buy no compile savings, and duplicate trailing
indices are unsound for read-write fused kernels.  (The *batch* axis of a
stacked drain is different: ``build_program(batch=B)`` pads B to a pow2
bucket upstream, because B is a jit shape every program specializes on —
DESIGN.md §7; lanes are whole independent workloads, so padding lanes
never alias real writes.)

Asynchronous dispatch (DESIGN.md §12): the jitted fn a WaveProgram compiles
to RETURNS BEFORE the device finishes — JAX dispatch is async, so calling
``fn(grids, idxs)`` costs host microseconds and the result grids are array
futures.  Nothing in this module (or downstream of it on the drain path)
forces materialization: outputs go straight back into grid-resident
``GData`` epochs, the executor records them as an ``InFlightEpoch``, and
the next drain's planning/tracing/dispatch proceeds while this program
executes.  The contract that makes this safe is donation discipline:
``donate_argnums=(0,)`` means a program CONSUMES its input grids, so the
only party allowed to hand a possibly-in-flight grid to a new program is
the executor's stacked grid-reuse fast path, which proves sole ownership
via the epoch holder count first — XLA then serializes the two programs on
the donated buffer, no host fence required.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...testing import faults
from ..data import GData
from ..task import GTask
from .base import group_wave


@dataclass(frozen=True, eq=False)
class GroupPlan:
    """One fused task group: static signature + per-segment index data.

    ``segments`` carries one ``(arg_slots, size)`` entry per merged source
    group; a group fused across roots has one segment per distinct slot
    tuple.  ``idxs`` holds per-arg ``(total_size, 2)`` int32 block coords,
    rows ordered segment by segment.
    """

    op: object  # Operation
    write_pos: Tuple[int, ...]  # arg positions with write access
    segments: Tuple[Tuple[Tuple[int, ...], int], ...]  # ((slots...), size)
    idxs: Tuple[np.ndarray, ...]  # per-arg (size, 2) int32 block coords
    height: int  # critical-path priority (lookahead ordering)

    @property
    def arg_slots(self) -> Tuple[int, ...]:
        return self.segments[0][0]

    @property
    def size(self) -> int:
        return sum(s for _, s in self.segments)

    @property
    def sig(self) -> tuple:
        return (self.op.name, self.segments, self.write_pos)


@dataclass
class SchedulePlan:
    """A fully analyzed, dependency-exactly scheduled drain."""

    roots_order: Tuple[int, ...]  # data ids, stable by first appearance
    datas: Dict[int, GData]
    blocks: Tuple[Tuple[int, int], ...]  # per-slot leaf block shape (br, bc)
    slots: List[List[GroupPlan]]  # issue slots; groups in a slot independent
    tasks: List[GTask]  # all tasks in slot order
    key: tuple  # structural cache key (no data identity)
    flat_idxs: jnp.ndarray  # ONE (total, 2) int32 array, built at plan time
    n_groups_prefusion: int  # barrier-wave group count (pre-fusion)

    @property
    def n_groups(self) -> int:
        return sum(len(s) for s in self.slots)

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    def groups(self):
        for slot in self.slots:
            yield from slot


class _Fused:
    """Mutable fusion-pass state for one (eventually fused) group."""

    __slots__ = ("op", "write_pos", "compat", "segments", "preds", "task_ids")

    def __init__(self, op, write_pos, compat, arg_slots, tasks, preds):
        self.op = op
        self.write_pos = write_pos
        self.compat = compat
        self.segments: List[Tuple[Tuple[int, ...], List[GTask]]] = [
            (arg_slots, list(tasks))
        ]
        self.preds: Set[int] = set(preds)
        self.task_ids: Set[int] = {t.id for t in tasks}

    def merge(self, arg_slots, tasks, preds) -> None:
        for slots_, members in self.segments:
            if slots_ == arg_slots:
                members.extend(tasks)
                break
        else:
            self.segments.append((arg_slots, list(tasks)))
        self.preds |= preds
        self.task_ids |= {t.id for t in tasks}


def _fuse(
    waves: Sequence[Sequence[GTask]],
    dag,
    slot_of: Dict[int, int],
) -> Tuple[List[List[_Fused]], int]:
    """Dependency-exact scheduling pass: fusion + issue-slot assignment.

    Returns (slots, prefusion_group_count).  Legality (DESIGN.md §2): a
    group may merge into an earlier one iff their signatures match and no
    path connects them.  The pass maintains the *quotient* DAG over fused
    groups and checks the candidate's transitive quotient ancestors — a
    quotient path implies a task path would be ordered through a third
    launch, so quotient-ancestor-freedom implies ``TaskDag.independent``
    and additionally keeps the fused-group DAG acyclic (schedulable) under
    repeated merging, which pairwise task-level independence alone would
    not guarantee.
    """
    fused: List[_Fused] = []
    owner: Dict[int, int] = {}  # task id -> fused group index
    wave_of: List[int] = []  # fused index -> source wave (dag-less fallback)
    prefusion = 0
    for wi, wave in enumerate(waves):
        for _, tasks in group_wave(wave).items():
            prefusion += 1
            rep = tasks[0]
            arg_slots = tuple(slot_of[v.data.id] for v in rep.args)
            write_pos = tuple(i for i, m in enumerate(rep.modes) if m.writes)
            compat = (
                rep.op.name,
                write_pos,
                tuple(v.region.shape for v in rep.args),
                tuple(str(jnp.dtype(v.data.dtype)) for v in rep.args),
            )
            dpreds: Set[int] = set()
            target = None
            if dag is not None:
                for t in tasks:
                    for p in dag.preds.get(t.id, ()):
                        dpreds.add(owner[p])
                # transitive ancestors in the current quotient DAG
                anc: Set[int] = set()
                stack = list(dpreds)
                while stack:
                    f = stack.pop()
                    if f not in anc:
                        anc.add(f)
                        stack.extend(fused[f].preds - anc)
                for fi, f in enumerate(fused):
                    if f.compat == compat and fi not in anc:
                        target = fi
                        break
            if target is None:
                target = len(fused)
                fused.append(
                    _Fused(rep.op, write_pos, compat, arg_slots, tasks, dpreds)
                )
                wave_of.append(wi)
            else:
                fused[target].merge(arg_slots, tasks, dpreds)
            for t in tasks:
                owner[t.id] = target

    if dag is None:
        # no DAG: keep the barrier-wave structure (slot = Kahn wave)
        depth = {i: w for i, w in enumerate(wave_of)}
    else:
        # issue slot = longest-path depth in the (acyclic) fused-group DAG
        depth = {}
        for i in range(len(fused)):
            stack = [i]
            while stack:
                g = stack[-1]
                if g in depth:
                    stack.pop()
                    continue
                missing = [p for p in fused[g].preds if p not in depth]
                if missing:
                    stack.extend(missing)
                    continue
                depth[g] = (
                    1 + max(depth[p] for p in fused[g].preds)
                    if fused[g].preds
                    else 0
                )
                stack.pop()
    n_slots = 1 + max(depth.values()) if depth else 0
    slots: List[List[_Fused]] = [[] for _ in range(n_slots)]
    for i, f in enumerate(fused):
        slots[depth[i]].append(f)
    return slots, prefusion


def _mutate_merge_dependent_groups(slots: List[List[_Fused]]) -> bool:
    """``plan.merge_groups`` fault site (DESIGN.md §11): force-merge the
    first same-signature group pair sitting in DIFFERENT issue slots.

    Such a pair is dependent by construction — the legal fusion pass has
    already merged every same-signature INDEPENDENT pair — so the merge
    produces exactly the corrupted shape ``verify_plan`` must reject: one
    launch containing path-connected tasks (V1), usually with overlapping
    write blocks as well (V3/V4).  Mutating after slotting (not inside
    ``_fuse``) keeps the quotient DAG acyclic, so planning itself cannot
    hang — the bug ships silently unless the verifier catches it.
    """
    flat = [
        (si, f) for si, groups in enumerate(slots) for f in groups
    ]
    for i, (si, f1) in enumerate(flat):
        for sj, f2 in flat[i + 1 :]:
            if sj > si and f1.compat == f2.compat and faults.fires(
                "plan.merge_groups", op=f1.op.name, slots=(si, sj)
            ):
                for slots_, ts in f2.segments:
                    f1.merge(slots_, ts, f2.preds)
                slots[sj].remove(f2)
                return True
    return False


def plan_schedule(
    waves: Sequence[Sequence[GTask]], dag=None
) -> Optional[SchedulePlan]:
    """Analyze a level schedule for whole-program compilation.

    ``dag`` is the scope's ``versioning.TaskDag``; when given, the
    dependency-exact pass fuses same-signature groups across former wave
    boundaries and re-slots groups by actual predecessors.  Without it the
    barrier-wave structure is kept (one slot per wave).

    Returns None (caller falls back to per-wave launches) when the schedule
    is not grid-uniform: some root lacks a value, or a task's region is not
    one aligned block of that root's uniform leaf grid.
    """
    roots_order: List[int] = []
    datas: Dict[int, GData] = {}
    blocks: Dict[int, Tuple[int, int]] = {}
    for wave in waves:
        for t in wave:
            for v in t.args:
                d = v.data
                if d.id not in datas:
                    if not d.has_value:
                        return None
                    roots_order.append(d.id)
                    datas[d.id] = d
                    blocks[d.id] = v.region.shape
                br, bc = blocks[d.id]
                r = v.region
                if (
                    r.shape != (br, bc)
                    or r.r0 % br
                    or r.c0 % bc
                    or d.shape[0] % br
                    or d.shape[1] % bc
                ):
                    return None
    if not any(waves):
        return None
    slot_of = {d: i for i, d in enumerate(roots_order)}

    heights = dag.heights() if dag is not None else {}
    fused_slots, prefusion = _fuse(waves, dag, slot_of)
    if faults.active():
        _mutate_merge_dependent_groups(fused_slots)

    plan_slots: List[List[GroupPlan]] = []
    tasks: List[GTask] = []
    for slot in fused_slots:
        groups: List[GroupPlan] = []
        for f in slot:
            members = [t for _, ts in f.segments for t in ts]
            n_args = len(f.segments[0][0])
            idxs = tuple(
                np.array(
                    [t.args[a].block_index() for t in members], dtype=np.int32
                )
                for a in range(n_args)
            )
            segments = tuple((slots_, len(ts)) for slots_, ts in f.segments)
            height = max((heights.get(t.id, 0) for t in members), default=0)
            groups.append(
                GroupPlan(f.op, f.write_pos, segments, idxs, height)
            )
        # lookahead: critical-path-first trace order within the slot
        order = sorted(range(len(groups)), key=lambda i: (-groups[i].height, i))
        groups = [groups[i] for i in order]
        slot = [slot[i] for i in order]
        plan_slots.append(groups)
        for f in slot:
            for _, ts in f.segments:
                tasks.extend(ts)

    roots = tuple(roots_order)
    blocks_t = tuple(blocks[d] for d in roots)
    key = (
        tuple(
            (datas[d].shape, str(jnp.dtype(datas[d].dtype)), blocks[d])
            for d in roots
        ),
        tuple(tuple(g.sig for g in slot) for slot in plan_slots),
    )
    parts = [ix for slot in plan_slots for g in slot for ix in g.idxs]
    flat = jnp.asarray(np.concatenate(parts, axis=0))
    return SchedulePlan(
        roots, datas, blocks_t, plan_slots, tasks, key, flat, prefusion
    )


def build_program(
    plan: SchedulePlan,
    backend: str,
    donate: bool,
    out_shardings=None,
    batch: Optional[int] = None,
):
    """Trace ``plan`` into one jitted fn: (grids, idx_array) -> grids'.

    With ``batch=B`` the SAME plan is traced in stacked form (DESIGN.md §7):
    every root grid carries a leading batch dimension ``(B, nr, nc, br, bc)``
    holding B structurally identical workloads, gathers pull ``(B, size)``
    blocks per group and flatten the two batch axes into one stack for the
    operation's batched leaf (so leaves need no batch awareness beyond the
    existing stacked-tiles convention), and the Pallas fused grid kernels
    run with a leading batch grid dimension.  The block-index array is the
    per-lane one, shared by all lanes — launch count and index traffic stay
    flat in B.

    Groups are traced slot by slot in lookahead order.  Per group: the
    operation's fused grid kernel (single-segment groups only) or gather ->
    batched leaf -> scatter, with multi-segment groups concatenating the
    per-segment gathers and splitting the scatters across their roots.
    Data movement stays per-group: coalescing all of a slot's scatters into
    one big scatter per root was measured as a CPU pessimization (the
    cross-op output concatenation blocks XLA fusion and the larger scatter
    is not cheaper), so slots drive *scheduling* (fusion legality, exact
    issue, lookahead order), not movement batching.

    A group's reads are legal against the current grids even mid-slot: any
    block a group reads and a slot-mate writes would be a RAW/WAR edge,
    and edges force different slots.

    The returned fn dispatches asynchronously (module docstring /
    DESIGN.md §12): callers must treat its outputs as in-flight until a
    fence of their choosing, and must not re-donate an input grid they do
    not solely own.
    """
    dtypes = tuple(plan.datas[d].dtype for d in plan.roots_order)

    # copy only the static fields out of each GroupPlan: the closure (and
    # thus the process-global program cache) must not retain the per-task
    # numpy index arrays, which reach the program as a traced argument
    steps = []
    base = 0
    for g in plan.groups():
        faults.fire("leaf.fn", op=g.op.name, backend=backend)
        fused = g.op.grid_fused_fn(backend)
        if (
            fused is not None
            and len(g.segments) == 1
            and g.write_pos == (fused[1],)
        ):
            kind, fn = "fused", fused[0]
        else:
            kind = "gather"
            fn = g.op.batched_leaf_fn(backend)
        steps.append((kind, fn, g.segments, g.write_pos, g.size, base))
        base += len(g.arg_slots) * g.size

    def program(grids: Tuple[jnp.ndarray, ...], idxs: jnp.ndarray):
        grids = list(grids)
        for kind, fn, segments, write_pos, size, b0 in steps:
            # static-offset slices of the single flat index array (trace
            # order matches SchedulePlan.flat_idxs)
            n_args = len(segments[0][0])
            gidx = [
                idxs[b0 + a * size : b0 + (a + 1) * size]
                for a in range(n_args)
            ]
            if kind == "fused":
                slots_ = segments[0][0]
                wslot = slots_[write_pos[0]]
                grids[wslot] = fn(gidx, tuple(grids[s] for s in slots_))
                continue
            blocks = []
            for a in range(n_args):
                chunks = []
                off = 0
                for slots_, ssize in segments:
                    ix = gidx[a][off : off + ssize]
                    g = grids[slots_[a]]
                    if batch is None:
                        chunks.append(g[ix[:, 0], ix[:, 1]])
                    else:
                        chunks.append(g[:, ix[:, 0], ix[:, 1]])
                    off += ssize
                stack = (
                    chunks[0]
                    if len(chunks) == 1
                    else jnp.concatenate(chunks, axis=0 if batch is None else 1)
                )
                if batch is not None:
                    # flatten (B, group) into one leaf stack: the batched
                    # leaf is elementwise over the stack, so lane order only
                    # has to match the un-flatten below
                    stack = stack.reshape((batch * size,) + stack.shape[2:])
                blocks.append(stack)
            outs = fn(*blocks)
            if not isinstance(outs, (tuple, list)):
                outs = (outs,)
            for out, a in zip(outs, write_pos):
                if batch is not None:
                    out = out.reshape((batch, size) + out.shape[1:])
                off = 0
                for slots_, ssize in segments:
                    r = slots_[a]
                    ix = gidx[a][off : off + ssize]
                    if batch is None:
                        part = (
                            out
                            if len(segments) == 1
                            else out[off : off + ssize]
                        )
                        grids[r] = grids[r].at[ix[:, 0], ix[:, 1]].set(
                            part.astype(dtypes[r])
                        )
                    else:
                        part = (
                            out
                            if len(segments) == 1
                            else out[:, off : off + ssize]
                        )
                        grids[r] = grids[r].at[:, ix[:, 0], ix[:, 1]].set(
                            part.astype(dtypes[r])
                        )
                    off += ssize
        return tuple(grids)

    jit_kwargs = {}
    if out_shardings is not None:
        jit_kwargs["out_shardings"] = out_shardings
    return jax.jit(
        program, donate_argnums=(0,) if donate else (), **jit_kwargs
    )
