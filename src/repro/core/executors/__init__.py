from .base import Executor, group_wave
from .inline import InlineExecutor
from .jit_wave import JitWaveExecutor, PallasExecutor
from .sharded import ShardExecutor, row_sharding

__all__ = [
    "Executor",
    "InlineExecutor",
    "JitWaveExecutor",
    "PallasExecutor",
    "ShardExecutor",
    "group_wave",
    "row_sharding",
]
