from .base import Executor, group_wave
from .inline import InlineExecutor
from .jit_wave import (
    JitWaveExecutor,
    PallasExecutor,
    clear_compile_cache,
    drain_memo_pressure,
    drain_memo_stats,
    set_drain_memo_capacity,
)
from .sharded import ShardExecutor, row_sharding
from .wave_program import SchedulePlan, build_program, plan_schedule

__all__ = [
    "Executor",
    "InlineExecutor",
    "JitWaveExecutor",
    "PallasExecutor",
    "SchedulePlan",
    "ShardExecutor",
    "build_program",
    "clear_compile_cache",
    "drain_memo_pressure",
    "drain_memo_stats",
    "group_wave",
    "plan_schedule",
    "row_sharding",
    "set_drain_memo_capacity",
]
