"""Wave-batched jitted executor — the SuperGlue wrapper analog, TPU-native.

SuperGlue runs ready tasks on multicore threads; the TPU-idiomatic
equivalent batches every wave of independent same-signature tasks into ONE
vmapped + jitted launch so the MXU sees a single large batched kernel
instead of many tiny ones (DESIGN.md §2).

Primary path (``execute_schedule``): the dispatcher's whole leaf schedule
plus its exact task DAG is compiled into a single XLA program over
grid-resident roots by the WaveProgram compiler — dependency-exact issue
slots, same-signature groups fused across former wave boundaries (also
across roots), one Python dispatch per drain; roots stay in
``(nr, nc, br, bc)`` layout for the epoch, and repeated drains with the
same schedule structure reuse one compiled program.

Stacked path (``execute_stacked``, DESIGN.md §7): a homogeneous root
stream runs ONE batched program over ``(B, nr, nc, br, bc)`` stacked grids
with B padded to a pow2 bucket — compiled programs and the drain memo key
depend on the bucket, never on the exact request count, and results hand
back as lazily extracted lanes of a shared ``StackedEpoch``.

Fallback path (``execute_wave``/``_run_group``): per-wave-group jitted
launches with the grid-reshape gather/scatter, used when the schedule is
not grid-uniform (mixed block shapes or unaligned regions on one root).
The jitted group function is cached on the static signature (op, backend,
root/block shapes & dtypes); block *indices* are traced arguments, so every
wave of the same kind reuses the compiled program.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...analysis.verify import verify_plan
from ...testing import faults
from ..data import GData, StackedEpoch, from_grid, to_grid
from ..task import GTask, TaskState
from ..versioning import InFlightEpoch
from .base import Executor, group_wave
from .wave_program import SchedulePlan, build_program, plan_schedule

# process-global compiled-program cache: keys are purely structural (op
# names, backend, shapes, dtypes, shardings, schedule structure) so every
# Dispatcher instance reuses the same compiled programs — dispatcher
# creation must stay O(tasks), not O(compiles) (paper §3 overhead-parity
# claim).  Holds both per-group functions ("group", ...) and whole-schedule
# WavePrograms ("waveprog", ...).
_GROUP_FN_CACHE: Dict[tuple, callable] = {}

class DrainMemo:
    """Bounded LRU drain memo with hit/miss/eviction counters (DESIGN.md §2).

    Structural root-task-stream key -> the captured sequence of compiled
    program executions for a whole dispatcher drain, so a structurally
    repeated drain skips Python re-splitting/re-versioning and replays the
    programs directly.  A long-running server sees an unbounded stream of
    distinct request signatures, so the memo must not grow without bound:
    entries evict least-recently-used past ``capacity`` (an evicted drain is
    simply re-captured on its next occurrence — correctness is unaffected).
    Counters feed ``Dispatcher.stats`` and the serving tick reports.
    """

    def __init__(self, capacity: int = 256):
        self.capacity = capacity
        self._entries: "OrderedDict[tuple, object]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        self.pressure_sheds = 0

    def get(self, key: tuple):
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return entry

    def __setitem__(self, key: tuple, entry: object) -> None:
        self._entries[key] = entry
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    def set_capacity(self, capacity: int) -> None:
        if capacity < 1:
            raise ValueError(f"drain memo capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    def discard(self, key: tuple) -> None:
        """Drop one entry (no-op if absent) — the in-flight failure
        hardening hook (DESIGN.md §12): a drain whose program FAILED after
        dispatch may have captured/refreshed an entry this drain can no
        longer vouch for, so the dispatcher's ``DrainHandle`` invalidates
        exactly the keys it stored.  Counted as an invalidation (the entry
        is simply re-captured on the next healthy occurrence)."""
        if key in self._entries:
            del self._entries[key]
            self.invalidations += 1

    def shed(self, fraction: float = 0.5) -> int:
        """Evict the least-recently-used ``fraction`` of entries; returns
        the count shed.  The memory-pressure hook (DESIGN.md §14): a device
        OOM means resident state must shrink NOW, and memo entries pin
        device-side index arrays plus compiled-program references — the LRU
        tail is exactly the state least likely to be replayed soon.
        Correctness is unaffected (a shed drain re-captures on its next
        occurrence); counted under ``pressure_sheds``."""
        if not 0.0 < fraction <= 1.0:
            raise ValueError(f"shed fraction must be in (0, 1], got {fraction}")
        n = min(len(self._entries), max(1, int(len(self._entries) * fraction))) \
            if self._entries else 0
        for _ in range(n):
            self._entries.popitem(last=False)
        self.pressure_sheds += n
        return n

    def stats(self) -> Dict[str, int]:
        return {
            "entries": len(self._entries),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
            "pressure_sheds": self.pressure_sheds,
        }

    # dict-compatible surface (tests introspect the memo directly)
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        return key in self._entries

    def values(self):
        return self._entries.values()

    def keys(self):
        return self._entries.keys()

    def clear(self) -> None:
        self._entries.clear()


# owned here (not in dispatcher.py) so one clear call drops every compiled
# artifact; counters are process-global like the compiled-program cache
_DRAIN_MEMO = DrainMemo()


def set_drain_memo_capacity(capacity: int) -> None:
    """Configure the LRU bound of the process-global drain memo."""
    _DRAIN_MEMO.set_capacity(capacity)


def drain_memo_stats() -> Dict[str, int]:
    """Entries/capacity/hits/misses/evictions of the global drain memo."""
    return _DRAIN_MEMO.stats()


def drain_memo_pressure(fraction: float = 0.5) -> int:
    """Shed the LRU ``fraction`` of the global drain memo (DESIGN.md §14).

    The memory-pressure callback: called by the serving layer on a device
    OOM (and available to any embedder's allocator hooks) so resident
    compiled-program state shrinks alongside the batch-cap degradation.
    Returns the number of entries shed."""
    return _DRAIN_MEMO.shed(fraction)


def clear_compile_cache() -> None:
    """Drop all cached compiled group fns / WavePrograms / drain memos."""
    _GROUP_FN_CACHE.clear()
    _DRAIN_MEMO.clear()


@dataclass(frozen=True)
class ProgramRecord:
    """One compiled-program execution inside a captured drain.

    ``root_slots`` index into the drain's root-argument data order; the
    dispatcher resolves them to fresh ``GData`` objects on replay.
    ``idxs`` is the plan's device-resident flat index array — replay reuses
    it as-is, no host concatenation or transfer.  ``batch`` is the stacked
    pow2 bucket for batched drains (DESIGN.md §7): replay then resolves each
    slot to the LIST of member data handles to restack."""

    fn: object  # the jitted WaveProgram
    root_slots: Tuple[int, ...]
    blocks: Tuple[Tuple[int, int], ...]  # per-root leaf block shape
    idxs: jnp.ndarray  # flat (total, 2) int32 block indices (device)
    n_tasks: int
    n_groups: int = 0  # fused launch count inside the program
    n_groups_prefusion: int = 0  # barrier-wave group count before fusion
    n_slots: int = 0  # dependency-exact issue slots
    batch: Optional[int] = None  # stacked bucket size (None = unstacked)


class JitWaveExecutor(Executor):
    name = "jit_wave"

    def __init__(self, backend: str = "jnp", donate: bool = True, **kw):
        super().__init__(**kw)
        self.backend = backend
        self.donate = donate
        self._fn_cache = _GROUP_FN_CACHE
        # optional: data_id -> jax.sharding.Sharding (set by ShardExecutor)
        self._shardings: Dict[int, object] = {}
        # drain-capture state (dispatcher memo protocol)
        self._capture: Optional[List[ProgramRecord]] = None
        self._capture_ids: Dict[int, int] = {}
        self._capture_ok = True
        # in-flight epoch handles, one per launch since the last take
        # (DESIGN.md §12); launches are asynchronous, so nothing here blocks
        self.inflight: List[InFlightEpoch] = []

    # -- async launch tracking (DESIGN.md §12) ---------------------------------
    def _note_launch(self, outs, label: str) -> None:
        """Record a dispatched program's outputs as an in-flight epoch.

        Launch order is preserved — the donation handshake relies on it
        (a donated grid's completion is covered by a LATER epoch in the
        list).  Already-materialized epochs are pruned opportunistically so
        a dispatcher reused across many drains without ``take_inflight``
        (e.g. ``run_lu`` one-shots) cannot accumulate handles."""
        if len(self.inflight) >= 8:
            self.inflight = [e for e in self.inflight if not e.is_ready()]
        self.inflight.append(InFlightEpoch(outs, label))

    def take_inflight(self) -> List[InFlightEpoch]:
        eps, self.inflight = self.inflight, []
        return eps

    def sync(self) -> float:
        """Fence all outstanding launches; accumulates the blocked host
        seconds into ``stats['host_block_us']``."""
        blocked = super().sync()
        self.stats["host_block_us"] += int(blocked * 1e6)
        return blocked

    # -- drain capture/replay protocol (DESIGN.md §2) --------------------------
    def memo_key_extra(self) -> tuple:
        """Executor-identity part of the dispatcher's drain-memo key."""
        return (self.name, self.backend, self.donate)

    def begin_capture(self, root_slot_of: Dict[int, int]) -> None:
        """Start recording program executions; ``root_slot_of`` maps the
        drain's root-argument data ids to stable slots."""
        self._capture = []
        self._capture_ids = dict(root_slot_of)
        self._capture_ok = True

    def end_capture(self):
        """Stop recording; returns (records, ok).  ``ok`` is False when any
        leaf work bypassed the WaveProgram path (legacy fallback) or touched
        a datum that is not a root argument — such drains are not memoized."""
        records, ok = self._capture, self._capture_ok
        self._capture = None
        self._capture_ids = {}
        return records or [], ok and bool(records)

    def replay_program(self, rec: ProgramRecord, datas: List) -> int:
        """Re-execute a captured program against fresh data handles.

        For a stacked record (``rec.batch``) each entry of ``datas`` is the
        LIST of member handles for that root slot; they are restacked (with
        pow2 padding) and the per-lane results handed back as lanes of a
        shared ``StackedEpoch`` (DESIGN.md §7)."""
        faults.fire(
            "executor.launch", batch=rec.batch, n_tasks=rec.n_tasks,
            replay=True,
        )
        faults.fire(
            "launch.oom", batch=rec.batch, n_tasks=rec.n_tasks, replay=True,
        )
        if rec.batch is not None:
            grids = self._stack_grids(datas, rec.blocks, rec.batch)
            outs = rec.fn(grids, rec.idxs)
            outs = faults.corrupt(
                "executor.output", outs, batch=rec.batch, replay=True
            )
            self._note_launch(outs, f"replay:stacked{rec.batch}")
            self._adopt_stacked(datas, outs, rec.blocks)
        else:
            grids, _ = self._enter_grids(datas, rec.blocks)
            outs = rec.fn(grids, rec.idxs)
            outs = faults.corrupt(
                "executor.output", outs, batch=None, replay=True
            )
            self._note_launch(outs, "replay")
            for data, g in zip(datas, outs):
                data.set_grid(g)
        self.stats["tasks"] += rec.n_tasks
        self.stats["launches"] += 1
        self.stats["groups"] += rec.n_groups
        self.stats["groups_prefusion"] += rec.n_groups_prefusion
        self.stats["slots"] += rec.n_slots
        return rec.n_tasks

    # -- whole-schedule compiled path (DESIGN.md §2) ---------------------------
    def execute_schedule(self, waves: List[List[GTask]], dag=None) -> int:
        """Dependency-exact compiled execution of a whole leaf schedule."""
        waves = [w for w in waves if w]
        if not waves:
            return 0
        self._prepare_roots(waves)
        plan = plan_schedule(waves, dag)
        if plan is None:
            self._capture_ok = False
            n = 0
            for wave in waves:
                n += self.execute_wave(wave)
            return n
        if self.verify and dag is not None:
            # prove the plan before launching it (DESIGN.md §11); verdicts
            # cache on (structural key, index digest) so a structurally
            # repeated drain pays one dict probe here
            verify_plan(plan, dag)
            self.stats["verified_plans"] += 1
        return self._run_program(plan)

    def execute_waves(self, waves: List[List[GTask]]) -> int:
        return self.execute_schedule(waves)

    # -- stacked (batched) drain path (DESIGN.md §7) ---------------------------
    def execute_stacked(
        self,
        schedules: List[tuple],
        members: Dict[int, List[GData]],
        bucket: int,
    ) -> Optional[int]:
        """Run a homogeneous-root drain as ONE batched program per schedule.

        ``schedules`` is the TEMPLATE root's list of leaf ``(waves, dag)``
        schedules; ``members`` maps each template root-argument data id to
        the per-request member handles (template first).  Every schedule is
        planned up front: if ANY falls off the whole-program path (non-
        grid-uniform), returns None WITHOUT executing anything, so the
        caller can fall back to segment fusion with no partial state.
        """
        plans = []
        for waves, dag in schedules:
            waves = [w for w in waves if w]
            if not waves:
                continue
            plan = plan_schedule(waves, dag)
            if plan is None or any(
                d not in members for d in plan.roots_order
            ):
                return None
            if self.verify and dag is not None:
                # all template plans are proven up front, before ANY lane
                # executes — a verification failure aborts with no partial
                # state, same contract as the planning fall-off above
                verify_plan(plan, dag)
                self.stats["verified_plans"] += 1
            plans.append(plan)
        n = 0
        for plan in plans:
            n += self._run_program(plan, stack=(members, bucket))
        return n

    def _stack_grids(
        self,
        member_lists: Sequence[List[GData]],
        blocks: Sequence[Tuple[int, int]],
        bucket: int,
    ) -> Tuple[jnp.ndarray, ...]:
        """Per root slot, stack the members' resident grids into one
        ``(bucket, nr, nc, br, bc)`` array, padding the batch by repeating
        the last member (lanes are independent, so padding lanes compute
        junk that is never read back).

        Repeat-tick fast path: when the members are exactly lanes 0..N-1 of
        one prior StackedEpoch with the same block and bucket — and they
        are that epoch's ONLY live holders, so donating its grid into the
        next program cannot invalidate a bystander lane — the grid is
        reused as-is: zero per-request data movement between ticks."""
        out: List[jnp.ndarray] = []
        for members, (br, bc) in zip(member_lists, blocks):
            first = members[0].lane
            if (
                first is not None
                and first[0].block == (br, bc)
                and first[0].batch == bucket
                and first[0].holders == len(members)
                and all(
                    m.lane is not None
                    and m.lane[0] is first[0]
                    and m.lane[1] == i
                    for i, m in enumerate(members)
                )
            ):
                out.append(first[0].grid)
                continue
            gs = [m.enter_grid(br, bc) for m in members]
            gs = gs + [gs[-1]] * (bucket - len(gs))
            out.append(jnp.stack(gs))
        return tuple(out)

    @staticmethod
    def _adopt_stacked(member_lists, outs, blocks) -> None:
        """Hand each member its lane of the stacked result grids."""
        for members, g, (br, bc) in zip(member_lists, outs, blocks):
            epoch = StackedEpoch(g, (br, bc))
            for i, m in enumerate(members):
                m.adopt_lane(epoch, i)

    def _prepare_roots(self, waves: Sequence[Sequence[GTask]]) -> None:
        """Hook: place/distribute roots before planning (ShardExecutor)."""

    def _grid_sharding(self, data: GData, br: int, bc: int):
        """Sharding for ``data``'s resident (nr, nc, br, bc) grid, or None."""
        return None

    def _enter_grids(self, datas: Sequence[GData], blocks):
        """Enter grid epochs (resident re-entry is free) and apply grid
        shardings; returns (grids, shardings)."""
        grids: List[jnp.ndarray] = []
        shardings: List[object] = []
        for data, (br, bc) in zip(datas, blocks):
            g = data.enter_grid(br, bc)
            sh = self._grid_sharding(data, br, bc)
            if sh is not None and getattr(g, "sharding", None) != sh:
                g = jax.device_put(g, sh)
                data.set_grid(g)
            grids.append(g)
            shardings.append(sh)
        return tuple(grids), tuple(shardings)

    def _run_program(self, plan: SchedulePlan, stack=None) -> int:
        """Compile-or-fetch and run one planned program.  With ``stack =
        (members, bucket)`` the plan is traced in stacked form over
        ``(bucket, nr, nc, br, bc)`` grids (DESIGN.md §7): the compiled
        program and its cache key depend on the pow2 bucket, never on the
        exact request count."""
        datas = [plan.datas[d] for d in plan.roots_order]
        batch = None
        if stack is not None:
            members, batch = stack
            member_lists = [members[d] for d in plan.roots_order]
            grids = self._stack_grids(member_lists, plan.blocks, batch)
            shardings = tuple(None for _ in datas)
        else:
            grids, shardings = self._enter_grids(datas, plan.blocks)
        out_shardings = (
            shardings if all(s is not None for s in shardings) else None
        )
        key = (
            "waveprog",
            batch,
            self.memo_key_extra(),
            tuple(str(s) for s in shardings),
        ) + plan.key
        fn = self._fn_cache.get(key)
        if fn is None:
            fn = build_program(
                plan, self.backend, self.donate, out_shardings, batch=batch
            )
            self._fn_cache[key] = fn
            self.stats["compiles"] += 1
        idxs = plan.flat_idxs  # built once at plan time, device-resident
        faults.fire(
            "executor.launch", batch=batch, n_tasks=len(plan.tasks),
            replay=False,
        )
        faults.fire(
            "launch.oom", batch=batch, n_tasks=len(plan.tasks), replay=False,
        )
        outs = fn(grids, idxs)
        outs = faults.corrupt(
            "executor.output", outs, batch=batch, replay=False
        )
        self._note_launch(
            outs, f"stacked{batch}" if batch is not None else "program"
        )
        if stack is not None:
            self._adopt_stacked(member_lists, outs, plan.blocks)
        else:
            for data, g in zip(datas, outs):
                data.set_grid(g)
        if self._capture is not None:
            slots = tuple(self._capture_ids.get(d, -1) for d in plan.roots_order)
            if -1 in slots:
                self._capture_ok = False  # touches a non-root-arg datum
            else:
                faults.fire("memo.capture", batch=batch)
                self._capture.append(
                    ProgramRecord(
                        fn,
                        slots,
                        plan.blocks,
                        idxs,
                        len(plan.tasks),
                        plan.n_groups,
                        plan.n_groups_prefusion,
                        plan.n_slots,
                        batch,
                    )
                )
        for t in plan.tasks:
            t.state = TaskState.FINISHED
            self.stats["tasks"] += 1
            self._finished(t)
        self.stats["launches"] += 1
        self.stats["groups"] += plan.n_groups
        self.stats["groups_prefusion"] += plan.n_groups_prefusion
        self.stats["slots"] += plan.n_slots
        return len(plan.tasks)

    # -- per-group fallback path -----------------------------------------------
    def _build_group_fn(
        self,
        op,
        slots: Tuple[int, ...],
        block_shapes: Tuple[Tuple[int, int], ...],
        root_shapes: Tuple[Tuple[int, int], ...],
        root_dtypes: Tuple,
        write_pos: Tuple[int, ...],
        out_shardings,
    ):
        backend = self.backend
        batched = op.batched_leaf_fn(backend)

        def fn(roots: Tuple[jnp.ndarray, ...], idxs: Tuple[jnp.ndarray, ...]):
            roots = list(roots)
            blocks = []
            for a, slot in enumerate(slots):
                br, bc = block_shapes[a]
                g = to_grid(roots[slot], br, bc)
                blocks.append(g[idxs[a][:, 0], idxs[a][:, 1]])
            outs = batched(*blocks)
            if not isinstance(outs, (tuple, list)):
                outs = (outs,)
            for out, a in zip(outs, write_pos):
                slot = slots[a]
                br, bc = block_shapes[a]
                g = to_grid(roots[slot], br, bc)
                g = g.at[idxs[a][:, 0], idxs[a][:, 1]].set(
                    out.astype(root_dtypes[slot])
                )
                roots[slot] = from_grid(g)
            return tuple(roots)

        jit_kwargs = {}
        if out_shardings is not None:
            jit_kwargs["out_shardings"] = out_shardings
        return jax.jit(fn, donate_argnums=(0,) if self.donate else (), **jit_kwargs)

    def _group_fn(self, op, rep: GTask, roots_order: Tuple[int, ...]):
        slot_of = {d: i for i, d in enumerate(roots_order)}
        slots = tuple(slot_of[v.data.id] for v in rep.args)
        block_shapes = tuple(v.region.shape for v in rep.args)
        roots = {v.data.id: v.data for v in rep.args}
        root_shapes = tuple(roots[d].shape for d in roots_order)
        root_dtypes = tuple(roots[d].dtype for d in roots_order)
        write_pos = tuple(i for i, m in enumerate(rep.modes) if m.writes)
        shardings = tuple(self._shardings.get(d) for d in roots_order)
        out_shardings = shardings if any(s is not None for s in shardings) else None
        key = (
            "group",
            op.name,
            self.backend,
            self.donate,
            slots,
            block_shapes,
            root_shapes,
            root_dtypes,
            write_pos,
            tuple(str(s) for s in shardings),
        )
        if key not in self._fn_cache:
            self._fn_cache[key] = self._build_group_fn(
                op,
                slots,
                block_shapes,
                root_shapes,
                root_dtypes,
                write_pos,
                out_shardings,
            )
            self.stats["compiles"] += 1
        return self._fn_cache[key]

    def execute_wave(self, wave: List[GTask]) -> int:
        for key, tasks in group_wave(wave).items():
            self._run_group(tasks)
        return len(wave)

    def _run_group(self, tasks: List[GTask]) -> None:
        rep = tasks[0]
        op = rep.op
        # stable unique root order
        roots_order: List[int] = []
        for v in rep.args:
            if v.data.id not in roots_order:
                roots_order.append(v.data.id)
        roots_order = tuple(roots_order)
        data_of = {v.data.id: v.data for t in tasks for v in t.args}
        fn = self._group_fn(op, rep, roots_order)
        # pad the batch to a power-of-two bucket so retraces are O(log n)
        # across wave sizes; padding repeats the last task, whose duplicate
        # scatter writes the identical value (idempotent: the gather of the
        # whole batch happens before any scatter in the traced fn).
        n = len(tasks)
        bucket = 1
        while bucket < n:
            bucket *= 2
        pad = [tasks[-1]] * (bucket - n)
        batch = tasks + pad
        idxs = tuple(
            jnp.asarray(
                np.array([t.args[a].block_index() for t in batch], dtype=np.int32)
            )
            for a in range(len(rep.args))
        )
        roots_in = tuple(data_of[d].value for d in roots_order)
        roots_out = fn(roots_in, idxs)
        self._note_launch(roots_out, "group")
        for d, arr in zip(roots_order, roots_out):
            data_of[d].value = arr
        for t in tasks:
            t.state = TaskState.FINISHED
            self.stats["tasks"] += 1
            self._finished(t)
        self.stats["launches"] += 1


class PallasExecutor(JitWaveExecutor):
    """cuBLAS wrapper analog: identical wave batching, Pallas tile kernels as
    leaves.  Under the WaveProgram path its groups lower to the fused
    scalar-prefetch grid kernels (gather/compute/scatter in one kernel, no
    gathered tile stacks in HBM); interpret=True on CPU, compiled on TPUs."""

    name = "pallas"

    def __init__(self, **kw):
        kw.setdefault("backend", "pallas")
        super().__init__(**kw)
