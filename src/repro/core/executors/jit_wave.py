"""Wave-batched jitted executor — the SuperGlue wrapper analog, TPU-native.

SuperGlue runs ready tasks on multicore threads; the TPU-idiomatic
equivalent batches every wave of independent same-signature tasks into ONE
vmapped + jitted launch so the MXU sees a single large batched kernel
instead of many tiny ones (DESIGN.md §2).  Block gather/scatter uses the
grid-reshape trick — ``(N,N) -> (nb, nb, b, b)`` fancy indexing — which XLA
fuses into the launch.

The jitted group function is cached on the static signature (op, backend,
root/block shapes & dtypes); block *indices* are traced arguments, so every
wave of the same kind reuses the compiled program.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..task import GTask, TaskState
from .base import Executor, group_wave


def _to_grid(a: jnp.ndarray, br: int, bc: int) -> jnp.ndarray:
    r, c = a.shape
    return a.reshape(r // br, br, c // bc, bc).transpose(0, 2, 1, 3)


def _from_grid(a4: jnp.ndarray) -> jnp.ndarray:
    nr, nc, br, bc = a4.shape
    return a4.transpose(0, 2, 1, 3).reshape(nr * br, nc * bc)


# process-global compiled-group cache: keys are purely structural (op name,
# backend, shapes, dtypes, shardings) so every Dispatcher instance reuses the
# same compiled programs — dispatcher creation must stay O(tasks), not
# O(compiles) (paper §3 overhead-parity claim).
_GROUP_FN_CACHE: Dict[tuple, callable] = {}


class JitWaveExecutor(Executor):
    name = "jit_wave"

    def __init__(self, backend: str = "jnp", donate: bool = True, **kw):
        super().__init__(**kw)
        self.backend = backend
        self.donate = donate
        self._fn_cache = _GROUP_FN_CACHE
        # optional: data_id -> jax.sharding.Sharding (set by ShardExecutor)
        self._shardings: Dict[int, object] = {}

    # -- compiled group launch -------------------------------------------------
    def _build_group_fn(
        self,
        op,
        slots: Tuple[int, ...],
        block_shapes: Tuple[Tuple[int, int], ...],
        root_shapes: Tuple[Tuple[int, int], ...],
        root_dtypes: Tuple,
        write_pos: Tuple[int, ...],
        out_shardings,
    ):
        backend = self.backend
        batched = op.batched_leaf_fn(backend) if hasattr(
            op, "batched_leaf_fn"
        ) else jax.vmap(op.leaf_fn(backend))

        def fn(roots: Tuple[jnp.ndarray, ...], idxs: Tuple[jnp.ndarray, ...]):
            roots = list(roots)
            blocks = []
            for a, slot in enumerate(slots):
                br, bc = block_shapes[a]
                g = _to_grid(roots[slot], br, bc)
                blocks.append(g[idxs[a][:, 0], idxs[a][:, 1]])
            outs = batched(*blocks)
            if not isinstance(outs, (tuple, list)):
                outs = (outs,)
            for out, a in zip(outs, write_pos):
                slot = slots[a]
                br, bc = block_shapes[a]
                g = _to_grid(roots[slot], br, bc)
                g = g.at[idxs[a][:, 0], idxs[a][:, 1]].set(
                    out.astype(root_dtypes[slot])
                )
                roots[slot] = _from_grid(g)
            return tuple(roots)

        jit_kwargs = {}
        if out_shardings is not None:
            jit_kwargs["out_shardings"] = out_shardings
        return jax.jit(fn, donate_argnums=(0,) if self.donate else (), **jit_kwargs)

    def _group_fn(self, op, rep: GTask, roots_order: Tuple[int, ...]):
        slot_of = {d: i for i, d in enumerate(roots_order)}
        slots = tuple(slot_of[v.data.id] for v in rep.args)
        block_shapes = tuple(v.region.shape for v in rep.args)
        root_shapes = tuple(rep.args[0].data.shape for _ in roots_order)
        roots = {v.data.id: v.data for v in rep.args}
        root_shapes = tuple(roots[d].shape for d in roots_order)
        root_dtypes = tuple(roots[d].dtype for d in roots_order)
        write_pos = tuple(i for i, m in enumerate(rep.modes) if m.writes)
        shardings = tuple(self._shardings.get(d) for d in roots_order)
        out_shardings = shardings if any(s is not None for s in shardings) else None
        key = (
            op.name,
            self.backend,
            self.donate,
            slots,
            block_shapes,
            root_shapes,
            root_dtypes,
            write_pos,
            tuple(str(s) for s in shardings),
        )
        if key not in self._fn_cache:
            self._fn_cache[key] = self._build_group_fn(
                op,
                slots,
                block_shapes,
                root_shapes,
                root_dtypes,
                write_pos,
                out_shardings,
            )
            self.stats["compiles"] += 1
        return self._fn_cache[key]

    # -- wave execution ----------------------------------------------------------
    def execute_wave(self, wave: List[GTask]) -> int:
        for key, tasks in group_wave(wave).items():
            self._run_group(tasks)
        return len(wave)

    def _run_group(self, tasks: List[GTask]) -> None:
        rep = tasks[0]
        op = rep.op
        # stable unique root order
        roots_order: List[int] = []
        for v in rep.args:
            if v.data.id not in roots_order:
                roots_order.append(v.data.id)
        roots_order = tuple(roots_order)
        data_of = {v.data.id: v.data for t in tasks for v in t.args}
        fn = self._group_fn(op, rep, roots_order)
        # pad the batch to a power-of-two bucket so retraces are O(log n)
        # across wave sizes; padding repeats the last task, whose duplicate
        # scatter writes the identical value (idempotent).
        n = len(tasks)
        bucket = 1
        while bucket < n:
            bucket *= 2
        pad = [tasks[-1]] * (bucket - n)
        batch = tasks + pad
        idxs = tuple(
            jnp.asarray(
                np.array([t.args[a].block_index() for t in batch], dtype=np.int32)
            )
            for a in range(len(rep.args))
        )
        roots_in = tuple(data_of[d].value for d in roots_order)
        roots_out = fn(roots_in, idxs)
        for d, arr in zip(roots_order, roots_out):
            data_of[d].value = arr
        for t in tasks:
            t.state = TaskState.FINISHED
            self.stats["tasks"] += 1
            self._finished(t)
        self.stats["launches"] += 1


class PallasExecutor(JitWaveExecutor):
    """cuBLAS wrapper analog: identical wave batching, Pallas tile kernels as
    leaves (interpret=True on CPU; compiled on real TPUs)."""

    name = "pallas"

    def __init__(self, **kw):
        kw.setdefault("backend", "pallas")
        super().__init__(**kw)
