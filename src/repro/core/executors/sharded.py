"""Sharded executor — the DuctTeip wrapper analog for a device mesh.

DuctTeip distributes level-1 blocks over MPI ranks (owner computes) and
moves panel blocks with messages.  On a TPU mesh the analog is: the root
array carries a ``NamedSharding`` over the mesh's ``data`` axis (block rows
owned by mesh rows), every wave launch is jitted *with those shardings*, and
XLA's SPMD partitioner materializes the panel movements as collectives
(all-gather / collective-permute) — explicit, inspectable in the HLO, and
overlappable by the latency-hiding scheduler.

``shard_axes`` picks which array dims map to which mesh axes; divisibility
is checked and falls back to replication per-dim (never fails to place).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..data import GData
from .jit_wave import JitWaveExecutor


def row_sharding(mesh: Mesh, data: GData, axes: Tuple[Optional[str], ...]):
    """NamedSharding for ``data`` with per-dim mesh axes, replication fallback."""
    spec = []
    for dim, ax in zip(data.shape, axes):
        if ax is None:
            spec.append(None)
            continue
        size = mesh.shape[ax]
        spec.append(ax if dim % size == 0 else None)
    return NamedSharding(mesh, P(*spec))


class ShardExecutor(JitWaveExecutor):
    name = "shard"

    def __init__(
        self,
        mesh: Mesh,
        backend: str = "jnp",
        shard_axes: Tuple[Optional[str], ...] = ("data", None),
        **kw,
    ):
        super().__init__(backend=backend, **kw)
        self.mesh = mesh
        self.shard_axes = shard_axes

    def place(self, data: GData) -> None:
        """Distribute a root datum over the mesh (owner-computes layout)."""
        sh = row_sharding(self.mesh, data, self.shard_axes)
        self._shardings[data.id] = sh
        if data.value is not None:
            data.value = jax.device_put(data.value, sh)

    def _run_group(self, tasks):
        # lazily place any root not yet distributed
        for t in tasks:
            for v in t.args:
                if v.data.id not in self._shardings and v.data.value is not None:
                    self.place(v.data)
        super()._run_group(tasks)
