"""Sharded executor — the DuctTeip wrapper analog for a device mesh.

DuctTeip distributes level-1 blocks over MPI ranks (owner computes) and
moves panel blocks with messages.  On a TPU mesh the analog is: the root
array carries a ``NamedSharding`` over the mesh's ``data`` axis (block rows
owned by mesh rows), every wave launch is jitted *with those shardings*, and
XLA's SPMD partitioner materializes the panel movements as collectives
(all-gather / collective-permute) — explicit, inspectable in the HLO, and
overlappable by the latency-hiding scheduler.

``shard_axes`` picks which array dims map to which mesh axes; divisibility
is checked and falls back to replication per-dim (never fails to place).
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..data import GData
from ..task import GTask
from .jit_wave import JitWaveExecutor


def row_sharding(mesh: Mesh, data: GData, axes: Tuple[Optional[str], ...]):
    """NamedSharding for ``data`` with per-dim mesh axes, replication fallback."""
    spec = []
    for dim, ax in zip(data.shape, axes):
        if ax is None:
            spec.append(None)
            continue
        size = mesh.shape[ax]
        spec.append(ax if dim % size == 0 else None)
    return NamedSharding(mesh, P(*spec))


class ShardExecutor(JitWaveExecutor):
    name = "shard"

    def __init__(
        self,
        mesh: Mesh,
        backend: str = "jnp",
        shard_axes: Tuple[Optional[str], ...] = ("data", None),
        **kw,
    ):
        super().__init__(backend=backend, **kw)
        self.mesh = mesh
        self.shard_axes = shard_axes

    def place(self, data: GData) -> None:
        """Distribute a root datum over the mesh (owner-computes layout)."""
        sh = row_sharding(self.mesh, data, self.shard_axes)
        self._shardings[data.id] = sh
        if data.value is not None:
            data.value = jax.device_put(data.value, sh)

    def memo_key_extra(self) -> tuple:
        # axis sizes alone don't identify a mesh: two meshes with the same
        # ('data', 2) layout over different devices compile different
        # out_shardings, so device identity must be part of every cache key
        mesh_desc = (
            tuple(sorted(self.mesh.shape.items())),
            tuple(d.id for d in self.mesh.devices.flat),
        )
        return super().memo_key_extra() + (mesh_desc, tuple(self.shard_axes))

    def _grid_sharding(self, data: GData, br: int, bc: int):
        """Shard the resident (nr, nc, br, bc) grid over its *grid* dims.

        The root's row sharding (block rows owned by mesh rows) becomes a
        sharding of the leading grid dims; block dims stay replicated, so
        the distributed drain rides the same resident layout as the local
        one and XLA's SPMD partitioner materializes panel movement as
        collectives around the compiled WaveProgram.
        """
        nr, nc = data.shape[0] // br, data.shape[1] // bc
        spec = []
        for dim, ax in zip((nr, nc), self.shard_axes):
            if ax is None:
                spec.append(None)
                continue
            size = self.mesh.shape[ax]
            spec.append(ax if dim % size == 0 else None)
        return NamedSharding(self.mesh, P(*spec, None, None))

    def _prepare_roots(self, waves: Sequence[Sequence[GTask]]) -> None:
        # lazily place any root not yet distributed (first drain only; the
        # resident grid keeps its sharding across subsequent drains).
        # Called from execute_schedule before planning, so the distributed
        # graphs ride the same dependency-exact fused schedule as the local
        # ones — a multi-root drain's fused cross-root groups gather from
        # several sharded grids and XLA's SPMD partitioner inserts the
        # collectives around the one compiled program (DESIGN.md §2).
        for wave in waves:
            for t in wave:
                for v in t.args:
                    d = v.data
                    if d.id not in self._shardings and (
                        d.in_grid_epoch or d.value is not None
                    ):
                        self.place(d)

    def _run_group(self, tasks: List[GTask]):
        self._prepare_roots([tasks])
        super()._run_group(tasks)
