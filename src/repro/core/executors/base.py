"""Unified executor (framework-wrapper) interface — paper §2.1.

The paper requires every framework wrapper to implement predefined
interfaces for data definition and task creation/submission/execution/
completion so the dispatcher can talk to any of them generically.  Here the
interface is ``execute_schedule``: the dispatcher hands over the Kahn level
schedule (list of waves of independent tasks) together with the exact task
DAG behind it (``versioning.TaskDag``), so capable executors can issue
dependency-exactly and fuse groups across wave boundaries; ``execute_waves``
is the DAG-less barrier form.  Completion is reported back via the returned
count (synchronous SPMD world) and the per-task callback for the
paper-faithful eager path.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..task import GTask


def group_wave(wave: Sequence[GTask]) -> Dict[tuple, List[GTask]]:
    """Group independent tasks by (op, arg signature) for batched execution.

    Signature captures everything static about the batched launch: operation
    name, per-arg access mode, root datum and block shape.  Tasks sharing a
    signature differ only in block *indices* -> one vmapped/Pallas-grid
    launch.  Modes are part of the key because the fused launch scatters by
    the GROUP's write positions: two same-op tasks whose mode vectors
    differ must never share a launch or the minority task's writes would be
    dropped (registry operations have fixed modes, so for real workloads
    this never splits a group — but the invariant must hold for any task
    stream the dispatcher accepts).
    """
    groups: Dict[tuple, List[GTask]] = defaultdict(list)
    for t in wave:
        key = (
            t.op.name,
            tuple(t.modes),
            tuple((v.data.id, v.region.shape) for v in t.args),
        )
        groups[key].append(t)
    return groups


class Executor:
    """Base wrapper. ``name`` identifies it in task-flow graph configs."""

    name = "base"

    def __init__(self, on_task_finished: Optional[Callable[[GTask], None]] = None):
        self.on_task_finished = on_task_finished
        self.stats = defaultdict(int)
        # Static verification flag (DESIGN.md §11), set by the owning
        # Dispatcher.  It lives on the executor — not only on dispatcher
        # drain paths — so EVERY route into plan_schedule is covered,
        # including the ``_StackedAbort`` fallback re-drain.
        self.verify = False

    def take_inflight(self) -> List[object]:
        """Drain and return the executor's in-flight epoch handles
        (``versioning.InFlightEpoch``) — the launches dispatched since the
        last take whose device results may not have materialized yet
        (DESIGN.md §12).  Synchronous executors have none: the base
        implementation returns ``[]``, which callers treat as "everything
        already complete"."""
        return []

    def sync(self) -> float:
        """Fence every outstanding in-flight epoch; returns host seconds
        spent blocked.  No-op (0.0) for synchronous executors."""
        total = 0.0
        for ep in self.take_inflight():
            total += ep.wait()
        return total

    def execute_schedule(self, waves: List[List[GTask]], dag=None) -> int:
        """Run a leaf schedule: the Kahn level waves plus (optionally) the
        exact task DAG behind them (``versioning.TaskDag``).

        Executors that can exploit the DAG — dependency-exact issue slots,
        cross-wave group fusion — override this; the default ignores it and
        runs the barrier-wave schedule, which is always a correct (if
        conservative) linearization of the DAG."""
        return self.execute_waves(waves)

    def execute_waves(self, waves: List[List[GTask]]) -> int:
        """Run all waves in order; within a wave tasks are independent."""
        n = 0
        for wave in waves:
            n += self.execute_wave(wave)
        return n

    def execute_wave(self, wave: List[GTask]) -> int:
        raise NotImplementedError

    def _finished(self, task: GTask) -> None:
        if self.on_task_finished is not None:
            self.on_task_finished(task)
