"""Inline executor — the paper's cpuBLAS wrapper analog.

Tasks submitted here are "immediately executed and their completions are
reported back to the dispatcher" (paper §2.2).  Each leaf runs eagerly with
the jnp backend; no batching, no jit caching.  This is the G1 configuration
leaf and also the reference semantics for every other executor.
"""

from __future__ import annotations

from typing import List

from ..task import GTask, TaskState
from .base import Executor


class InlineExecutor(Executor):
    name = "inline"

    def __init__(self, backend: str = "jnp", **kw):
        super().__init__(**kw)
        self.backend = backend

    def execute_wave(self, wave: List[GTask]) -> int:
        for task in wave:
            self.run_task(task)
        return len(wave)

    def run_task(self, task: GTask) -> None:
        task.state = TaskState.RUNNING
        fn = task.op.leaf_fn(self.backend)
        ins = [v.get() for v in task.args]
        outs = fn(*ins)
        if not isinstance(outs, tuple):
            outs = (outs,)
        wviews = task.outputs()
        assert len(outs) == len(wviews), (task.op.name, len(outs), len(wviews))
        for view, arr in zip(wviews, outs):
            view.set(arr)
        task.state = TaskState.FINISHED
        self.stats["tasks"] += 1
        self._finished(task)
