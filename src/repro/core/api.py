"""Paper-style application-layer facade (Fig. 2a): utp_initialize/finalize.

Keeps a module-level current dispatcher so application programs read like
the paper's ``unified_cholesky.cpp``:

    utp_initialize(graph="g2")            # pick the task-flow graph
    A = GData(...); utp_cholesky(dispatcher(), A)   # submit root tasks
    utp_finalize()                        # drain: run everything submitted

For library code prefer constructing a ``Dispatcher`` directly (as
``repro.linalg.run_*`` do); this facade exists for paper-shaped example
programs and scripts.
"""

from __future__ import annotations

import sys
from typing import List, Optional, Tuple

from .dispatcher import Dispatcher

_current: Optional[Dispatcher] = None


def utp_initialize(graph: str = "g2", mesh=None) -> Dispatcher:
    """Create the current dispatcher (paper Fig. 2a line 11).

    ``graph`` names a task-flow graph (g1/g2/g2p/g3/g4/g3flat — see
    ``core.graph.GRAPHS``); distributed graphs additionally need ``mesh``
    (a ``jax.sharding.Mesh``).  Returns the dispatcher, which is also
    reachable through ``dispatcher()`` until the next ``utp_initialize``.
    """
    global _current
    _current = Dispatcher(graph=graph, mesh=mesh)
    return _current


def dispatcher() -> Dispatcher:
    """The dispatcher created by the last ``utp_initialize`` call."""
    if _current is None:
        raise RuntimeError("call utp_initialize() first")
    return _current


def utp_finalize() -> int:
    """Wait for all tasks to finish (paper Fig. 2a line 16)."""
    n = dispatcher().run()
    return n


def utp_get_parameters(
    argv: Optional[List[str]] = None, defaults: Tuple[int, int, int] = (1024, 4, 4)
) -> Tuple[int, int, int]:
    """(N, b1, b2) from the command line, as in paper Fig. 2a line 10.

    Raises ``ValueError`` for non-positive values: a negative or zero matrix
    size / partition count would silently produce empty or inverted block
    grids downstream (``"-4".lstrip("-").isdigit()`` is True, so these used
    to parse "successfully").
    """
    argv = sys.argv[1:] if argv is None else argv
    names = ("N", "b1", "b2")
    vals = []
    for a in argv[:3]:
        # one optional sign, then digits; anything else is a non-int flag
        if not (a[1:] if a[:1] in "+-" else a).isdigit():
            continue
        v = int(a)
        if v <= 0:
            raise ValueError(
                f"utp_get_parameters: {names[len(vals)]}={v} must be a "
                "positive integer"
            )
        vals.append(v)
    n = vals[0] if len(vals) > 0 else defaults[0]
    b1 = vals[1] if len(vals) > 1 else defaults[1]
    b2 = vals[2] if len(vals) > 2 else defaults[2]
    return n, b1, b2
