"""Generic data handles with hierarchical partitioning (paper §2.2).

``GData`` is the UTP analog of the paper's generic data type: a handle that
the application layer manipulates *by reference* while the dispatcher and
executors decide where the bytes live (host, one device, or a sharded mesh).

A ``GData`` owns a root 2-D array and a list of partition levels.  Level
``l`` divides the matrix into a ``p_l x p_l`` grid of equal blocks *inside
each level ``l-1`` block* (the paper's nested ``b1``/``b2`` partitioning).
``GView`` addresses a rectangular region in absolute root coordinates;
``view(r, c)`` returns the child block at the next level, mirroring the
paper's ``A(r, c)`` indexing interface (Fig. 2b).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_uid = itertools.count()


def to_grid(a: jnp.ndarray, br: int, bc: int) -> jnp.ndarray:
    """(R, C) root layout -> (R//br, C//bc, br, bc) grid-major layout."""
    r, c = a.shape
    return a.reshape(r // br, br, c // bc, bc).transpose(0, 2, 1, 3)


def from_grid(a4: jnp.ndarray) -> jnp.ndarray:
    """(nr, nc, br, bc) grid-major layout -> (nr*br, nc*bc) root layout."""
    nr, nc, br, bc = a4.shape
    return a4.transpose(0, 2, 1, 3).reshape(nr * br, nc * bc)


# jitted epoch-boundary wrappers: one fused XLA call per layout change
# instead of a reshape+transpose+reshape dispatch chain (hot on repeated
# drains; the traced executor code uses the plain functions above).
_to_grid_jit = jax.jit(to_grid, static_argnums=(1, 2))
_from_grid_jit = jax.jit(from_grid)
# lane extraction from a stacked (B, nr, nc, br, bc) epoch grid: the lane
# index is a traced argument, so every lane of every batch shares ONE
# compiled slice+de-grid program regardless of which lane is read.
_from_grid_lane_jit = jax.jit(lambda g, i: from_grid(g[i]))


class StackedEpoch:
    """Shared result holder for one stacked (batched) drain — DESIGN.md §7.

    When the dispatcher stacks N structurally identical roots into one
    batched WaveProgram, the program's output per root slot is a single
    ``(B, nr, nc, br, bc)`` stacked grid.  Splitting it eagerly back into N
    per-root grids would reintroduce the per-root data movement the stacking
    removed, so instead every member ``GData`` adopts a *lane* of this shared
    epoch: reading a member's ``.value`` (or re-entering its grid epoch)
    extracts its lane lazily.  The epoch object dies when the last member
    resolves or re-adopts elsewhere.
    """

    __slots__ = ("grid", "block", "holders")

    def __init__(self, grid: jnp.ndarray, block: Tuple[int, int]):
        self.grid = grid  # (B, nr, nc, br, bc), device-resident
        self.block = tuple(block)
        # live lane holders: executors may DONATE this grid back into the
        # next stacked program only when every holder is re-adopted in that
        # same drain (otherwise a bystander lane would read a donated
        # buffer) — see JitWaveExecutor._stack_grids
        self.holders = 0

    @property
    def batch(self) -> int:
        return self.grid.shape[0]


@dataclass(frozen=True)
class Region:
    """A rectangular region of a root array, in absolute element coords."""

    r0: int
    c0: int
    rows: int
    cols: int

    def overlaps(self, other: "Region") -> bool:
        return not (
            self.r0 + self.rows <= other.r0
            or other.r0 + other.rows <= self.r0
            or self.c0 + self.cols <= other.c0
            or other.c0 + other.cols <= self.c0
        )

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.rows, self.cols)


class GData:
    """Root data handle.  ``partitions[l]`` = (rows, cols) grid at level l.

    The concrete array lives in ``.value`` and is only touched by executors;
    the application program works with handles and block indices, exactly as
    in the paper's Fig. 2(a) (``GData A(N, N, b1, b2)``).
    """

    def __init__(
        self,
        shape: Tuple[int, int],
        partitions: Tuple[Tuple[int, int], ...] = (),
        dtype: Any = jnp.float32,
        value: Optional[jnp.ndarray] = None,
        name: str = "",
    ):
        self.id = next(_uid)
        self.shape = tuple(shape)
        self.dtype = dtype
        self.partitions: List[Tuple[int, int]] = [tuple(p) for p in partitions]
        # Grid-resident epoch state (DESIGN.md §2): while ``_grid`` is set the
        # authoritative bytes live in (nr, nc, br, bc) grid-major layout and
        # ``_value`` is stale; reading ``.value`` de-grids lazily.
        self._grid: Optional[jnp.ndarray] = None
        self._grid_block: Optional[Tuple[int, int]] = None
        # Stacked-epoch lane (DESIGN.md §7): while set, the authoritative
        # bytes are one lane of a shared StackedEpoch grid; resolved lazily.
        self._lane: Optional[Tuple[StackedEpoch, int]] = None
        # Copy on ingest: executors may donate (destroy) the root buffer, so
        # GData must own its storage rather than alias a caller's array.
        self.value = None if value is None else jnp.array(value, dtype=dtype)
        self.name = name or f"gdata{self.id}"
        for lvl, (pr, pc) in enumerate(self.partitions):
            rows, cols = self._level_block_shape(lvl)
            if rows * pr != self._level_block_shape(lvl - 1)[0] or (
                cols * pc != self._level_block_shape(lvl - 1)[1]
            ):
                raise ValueError(
                    f"partition level {lvl} ({pr}x{pc}) does not evenly divide "
                    f"{self.name} of shape {self.shape}"
                )

    # -- grid-resident epoch (DESIGN.md §2) ---------------------------------
    @property
    def value(self) -> Optional[jnp.ndarray]:
        """Root-layout array.  Reading from inside a grid epoch de-grids
        lazily and ends the epoch (the next drain re-enters it); reading
        from a stacked-epoch lane extracts + de-grids that lane."""
        if self._lane is not None:
            ep, i = self._lane
            self._drop_lane()
            self._value = _from_grid_lane_jit(ep.grid, i)
            return self._value
        if self._grid is not None:
            self._value = _from_grid_jit(self._grid)
            self._grid = None
            self._grid_block = None
        return self._value

    @value.setter
    def value(self, v: Optional[jnp.ndarray]) -> None:
        self._grid = None
        self._grid_block = None
        self._drop_lane()
        self._value = v

    def _drop_lane(self) -> None:
        if self._lane is not None:
            self._lane[0].holders -= 1
            self._lane = None

    @property
    def in_grid_epoch(self) -> bool:
        return self._grid is not None

    @property
    def has_value(self) -> bool:
        """True when authoritative bytes exist in ANY epoch (root-layout
        value, resident grid, or stacked-epoch lane)."""
        return (
            self._value is not None
            or self._grid is not None
            or self._lane is not None
        )

    @property
    def lane(self) -> Optional[Tuple["StackedEpoch", int]]:
        """(epoch, lane index) while lane-resident, else None."""
        return self._lane

    def adopt_lane(self, epoch: StackedEpoch, lane: int) -> None:
        """Adopt lane ``lane`` of a stacked drain's result grid (DESIGN.md
        §7).  The shared epoch becomes the single authority for this datum;
        nothing is sliced or de-gridded until someone reads ``.value`` or
        re-enters a per-datum grid epoch."""
        nr, nc, br, bc = epoch.grid.shape[1:]
        want = (nr * br, nc * bc)
        if want != tuple(self.shape):
            raise ValueError(
                f"{self.name}: stacked lane shape {want} != {self.shape}"
            )
        self._grid = None
        self._grid_block = None
        self._value = None
        self._drop_lane()
        self._lane = (epoch, lane)
        epoch.holders += 1

    @property
    def grid_block(self) -> Optional[Tuple[int, int]]:
        return self._grid_block

    def enter_grid(self, br: int, bc: int) -> jnp.ndarray:
        """Enter (or stay in) the grid-resident epoch with block ``(br, bc)``.

        Executors call this once per dispatcher drain; repeated drains with
        the same block shape find the grid already resident and pay zero
        layout traffic.  A different block shape flushes through ``.value``
        first (root layout is the common interchange format).
        """
        if self.shape[0] % br or self.shape[1] % bc:
            raise ValueError(
                f"{self.name}: block ({br},{bc}) does not divide {self.shape}"
            )
        if self._grid is not None and self._grid_block == (br, bc):
            return self._grid
        if self._lane is not None and self._lane[0].block == (br, bc):
            # lane-resident with the right block shape: slice the lane out
            # of the stacked epoch directly, no root-layout round trip
            ep, i = self._lane
            self._drop_lane()
            self._grid = ep.grid[i]
            self._grid_block = (br, bc)
            return self._grid
        v = self.value  # flushes any differently-blocked resident grid/lane
        if v is None:
            raise ValueError(f"{self.name}: cannot enter grid epoch, no value")
        self._grid = _to_grid_jit(jnp.asarray(v, dtype=self.dtype), br, bc)
        self._grid_block = (br, bc)
        self._value = None  # grid is now the single authority
        return self._grid

    @property
    def grid(self) -> Optional[jnp.ndarray]:
        """The resident (nr, nc, br, bc) array, or None outside an epoch."""
        return self._grid

    def set_grid(self, g4: jnp.ndarray) -> None:
        """Replace the resident grid (executor scatter-back inside an epoch)."""
        if self._grid_block is None:
            raise ValueError(f"{self.name}: set_grid outside a grid epoch")
        br, bc = self._grid_block
        want = (self.shape[0] // br, self.shape[1] // bc, br, bc)
        if g4.shape != want:
            raise ValueError(
                f"{self.name}: set_grid shape {g4.shape} != resident {want}"
            )
        self._grid = g4

    # -- partition geometry -------------------------------------------------
    def _level_block_shape(self, level: int) -> Tuple[int, int]:
        """Block shape at ``level`` (level -1 or 0-indexed root = whole)."""
        rows, cols = self.shape
        for pr, pc in self.partitions[: level + 1]:
            rows //= pr
            cols //= pc
        return rows, cols

    def partition(self, pr: int, pc: int) -> "GData":
        """Append one more partitioning level (chainable)."""
        self.partitions.append((pr, pc))
        self._level_block_shape(len(self.partitions) - 1)  # validate
        return self

    @property
    def n_levels(self) -> int:
        return len(self.partitions)

    def root_view(self) -> "GView":
        return GView(self, Region(0, 0, *self.shape), level=-1)

    # convenience: A(r, c) on the root == level-0 block indexing
    def __call__(self, r: int, c: int) -> "GView":
        return self.root_view()(r, c)

    def row_part_num(self, level: int = 0) -> int:
        return self.partitions[level][0]

    def col_part_num(self, level: int = 0) -> int:
        return self.partitions[level][1]

    def materialize(self, fill: Optional[jnp.ndarray] = None) -> None:
        if fill is not None:
            assert fill.shape == self.shape, (fill.shape, self.shape)
            self.value = jnp.array(fill, dtype=self.dtype)  # copy: see __init__
        elif self.value is None:
            self.value = jnp.zeros(self.shape, dtype=self.dtype)

    def __repr__(self) -> str:  # pragma: no cover
        return f"GData({self.name}, {self.shape}, parts={self.partitions})"


@dataclass(frozen=True)
class GView:
    """A block view into a ``GData`` (the paper's ``A(r, c)``)."""

    data: GData
    region: Region
    level: int  # partition level this view sits at (-1 = root)

    def __call__(self, r: int, c: int) -> "GView":
        lvl = self.level + 1
        if lvl >= self.data.n_levels:
            raise IndexError(
                f"{self.data.name}: no partition level {lvl} "
                f"(has {self.data.n_levels})"
            )
        pr, pc = self.data.partitions[lvl]
        if not (0 <= r < pr and 0 <= c < pc):
            raise IndexError(f"block ({r},{c}) outside {pr}x{pc} grid")
        br = self.region.rows // pr
        bc = self.region.cols // pc
        return GView(
            self.data,
            Region(self.region.r0 + r * br, self.region.c0 + c * bc, br, bc),
            level=lvl,
        )

    def row_part_num(self) -> int:
        lvl = self.level + 1
        return self.data.partitions[lvl][0]

    def col_part_num(self) -> int:
        lvl = self.level + 1
        return self.data.partitions[lvl][1]

    @property
    def shape(self) -> Tuple[int, int]:
        return self.region.shape

    # -- executor-side array access (host path) -----------------------------
    def get(self) -> jnp.ndarray:
        v = self.data.value
        r = self.region
        return v[r.r0 : r.r0 + r.rows, r.c0 : r.c0 + r.cols]

    def set(self, block: jnp.ndarray) -> None:
        r = self.region
        self.data.value = self.data.value.at[
            r.r0 : r.r0 + r.rows, r.c0 : r.c0 + r.cols
        ].set(block.astype(self.data.dtype))

    def block_index(self) -> Tuple[int, int]:
        """(row, col) index of this block within the uniform grid of its level."""
        br, bc = self.region.rows, self.region.cols
        return self.region.r0 // br, self.region.c0 // bc

    def __repr__(self) -> str:  # pragma: no cover
        return f"{self.data.name}[{self.region.r0}:{self.region.r0+self.region.rows},{self.region.c0}:{self.region.c0+self.region.cols}]"


def spd_matrix(n: int, dtype=jnp.float32, seed: int = 0) -> jnp.ndarray:
    """Random symmetric positive definite matrix (test/benchmark input)."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32) / np.sqrt(n)
    a = a @ a.T + np.eye(n, dtype=np.float32) * 2.0
    return jnp.asarray(a, dtype=dtype)


def dd_matrix(n: int, dtype=jnp.float32, seed: int = 0) -> jnp.ndarray:
    """Random strictly column-diagonally-dominant matrix.

    Such matrices admit LU without pivoting, and partial pivoting provably
    selects the diagonal at every step (the Schur complement stays column-
    dominant), so ``jax.scipy.linalg.lu`` returns P == I — making pivoted
    library factors directly comparable to pivot-free task-layer ones.
    """
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    a /= np.abs(a).sum(axis=0, keepdims=True) * 1.5  # col |off-diag| sum < 2/3
    diag = 1.0 + rng.uniform(0.0, 1.0, n).astype(np.float32)
    np.fill_diagonal(a, diag)
    return jnp.asarray(a, dtype=dtype)
