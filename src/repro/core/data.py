"""Generic data handles with hierarchical partitioning (paper §2.2).

``GData`` is the UTP analog of the paper's generic data type: a handle that
the application layer manipulates *by reference* while the dispatcher and
executors decide where the bytes live (host, one device, or a sharded mesh).

A ``GData`` owns a root 2-D array and a list of partition levels.  Level
``l`` divides the matrix into a ``p_l x p_l`` grid of equal blocks *inside
each level ``l-1`` block* (the paper's nested ``b1``/``b2`` partitioning).
``GView`` addresses a rectangular region in absolute root coordinates;
``view(r, c)`` returns the child block at the next level, mirroring the
paper's ``A(r, c)`` indexing interface (Fig. 2b).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

_uid = itertools.count()


@dataclass(frozen=True)
class Region:
    """A rectangular region of a root array, in absolute element coords."""

    r0: int
    c0: int
    rows: int
    cols: int

    def overlaps(self, other: "Region") -> bool:
        return not (
            self.r0 + self.rows <= other.r0
            or other.r0 + other.rows <= self.r0
            or self.c0 + self.cols <= other.c0
            or other.c0 + other.cols <= self.c0
        )

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.rows, self.cols)


class GData:
    """Root data handle.  ``partitions[l]`` = (rows, cols) grid at level l.

    The concrete array lives in ``.value`` and is only touched by executors;
    the application program works with handles and block indices, exactly as
    in the paper's Fig. 2(a) (``GData A(N, N, b1, b2)``).
    """

    def __init__(
        self,
        shape: Tuple[int, int],
        partitions: Tuple[Tuple[int, int], ...] = (),
        dtype: Any = jnp.float32,
        value: Optional[jnp.ndarray] = None,
        name: str = "",
    ):
        self.id = next(_uid)
        self.shape = tuple(shape)
        self.dtype = dtype
        self.partitions: List[Tuple[int, int]] = [tuple(p) for p in partitions]
        # Copy on ingest: executors may donate (destroy) the root buffer, so
        # GData must own its storage rather than alias a caller's array.
        self.value = None if value is None else jnp.array(value, dtype=dtype)
        self.name = name or f"gdata{self.id}"
        for lvl, (pr, pc) in enumerate(self.partitions):
            rows, cols = self._level_block_shape(lvl)
            if rows * pr != self._level_block_shape(lvl - 1)[0] or (
                cols * pc != self._level_block_shape(lvl - 1)[1]
            ):
                raise ValueError(
                    f"partition level {lvl} ({pr}x{pc}) does not evenly divide "
                    f"{self.name} of shape {self.shape}"
                )

    # -- partition geometry -------------------------------------------------
    def _level_block_shape(self, level: int) -> Tuple[int, int]:
        """Block shape at ``level`` (level -1 or 0-indexed root = whole)."""
        rows, cols = self.shape
        for pr, pc in self.partitions[: level + 1]:
            rows //= pr
            cols //= pc
        return rows, cols

    def partition(self, pr: int, pc: int) -> "GData":
        """Append one more partitioning level (chainable)."""
        self.partitions.append((pr, pc))
        self._level_block_shape(len(self.partitions) - 1)  # validate
        return self

    @property
    def n_levels(self) -> int:
        return len(self.partitions)

    def root_view(self) -> "GView":
        return GView(self, Region(0, 0, *self.shape), level=-1)

    # convenience: A(r, c) on the root == level-0 block indexing
    def __call__(self, r: int, c: int) -> "GView":
        return self.root_view()(r, c)

    def row_part_num(self, level: int = 0) -> int:
        return self.partitions[level][0]

    def col_part_num(self, level: int = 0) -> int:
        return self.partitions[level][1]

    def materialize(self, fill: Optional[jnp.ndarray] = None) -> None:
        if fill is not None:
            assert fill.shape == self.shape, (fill.shape, self.shape)
            self.value = jnp.array(fill, dtype=self.dtype)  # copy: see __init__
        elif self.value is None:
            self.value = jnp.zeros(self.shape, dtype=self.dtype)

    def __repr__(self) -> str:  # pragma: no cover
        return f"GData({self.name}, {self.shape}, parts={self.partitions})"


@dataclass(frozen=True)
class GView:
    """A block view into a ``GData`` (the paper's ``A(r, c)``)."""

    data: GData
    region: Region
    level: int  # partition level this view sits at (-1 = root)

    def __call__(self, r: int, c: int) -> "GView":
        lvl = self.level + 1
        if lvl >= self.data.n_levels:
            raise IndexError(
                f"{self.data.name}: no partition level {lvl} "
                f"(has {self.data.n_levels})"
            )
        pr, pc = self.data.partitions[lvl]
        if not (0 <= r < pr and 0 <= c < pc):
            raise IndexError(f"block ({r},{c}) outside {pr}x{pc} grid")
        br = self.region.rows // pr
        bc = self.region.cols // pc
        return GView(
            self.data,
            Region(self.region.r0 + r * br, self.region.c0 + c * bc, br, bc),
            level=lvl,
        )

    def row_part_num(self) -> int:
        lvl = self.level + 1
        return self.data.partitions[lvl][0]

    def col_part_num(self) -> int:
        lvl = self.level + 1
        return self.data.partitions[lvl][1]

    @property
    def shape(self) -> Tuple[int, int]:
        return self.region.shape

    # -- executor-side array access (host path) -----------------------------
    def get(self) -> jnp.ndarray:
        v = self.data.value
        r = self.region
        return v[r.r0 : r.r0 + r.rows, r.c0 : r.c0 + r.cols]

    def set(self, block: jnp.ndarray) -> None:
        r = self.region
        self.data.value = self.data.value.at[
            r.r0 : r.r0 + r.rows, r.c0 : r.c0 + r.cols
        ].set(block.astype(self.data.dtype))

    def block_index(self) -> Tuple[int, int]:
        """(row, col) index of this block within the uniform grid of its level."""
        br, bc = self.region.rows, self.region.cols
        return self.region.r0 // br, self.region.c0 // bc

    def __repr__(self) -> str:  # pragma: no cover
        return f"{self.data.name}[{self.region.r0}:{self.region.r0+self.region.rows},{self.region.c0}:{self.region.c0+self.region.cols}]"


def spd_matrix(n: int, dtype=jnp.float32, seed: int = 0) -> jnp.ndarray:
    """Random symmetric positive definite matrix (test/benchmark input)."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32) / np.sqrt(n)
    a = a @ a.T + np.eye(n, dtype=np.float32) * 2.0
    return jnp.asarray(a, dtype=dtype)
