"""Batched serving front end over the stacked drain path (DESIGN.md §7).

``BatchServer`` queues many small independent user requests (e.g.
``lu_solve(a, b)``), buckets them by structural signature, and drains ONE
stacked WaveProgram per signature per ``tick()`` — the piece that turns the
single-program compiler into a serving engine.  Each request returns a
``ServeFuture`` resolved at tick time; results are extracted lazily from
the shared stacked result grids.

Serving is fault-contained (DESIGN.md §10): a failing drain is bisected to
isolate the poisoned request(s), transient failures retry with backoff,
requests carry deadlines, and ``max_pending`` bounds the queue with
explicit overload shedding.  The error taxonomy lives in ``repro.errors``
and is re-exported here for convenience.

This is the task-layer analog of ``repro/serving`` (the LM token engine):
same continuous-batching shape, but the unit of work is a whole task-graph
drain rather than a decode step.
"""

from ..errors import (
    DeadlineExceeded,
    DrainError,
    InflightError,
    NumericalError,
    RejectedError,
    ServeError,
)
from .server import BatchServer, ServeFuture, TickReport

__all__ = [
    "BatchServer",
    "DeadlineExceeded",
    "DrainError",
    "InflightError",
    "NumericalError",
    "RejectedError",
    "ServeError",
    "ServeFuture",
    "TickReport",
]
