"""BatchServer: signature-bucketed batched serving of task-graph drains.

Requests accumulate between ticks; ``tick()`` groups them by *structural
signature* — (graph, operation, per-argument shape/dtype/partitions) — and
submits each group's root tasks to one dispatcher drain.  A homogeneous
group takes the stacked path (DESIGN.md §7): ONE batched WaveProgram over a
pow2-padded batch axis, so a tick serving N requests of one signature costs
one launch, and a structurally repeated tick replays with zero Python
re-splitting and zero recompiles (the drain memo's stacked key is
independent of the exact N inside a bucket).

The generic surface is ``submit(op_name, arrays, ...)`` for any registered
Operation; ``lu``, ``lu_solve``, and ``cholesky`` are typed conveniences
that attach the right partitions and result extraction.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from ..core import Dispatcher, GData, GTask
from ..core.operation import OpRegistry
from ..linalg.lu import _unpack

_rid = itertools.count()


class ServeFuture:
    """Per-request result handle: resolved at tick time, materialized lazily.

    ``result()`` raises if the request has not been drained yet (call
    ``BatchServer.tick()`` first).  Extraction is lazy: resolving stores a
    thunk over the request's data handles, so a tick never pays per-request
    de-grid work for results nobody reads.
    """

    def __init__(self, rid: int, signature: tuple):
        self.rid = rid
        self.signature = signature
        self._thunk: Optional[Callable[[], Any]] = None
        self._error: Optional[BaseException] = None
        self._value: Any = None
        self._materialized = False

    @property
    def done(self) -> bool:
        return self._thunk is not None or self._error is not None

    def _resolve(self, thunk: Callable[[], Any]) -> None:
        self._thunk = thunk

    def _fail(self, error: BaseException) -> None:
        self._error = error

    def result(self) -> Any:
        if self._error is not None:
            raise self._error
        if self._thunk is None:
            raise RuntimeError(
                f"request {self.rid} not drained yet — call BatchServer.tick()"
            )
        if not self._materialized:
            self._value = self._thunk()
            self._materialized = True
            self._thunk = lambda: self._value
        return self._value


@dataclass
class _Pending:
    future: ServeFuture
    op: object
    datas: List[GData]
    extract: Callable[[List[GData]], Any]


@dataclass
class TickReport:
    """What one ``tick()`` did, per signature bucket and in total."""

    requests: int = 0
    buckets: int = 0
    drains: int = 0
    launches: int = 0
    compiles: int = 0
    stacked_drains: int = 0
    memo_hits: int = 0
    memo_misses: int = 0
    per_bucket: List[dict] = field(default_factory=list)


class BatchServer:
    """Queue -> signature buckets -> one stacked drain per bucket per tick.

    ``max_batch`` caps one drain's batch (requests beyond it drain as
    additional chunks in the same tick); it must be a power of two so full
    chunks match compiled-program buckets exactly (a 48-cap would pad
    every full chunk to the 64 bucket — 33% junk lanes forever).
    """

    def __init__(self, graph: str = "g2", mesh=None, max_batch: int = 64):
        if max_batch < 1 or max_batch & (max_batch - 1):
            raise ValueError(
                f"max_batch must be a power of two >= 1, got {max_batch}"
            )
        self.graph = graph
        self.mesh = mesh
        self.max_batch = max_batch
        self._queues: Dict[tuple, List[_Pending]] = {}
        self.stats: Dict[str, int] = {
            "requests": 0,
            "ticks": 0,
            "drains": 0,
            "launches": 0,
            "compiles": 0,
            "memo_hits": 0,
            "memo_misses": 0,
            "stacked_drains": 0,
        }

    # -- request surface -------------------------------------------------------
    def submit(
        self,
        op_name: str,
        arrays: Sequence[jnp.ndarray],
        partitions: Sequence[Tuple[Tuple[int, int], ...]],
        extract: Optional[Callable[[List[GData]], Any]] = None,
    ) -> ServeFuture:
        """Queue one request: ``op_name`` applied to ``arrays`` (one root
        task).  ``partitions`` gives each argument's partition levels;
        ``extract(datas)`` builds the result from the drained data handles
        (default: the last argument's value — the written-in-place result
        convention of the linalg families)."""
        op = OpRegistry.get(op_name)
        if len(arrays) != len(partitions):
            raise ValueError(
                f"{len(arrays)} arrays vs {len(partitions)} partition specs"
            )
        datas = [
            GData(a.shape, partitions=parts, dtype=a.dtype, value=jnp.asarray(a))
            for a, parts in zip(arrays, partitions)
        ]
        sig = (
            self.graph,
            op.name,
            tuple(
                (d.shape, str(jnp.dtype(d.dtype)), tuple(d.partitions))
                for d in datas
            ),
        )
        fut = ServeFuture(next(_rid), sig)
        if extract is None:
            extract = lambda ds: ds[-1].value
        self._queues.setdefault(sig, []).append(
            _Pending(fut, op, datas, extract)
        )
        self.stats["requests"] += 1
        return fut

    def lu(
        self, a, partitions: Tuple[Tuple[int, int], ...] = ((4, 4),)
    ) -> ServeFuture:
        """Queue a pivot-free LU; resolves to (L, U) unpacked."""
        return self.submit(
            "getrf", [a], [partitions], extract=lambda ds: _unpack(ds[0])
        )

    def cholesky(
        self, a, partitions: Tuple[Tuple[int, int], ...] = ((4, 4),)
    ) -> ServeFuture:
        """Queue a Cholesky factorization; resolves to the lower factor."""
        return self.submit(
            "potrf",
            [a],
            [partitions],
            extract=lambda ds: jnp.tril(ds[0].value),
        )

    def lu_solve(
        self,
        a,
        b,
        partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
        b_partitions: Tuple[Tuple[int, int], ...] = None,
    ) -> ServeFuture:
        """Queue ``a @ x == b`` (composed factor+solve, one root task);
        resolves to x.  ``b`` may be a vector or a matrix, as in
        ``run_lu_solve``."""
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        if b.shape[0] != a.shape[0]:
            raise ValueError(f"shape mismatch: a {a.shape} vs b {b.shape}")
        vec = b.ndim == 1
        b2 = b[:, None] if vec else b
        if b_partitions is None:
            b_partitions = tuple(
                (pr, 1 if vec else pc) for pr, pc in partitions
            )
        extract = (
            (lambda ds: ds[1].value[:, 0]) if vec else (lambda ds: ds[1].value)
        )
        return self.submit(
            "lu_solve", [a, b2], [partitions, b_partitions], extract=extract
        )

    # -- serving loop ----------------------------------------------------------
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def tick(self) -> TickReport:
        """Drain every queued request: one stacked drain per signature
        bucket (chunked at ``max_batch``), resolve the futures.

        Failure containment: if a chunk's drain raises, that chunk's
        futures carry the error (``result()`` re-raises it), every
        not-yet-drained request stays queued for the next tick, and the
        exception propagates to the tick caller — nothing is stranded."""
        queues, self._queues = self._queues, {}
        chunks: List[Tuple[tuple, List[_Pending]]] = [
            (sig, pending[lo : lo + self.max_batch])
            for sig, pending in queues.items()
            for lo in range(0, len(pending), self.max_batch)
        ]
        report = TickReport()
        report.buckets = len(queues)
        self.stats["ticks"] += 1
        for ci, (sig, chunk) in enumerate(chunks):
            d = Dispatcher(graph=self.graph, mesh=self.mesh)
            for p in chunk:
                d.submit_task(
                    GTask(p.op, None, [dd.root_view() for dd in p.datas])
                )
            try:
                d.run()
            except BaseException as e:
                for p in chunk:
                    p.future._fail(e)
                for sig2, rest in chunks[ci + 1 :]:
                    self._queues.setdefault(sig2, []).extend(rest)
                raise
            for p in chunk:
                datas = p.datas
                extract = p.extract
                p.future._resolve(
                    (lambda ds=datas, ex=extract: ex(ds))
                )
            est = d.executor.stats
            bucket_stats = {
                "signature": sig[1],
                "requests": len(chunk),
                "launches": int(est.get("launches", 0)),
                "compiles": int(est.get("compiles", 0)),
                "stacked": int(d.stats["stacked_drains"]),
                "memo_hits": int(d.stats["memo_hits"]),
                "memo_misses": int(d.stats["memo_misses"]),
            }
            report.per_bucket.append(bucket_stats)
            report.requests += len(chunk)
            report.drains += 1
            report.launches += bucket_stats["launches"]
            report.compiles += bucket_stats["compiles"]
            report.stacked_drains += bucket_stats["stacked"]
            report.memo_hits += bucket_stats["memo_hits"]
            report.memo_misses += bucket_stats["memo_misses"]
        for k in (
            "drains",
            "launches",
            "compiles",
            "memo_hits",
            "memo_misses",
            "stacked_drains",
        ):
            self.stats[k] += getattr(report, k)
        return report
