"""BatchServer: signature-bucketed batched serving of task-graph drains.

Requests accumulate between ticks; ``tick()`` groups them by *structural
signature* — (graph, operation, per-argument shape/dtype/partitions) — and
submits each group's root tasks to one dispatcher drain.  A homogeneous
group takes the stacked path (DESIGN.md §7): ONE batched WaveProgram over a
pow2-padded batch axis, so a tick serving N requests of one signature costs
one launch, and a structurally repeated tick replays with zero Python
re-splitting and zero recompiles (the drain memo's stacked key is
independent of the exact N inside a bucket).

Failure model (DESIGN.md §10): a failing drain never unwinds the serving
loop.  A chunk whose drain raises is BISECTED — log2 re-drains over pow2
halves (which replay from the drain memo's bucket programs) isolate the
poisoned request(s); healthy requests resolve in the same tick, only the
culprits fail, with a typed error (``DrainError``/``NumericalError``) on
their futures.  Transient failures consume a bounded per-request retry
budget with exponential tick backoff.  ``check_finite=True`` additionally
validates result lanes after every successful drain (one fused reduce over
the shared stacked epoch grid — no per-request de-grid), failing exactly
the non-finite lanes with ``NumericalError``.  Requests carry optional
deadlines (expired requests fail with ``DeadlineExceeded`` WITHOUT being
drained), and ``max_pending`` bounds the queue with explicit overload
shedding (``RejectedError``; reject-new or drop-oldest policy).

Async drain overlap (DESIGN.md §12): with ``overlap=True`` (the default)
``tick()`` is a pipeline — every bucket's stacked program is LAUNCHED
back-to-back with no device fence in between (JAX dispatch is
asynchronous), ``check_finite`` reduces are dispatched eagerly per epoch
but materialized only in a deferred validation pass at end-of-tick, and an
in-flight failure (a program that dispatched but failed before its results
materialized) is contained exactly like a synchronous one: memo
invalidation via the drain handle, pristine-input rebuild, bisect
isolation, typed ``InflightError`` with the normal retry budget.
``overlap=False`` pins the fence-per-bucket behaviour (the A/B baseline).

The generic surface is ``submit(op_name, arrays, ...)`` for any registered
Operation; ``lu``, ``lu_solve``, and ``cholesky`` are typed conveniences
that attach the right partitions and result extraction.
"""

from __future__ import annotations

import itertools
import random
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import Dispatcher, GData, GTask
from ..core.dispatcher import DrainHandle
from ..core.executors import drain_memo_pressure
from ..core.operation import OpRegistry
from ..errors import (
    CircuitOpenError,
    DeadlineExceeded,
    DrainError,
    DrainStalledError,
    InflightError,
    NumericalError,
    RejectedError,
    ResourceExhausted,
    ScheduleVerificationError,
    ServeError,
)
from ..linalg.lu import _unpack
from ..testing import faults

_rid = itertools.count()

#: errors a retry cannot fix — deterministic reproductions (NumericalError,
#: ScheduleVerificationError, single-request ResourceExhausted), already-
#: decided outcomes (DeadlineExceeded, RejectedError), or failures whose
#: retry would race live device state (DrainStalledError: the hung
#: computation still owns its resources, DESIGN.md §14) — failing fast
#: beats burning the retry budget on them
_NON_RETRYABLE = (
    NumericalError,
    DeadlineExceeded,
    RejectedError,
    ScheduleVerificationError,
    DrainStalledError,
    ResourceExhausted,
)


def _is_oom(e: BaseException) -> bool:
    """True iff ``e`` is a device out-of-memory failure: either our typed
    ``ResourceExhausted`` (injected or pre-wrapped) or a runtime error
    carrying XLA's RESOURCE_EXHAUSTED text (``XlaRuntimeError`` is not
    importable on every backend, so the match is textual by design)."""
    if isinstance(e, ResourceExhausted):
        return True
    s = str(e)
    return "RESOURCE_EXHAUSTED" in s or "out of memory" in s.lower()


class ServeFuture:
    """Per-request result handle: resolved at tick time, materialized lazily.

    ``result()`` raises if the request has not been drained yet (call
    ``BatchServer.tick()`` first) and re-raises the typed ``ServeError`` if
    the request failed; ``exception()`` mirrors ``concurrent.futures``:
    the error for a failed request, ``None`` for a resolved one.
    Extraction is lazy: resolving stores a thunk over the request's data
    handles, so a tick never pays per-request de-grid work for results
    nobody reads.
    """

    def __init__(self, rid: int, signature: tuple):
        self.rid = rid
        self.signature = signature
        self._thunk: Optional[Callable[[], Any]] = None
        self._error: Optional[BaseException] = None
        self._value: Any = None
        self._materialized = False

    @property
    def done(self) -> bool:
        return self._thunk is not None or self._error is not None

    def _resolve(self, thunk: Callable[[], Any]) -> None:
        if not self.done:
            self._thunk = thunk

    def _fail(self, error: BaseException) -> None:
        if not self.done:
            self._error = error

    def _pending_error(self) -> RuntimeError:
        op = self.signature[1] if len(self.signature) > 1 else "?"
        return RuntimeError(
            f"request rid={self.rid} (op={op!r}, graph={self.signature[0]!r}) "
            f"is not drained yet — call BatchServer.tick() to serve it"
        )

    def result(self) -> Any:
        if self._error is not None:
            raise self._error
        if self._thunk is None:
            raise self._pending_error()
        if not self._materialized:
            self._value = self._thunk()
            self._materialized = True
            self._thunk = lambda: self._value
        return self._value

    def exception(self) -> Optional[BaseException]:
        """The request's error (a ``ServeError`` subtype), or ``None`` if
        it resolved successfully.  Raises the pending ``RuntimeError`` if
        the request has not been drained yet."""
        if not self.done:
            raise self._pending_error()
        return self._error


@dataclass
class _Pending:
    future: ServeFuture
    op: object
    datas: List[GData]
    extract: Callable[[List[GData]], Any]
    # pristine inputs, kept so a retry can rebuild ``datas`` from scratch —
    # a failed drain may have partially overwritten the in-place results
    # (DESIGN.md §10 donation/retry caveat)
    arrays: List[jnp.ndarray] = field(default_factory=list)
    parts: List[tuple] = field(default_factory=list)
    enqueue_t: float = 0.0
    deadline: Optional[float] = None  # absolute clock time, or None
    retries_left: int = 0
    attempts: int = 0  # failed drain attempts so far
    not_before: int = 0  # earliest tick number eligible (retry backoff)

    def rebuild_datas(self) -> None:
        self.datas = [
            GData(a.shape, partitions=p, dtype=a.dtype, value=a)
            for a, p in zip(self.arrays, self.parts)
        ]


@dataclass
class TickReport:
    """What one ``tick()`` did, per signature bucket and in total."""

    requests: int = 0  # completed this tick: resolved + failed + expired
    buckets: int = 0
    drains: int = 0
    launches: int = 0
    compiles: int = 0
    stacked_drains: int = 0
    memo_hits: int = 0
    memo_misses: int = 0
    per_bucket: List[dict] = field(default_factory=list)
    # failure/latency accounting (DESIGN.md §10)
    resolved: int = 0
    failed: int = 0
    expired: int = 0
    retried: int = 0
    bisected: int = 0  # failed chunks that entered bisection
    pending_after: int = 0
    p50_ms: float = 0.0
    p99_ms: float = 0.0
    # pipeline accounting (DESIGN.md §12)
    host_idle_us: float = 0.0  # host time blocked on device results
    overlap_ratio: float = 1.0  # 1 - host_idle / tick wall time
    # self-healing accounting (DESIGN.md §14)
    breaker_state: str = "closed"  # worst across buckets after this tick
    breaker_trips: int = 0  # breakers that tripped OPEN this tick
    breaker_closes: int = 0  # breakers that re-CLOSED this tick
    breaker_fast_fails: int = 0  # queued requests failed fast (open bucket)
    watchdog_fires: int = 0  # chunks stalled past the watchdog budget
    oom_events: int = 0  # device-OOM launches (each halves a bucket cap)
    degraded_buckets: int = 0  # buckets below full max_batch after this tick
    health: str = "HEALTHY"  # server health after this tick


@dataclass
class _Launched:
    """One dispatched-but-unresolved chunk in the tick pipeline
    (DESIGN.md §12): its programs are in flight, its ``check_finite``
    probes (if any) are dispatched, nothing has been materialized."""

    sig: tuple
    chunk: List[_Pending]
    dispatcher: Dispatcher
    handle: DrainHandle
    probes: Optional[List[list]]  # per member: [(device probe, lane|None)]


#: breaker state ordering for the tick report's worst-across-buckets field
_BREAKER_SEVERITY = {"closed": 0, "half_open": 1, "open": 2}


@dataclass
class _Breaker:
    """Per-signature circuit breaker (DESIGN.md §14).

    ``failures`` counts consecutive isolated drain failures for the bucket;
    ANY successful chunk resets it, so bisecting a single poisoned request
    out of a healthy chunk (successes interleave with the failing halves)
    never trips the breaker — only a bucket that keeps failing does.
    """

    state: str = "closed"  # closed | open | half_open
    failures: int = 0  # consecutive failures (successes reset)
    opened_tick: int = -1  # tick the breaker last tripped OPEN
    round_trips: int = 0  # completed open -> half_open -> closed cycles


@dataclass
class _Degrade:
    """Per-signature degradation level under memory pressure (DESIGN.md
    §14): the bucket's effective batch cap is ``max_batch >> level``.
    ``healthy`` counts OOM-free chunk drains since the last OOM; every
    ``degrade_recovery`` of them steps the level back down one."""

    level: int = 0
    healthy: int = 0


class BatchServer:
    """Queue -> signature buckets -> one stacked drain per bucket per tick.

    ``max_batch`` caps one drain's batch (requests beyond it drain as
    additional chunks in the same tick); it must be a power of two so full
    chunks match compiled-program buckets exactly (a 48-cap would pad
    every full chunk to the 64 bucket — 33% junk lanes forever).

    ``max_pending`` bounds the queue: once reached, ``submit`` sheds per
    ``overload_policy`` — "reject" fails the NEW request's future with
    ``RejectedError``; "drop_oldest" evicts the oldest queued request
    (failing ITS future) and admits the new one.  ``max_retries`` is the
    default per-request retry budget for transient drain failures;
    ``retry_backoff`` scales the exponential tick backoff between
    attempts.  ``check_finite=True`` validates result lanes after every
    drain (NumericalError on the poisoned lanes only).  ``clock`` is
    injectable for deterministic deadline tests.

    ``overlap=True`` (default) pipelines the tick (DESIGN.md §12): all
    bucket programs launch back-to-back and validation is deferred to
    end-of-tick, so the device is never idle between buckets;
    ``overlap=False`` fences each bucket before launching the next — bit-
    identical results, the interleaved-A/B baseline.  ``latency_window``
    bounds the rolling latency history (a ring buffer, so a long-running
    server's percentile cost stays O(window), not O(lifetime)).

    Self-healing (DESIGN.md §14): ``breaker_threshold`` consecutive
    isolated drain failures trip a signature bucket's circuit breaker OPEN
    (queued + incoming requests of that signature fail fast with
    ``CircuitOpenError``); after ``breaker_cooldown`` ticks the breaker
    half-opens and a single probe request decides re-close vs re-open.
    ``watchdog_s`` arms the hung-drain watchdog: a chunk whose fence is
    not ready within the budget fails its futures with
    ``DrainStalledError`` (memo invalidated, no retry — the hung
    computation still owns its device resources).  Device OOM on a launch
    halves the bucket's effective batch cap, sheds drain-memo entries,
    and re-drains split halves; ``degrade_recovery`` OOM-free drains step
    the cap back up.  ``retry_jitter_seed`` arms deterministic full-jitter
    on the retry backoff.  ``health()`` reports HEALTHY / DEGRADED /
    DRAINING; ``drain()`` flushes the queue and rejects new submits.
    """

    def __init__(
        self,
        graph: str = "g2",
        mesh=None,
        max_batch: int = 64,
        max_pending: Optional[int] = None,
        overload_policy: str = "reject",
        max_retries: int = 1,
        retry_backoff: int = 1,
        check_finite: bool = False,
        overlap: bool = True,
        latency_window: int = 4096,
        clock: Callable[[], float] = time.monotonic,
        retry_jitter_seed: Optional[int] = None,
        watchdog_s: Optional[float] = None,
        breaker_threshold: int = 5,
        breaker_cooldown: int = 3,
        degrade_recovery: int = 8,
    ):
        if max_batch < 1 or max_batch & (max_batch - 1):
            raise ValueError(
                f"max_batch must be a power of two >= 1, got {max_batch}"
            )
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        if overload_policy not in ("reject", "drop_oldest"):
            raise ValueError(
                f"overload_policy must be 'reject' or 'drop_oldest', "
                f"got {overload_policy!r}"
            )
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if retry_backoff < 1:
            raise ValueError(f"retry_backoff must be >= 1, got {retry_backoff}")
        if latency_window < 1:
            raise ValueError(
                f"latency_window must be >= 1, got {latency_window}"
            )
        if watchdog_s is not None and watchdog_s <= 0:
            raise ValueError(f"watchdog_s must be > 0, got {watchdog_s}")
        if breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {breaker_threshold}"
            )
        if breaker_cooldown < 1:
            raise ValueError(
                f"breaker_cooldown must be >= 1, got {breaker_cooldown}"
            )
        if degrade_recovery < 1:
            raise ValueError(
                f"degrade_recovery must be >= 1, got {degrade_recovery}"
            )
        self.graph = graph
        self.mesh = mesh
        self.max_batch = max_batch
        self.max_pending = max_pending
        self.overload_policy = overload_policy
        self.max_retries = max_retries
        self.retry_backoff = retry_backoff
        self.check_finite = check_finite
        self.overlap = bool(overlap)
        self._clock = clock
        # self-healing policy + state (DESIGN.md §14)
        self.watchdog_s = watchdog_s
        self.breaker_threshold = breaker_threshold
        self.breaker_cooldown = breaker_cooldown
        self.degrade_recovery = degrade_recovery
        # full-jitter on the exponential retry backoff: None keeps the
        # deterministic schedule; a seed draws each delay uniformly from
        # [1, cap] so synchronized bucket retries don't stampede a
        # recovering device — seedable, hence reproducible in tests
        self._jitter_rng = (
            None if retry_jitter_seed is None else random.Random(retry_jitter_seed)
        )
        self._breakers: Dict[tuple, _Breaker] = {}
        self._degraded: Dict[tuple, _Degrade] = {}
        self._draining = False
        self._queues: Dict[tuple, List[_Pending]] = {}
        # rolling window of resolved-request latencies (ms) for p50/p99 —
        # a bounded ring buffer, NOT an unbounded list (a long-running
        # server would otherwise leak one float per resolved request)
        self._latencies: deque = deque(maxlen=latency_window)
        self._tick_lat: List[float] = []  # this tick's resolved latencies
        self.stats: Dict[str, int] = {
            "requests": 0,
            "ticks": 0,
            "drains": 0,
            "launches": 0,
            "compiles": 0,
            "memo_hits": 0,
            "memo_misses": 0,
            "stacked_drains": 0,
            "resolved": 0,
            "failed": 0,
            "expired": 0,
            "retried": 0,
            "shed": 0,
            "bisected": 0,
            "host_idle_us": 0,
            "breaker_trips": 0,
            "breaker_closes": 0,
            "breaker_fast_fails": 0,
            "watchdog_fires": 0,
            "oom_events": 0,
        }

    # -- request surface -------------------------------------------------------
    def submit(
        self,
        op_name: str,
        arrays: Sequence[jnp.ndarray],
        partitions: Sequence[Tuple[Tuple[int, int], ...]],
        extract: Optional[Callable[[List[GData]], Any]] = None,
        *,
        deadline: Optional[float] = None,
        max_retries: Optional[int] = None,
    ) -> ServeFuture:
        """Queue one request: ``op_name`` applied to ``arrays`` (one root
        task).  ``partitions`` gives each argument's partition levels;
        ``extract(datas)`` builds the result from the drained data handles
        (default: the last argument's value — the written-in-place result
        convention of the linalg families).

        ``deadline`` is seconds from now: a request still queued when it
        expires fails with ``DeadlineExceeded`` instead of being drained.
        ``max_retries`` overrides the server's transient-failure retry
        budget for this request.  Under overload (``max_pending`` reached)
        the request may be shed: the returned future then already carries
        ``RejectedError`` (policy "reject"), or the oldest queued request
        is evicted to make room (policy "drop_oldest")."""
        op = OpRegistry.get(op_name)
        if len(arrays) != len(partitions):
            raise ValueError(
                f"{len(arrays)} arrays vs {len(partitions)} partition specs"
            )
        datas = [
            GData(a.shape, partitions=parts, dtype=a.dtype, value=jnp.asarray(a))
            for a, parts in zip(arrays, partitions)
        ]
        sig = (
            self.graph,
            op.name,
            tuple(
                (d.shape, str(jnp.dtype(d.dtype)), tuple(d.partitions))
                for d in datas
            ),
        )
        fut = ServeFuture(next(_rid), sig)
        self.stats["requests"] += 1
        if self._draining:
            fut._fail(
                RejectedError(
                    f"request rid={fut.rid} rejected: server is draining "
                    f"(graceful shutdown in progress)"
                )
            )
            return fut
        br = self._breakers.get(sig)
        if br is not None and br.state == "open":
            self.stats["breaker_fast_fails"] += 1
            fut._fail(
                CircuitOpenError(
                    f"request rid={fut.rid} ({op.name}): signature bucket "
                    f"circuit-broken after {br.failures} consecutive drain "
                    f"failures; half-opens {self.breaker_cooldown} tick(s) "
                    f"after trip"
                )
            )
            return fut
        if self.max_pending is not None and self.pending() >= self.max_pending:
            if not self._shed_for(fut):
                return fut  # rejected: future already failed
        if extract is None:
            extract = lambda ds: ds[-1].value
        now = self._clock()
        self._queues.setdefault(sig, []).append(
            _Pending(
                fut,
                op,
                datas,
                extract,
                arrays=[d.value for d in datas],
                parts=[d.partitions for d in datas],
                enqueue_t=now,
                deadline=None if deadline is None else now + deadline,
                retries_left=(
                    self.max_retries if max_retries is None else max_retries
                ),
            )
        )
        return fut

    def _shed_for(self, fut: ServeFuture) -> bool:
        """Apply the overload policy; returns True if ``fut`` may enqueue."""
        self.stats["shed"] += 1
        if self.overload_policy == "reject":
            fut._fail(
                RejectedError(
                    f"request rid={fut.rid} rejected: queue at max_pending="
                    f"{self.max_pending} (policy 'reject')"
                )
            )
            return False
        # drop_oldest: evict the globally oldest queued request (min rid —
        # rids are assigned in submission order) and admit the new one
        sig = min(
            (q[0].future.rid, s) for s, q in self._queues.items() if q
        )[1]
        victim = self._queues[sig].pop(0)
        if not self._queues[sig]:
            del self._queues[sig]
        victim.future._fail(
            RejectedError(
                f"request rid={victim.future.rid} dropped: queue at "
                f"max_pending={self.max_pending} (policy 'drop_oldest')"
            )
        )
        return True

    def lu(
        self,
        a,
        partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
        **kw,
    ) -> ServeFuture:
        """Queue a pivot-free LU; resolves to (L, U) unpacked."""
        return self.submit(
            "getrf", [a], [partitions], extract=lambda ds: _unpack(ds[0]), **kw
        )

    def cholesky(
        self,
        a,
        partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
        **kw,
    ) -> ServeFuture:
        """Queue a Cholesky factorization; resolves to the lower factor."""
        return self.submit(
            "potrf",
            [a],
            [partitions],
            extract=lambda ds: jnp.tril(ds[0].value),
            **kw,
        )

    def lu_solve(
        self,
        a,
        b,
        partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
        b_partitions: Tuple[Tuple[int, int], ...] = None,
        **kw,
    ) -> ServeFuture:
        """Queue ``a @ x == b`` (composed factor+solve, one root task);
        resolves to x.  ``b`` may be a vector or a matrix, as in
        ``run_lu_solve``."""
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        if b.shape[0] != a.shape[0]:
            raise ValueError(f"shape mismatch: a {a.shape} vs b {b.shape}")
        vec = b.ndim == 1
        b2 = b[:, None] if vec else b
        if b_partitions is None:
            b_partitions = tuple(
                (pr, 1 if vec else pc) for pr, pc in partitions
            )
        extract = (
            (lambda ds: ds[1].value[:, 0]) if vec else (lambda ds: ds[1].value)
        )
        return self.submit(
            "lu_solve", [a, b2], [partitions, b_partitions], extract=extract,
            **kw,
        )

    # -- serving loop ----------------------------------------------------------
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p99 (ms) over the rolling resolved-request latency window."""
        if not self._latencies:
            return {"p50_ms": 0.0, "p99_ms": 0.0, "samples": 0}
        arr = np.asarray(self._latencies)
        return {
            "p50_ms": float(np.percentile(arr, 50)),
            "p99_ms": float(np.percentile(arr, 99)),
            "samples": len(arr),
        }

    def tick(self) -> TickReport:
        """Drain every eligible queued request: one stacked drain per
        signature bucket (chunked at ``max_batch``), resolve the futures.

        Pipelined (DESIGN.md §12): launch-all-buckets, deferred-validate,
        resolve.  With ``overlap`` on, every chunk's program (and its
        eagerly dispatched ``check_finite`` probes) is launched before ANY
        result is materialized; the single deferred-validation pass at the
        end of the tick is the only point the host may block, and only
        when ``check_finite`` needs the probe values.  With ``overlap``
        off each chunk is finalized (fenced) before the next launches.

        Failure containment (DESIGN.md §10): the serving loop never
        unwinds.  Deadline-expired requests fail with ``DeadlineExceeded``
        without draining; a chunk whose drain raises is bisected to
        isolate the culprits (healthy requests resolve in this same tick);
        isolated transient failures consume the request's retry budget and
        re-queue IN FIFO ORDER with exponential tick backoff, carrying
        their retry count; exhausted or deterministic failures land on the
        future as a typed ``ServeError``.  In-flight failures (overlap on,
        after dispatch) follow the same path with ``InflightError`` and
        drain-memo invalidation — identical semantics, deferred detection."""
        tick_no = self.stats["ticks"]
        self.stats["ticks"] += 1
        t_tick = time.perf_counter()
        now = self._clock()
        report = TickReport()
        self._tick_lat = []
        # breaker cooldown sweep: an OPEN breaker whose cooldown has
        # elapsed half-opens — one probe request (below) decides its fate
        for br in self._breakers.values():
            if (
                br.state == "open"
                and tick_no >= br.opened_tick + self.breaker_cooldown
            ):
                br.state = "half_open"
        queues, self._queues = self._queues, {}
        held: Dict[tuple, List[_Pending]] = {}
        ready: Dict[tuple, List[_Pending]] = {}
        for sig, pend in queues.items():
            br = self._breakers.get(sig)
            if br is not None and br.state == "open":
                # fail-fast the whole bucket: no drain, no retry budget
                for p in pend:
                    report.breaker_fast_fails += 1
                    self._finish_fail(
                        p,
                        CircuitOpenError(
                            f"request rid={p.future.rid} ({p.op.name}): "
                            f"signature bucket circuit-broken"
                        ),
                        report,
                    )
                continue
            probe_taken = False
            for p in pend:
                if p.deadline is not None and now >= p.deadline:
                    self._finish_fail(
                        p,
                        DeadlineExceeded(
                            f"request rid={p.future.rid} ({p.op.name}) "
                            f"deadline expired before drain"
                        ),
                        report,
                        expired=True,
                    )
                elif p.not_before > tick_no:
                    held.setdefault(sig, []).append(p)  # retry backoff
                elif br is not None and br.state == "half_open" and probe_taken:
                    held.setdefault(sig, []).append(p)  # behind the probe
                else:
                    ready.setdefault(sig, []).append(p)
                    probe_taken = True  # half-open: FIRST ready = the probe
        report.buckets = len(ready)
        retried: Dict[tuple, List[_Pending]] = {}
        # phase 1 — launch: every chunk's program dispatches back-to-back;
        # with overlap on, no device fence separates the launches
        launched: Optional[List[_Launched]] = [] if self.overlap else None
        for sig, pend in ready.items():
            cap = self._bucket_cap(sig)  # degraded buckets drain smaller
            for lo in range(0, len(pend), cap):
                self._launch_chunk(
                    sig, pend[lo : lo + cap], report, retried,
                    tick_no, launched,
                )
        # phase 2/3 — deferred-validate + resolve (end-of-tick): the only
        # point this tick may block on the device, and only for probes
        if launched:
            for item in launched:
                self._finalize_chunk(item, report, retried, tick_no)
        # re-queue held + retried requests at the FRONT of their buckets,
        # merged by rid (== global FIFO submission order): they are older
        # than anything submitted after this tick
        for sig in set(held) | set(retried):
            front = sorted(
                held.get(sig, []) + retried.get(sig, []),
                key=lambda p: p.future.rid,
            )
            self._queues[sig] = front + self._queues.get(sig, [])
        report.pending_after = self.pending()
        wall = time.perf_counter() - t_tick
        if wall > 0:
            report.overlap_ratio = max(
                0.0, 1.0 - report.host_idle_us / (wall * 1e6)
            )
        report.degraded_buckets = sum(
            1 for deg in self._degraded.values() if deg.level > 0
        )
        report.breaker_state = max(
            (br.state for br in self._breakers.values()),
            key=_BREAKER_SEVERITY.__getitem__,
            default="closed",
        )
        report.health = self.health()
        for k in (
            "drains",
            "launches",
            "compiles",
            "memo_hits",
            "memo_misses",
            "stacked_drains",
            "resolved",
            "failed",
            "expired",
            "retried",
            "bisected",
            "breaker_trips",
            "breaker_closes",
            "breaker_fast_fails",
            "watchdog_fires",
            "oom_events",
        ):
            self.stats[k] += getattr(report, k)
        self.stats["host_idle_us"] += int(report.host_idle_us)
        return report

    # -- chunk serving with lane isolation (DESIGN.md §10, §12) ----------------
    def _launch_chunk(
        self,
        sig: tuple,
        chunk: List[_Pending],
        report: TickReport,
        retried: Dict[tuple, List[_Pending]],
        tick_no: int,
        launched: Optional[List[_Launched]],
    ) -> None:
        """Dispatch one chunk's drain (and its deferred-validation probes).

        With ``launched`` a list (overlap on) the chunk joins the tick
        pipeline and is finalized at end-of-tick; with ``launched=None``
        it is finalized — fenced and resolved — immediately."""
        try:
            d, handle = self._drain_chunk(chunk)
        except Exception as e:  # noqa: BLE001 — typed at the future boundary
            if _is_oom(e):
                # pressure, not poison (DESIGN.md §14): halve the bucket's
                # cap, shed memo entries, and re-drain as split halves —
                # no retry budget consumed, no breaker failure noted
                self._oom_degrade(sig, report)
                if len(chunk) > 1:
                    mid = len(chunk) // 2
                    self._launch_chunk(
                        sig, chunk[:mid], report, retried, tick_no, launched
                    )
                    self._launch_chunk(
                        sig, chunk[mid:], report, retried, tick_no, launched
                    )
                    return
                # a SINGLE request that still OOMs reproduces at any size:
                # typed terminal failure, never retried
                p = chunk[0]
                if isinstance(e, ResourceExhausted):
                    err = e
                else:
                    err = ResourceExhausted(
                        f"request rid={p.future.rid} ({p.op.name}) OOMs "
                        f"even as a singleton drain: {e}"
                    )
                    err.__cause__ = e
                self._finish_fail(p, err, report)
                return
            if len(chunk) == 1:
                self._fail_or_retry(sig, chunk[0], e, report, retried, tick_no)
                return
            # bisect: pow2 halves hit the drain memo's bucket programs, so
            # isolating k culprits in a chunk of C costs O(k log C) cheap
            # re-drains, not C singleton drains
            report.bisected += 1
            mid = len(chunk) // 2
            self._launch_chunk(
                sig, chunk[:mid], report, retried, tick_no, launched
            )
            self._launch_chunk(
                sig, chunk[mid:], report, retried, tick_no, launched
            )
            return
        probes = (
            self._dispatch_finite_probes(chunk) if self.check_finite else None
        )
        item = _Launched(sig, chunk, d, handle, probes)
        if launched is not None:
            launched.append(item)
        else:
            self._finalize_chunk(item, report, retried, tick_no)

    def _finalize_chunk(
        self,
        item: _Launched,
        report: TickReport,
        retried: Dict[tuple, List[_Pending]],
        tick_no: int,
    ) -> None:
        """Deferred-validate and resolve one launched chunk.

        The ONLY blocking step of a tick: materializing the ``check_finite``
        probe values (skipped entirely when validation is off — resolution
        is then fence-free and results stay lazy on their futures).  A
        failure here is an IN-FLIGHT failure (DESIGN.md §12): the programs
        were dispatched, so every member's data is suspect — the drain
        handle's memo entries are invalidated, members rebuild from their
        pristine inputs, and isolation proceeds by synchronous
        (immediately finalized) half re-drains, typed ``InflightError`` at
        the single-request leaf."""
        chunk = item.chunk
        if self.watchdog_s is not None and not self._watchdog_fence(
            item, report, retried, tick_no
        ):
            return  # stalled: futures failed, memo invalidated
        try:
            faults.fire(
                "drain.inflight",
                rids=[p.future.rid for p in chunk],
                op=chunk[0].op.name,
                size=len(chunk),
                pending=not item.handle.is_ready(),
            )
            bad = (
                self._materialize_probes(item.probes, report)
                if item.probes is not None
                else ()
            )
        except Exception as e:  # noqa: BLE001 — typed at the future boundary
            item.handle.invalidate_memo()
            if len(chunk) == 1:
                self._fail_or_retry(
                    item.sig, chunk[0], e, report, retried, tick_no,
                    wrap=InflightError,
                )
                return
            report.bisected += 1
            for p in chunk:
                p.rebuild_datas()
            mid = len(chunk) // 2
            self._launch_chunk(
                item.sig, chunk[:mid], report, retried, tick_no, None
            )
            self._launch_chunk(
                item.sig, chunk[mid:], report, retried, tick_no, None
            )
            return
        self._note_chunk_success(item.sig, report)
        now = self._clock()
        for i, p in enumerate(chunk):
            if i in bad:
                self._finish_fail(
                    p,
                    NumericalError(
                        f"request rid={p.future.rid} ({p.op.name}): "
                        f"non-finite values in result lane"
                    ),
                    report,
                )
                continue
            datas, extract = p.datas, p.extract
            p.future._resolve(lambda ds=datas, ex=extract: ex(ds))
            report.resolved += 1
            report.requests += 1
            self._record_latency(report, (now - p.enqueue_t) * 1e3)
        d = item.dispatcher
        est = d.executor.stats
        bucket_stats = {
            "signature": item.sig[1],
            "requests": len(chunk),
            "launches": int(est.get("launches", 0)),
            "compiles": int(est.get("compiles", 0)),
            "stacked": int(d.stats["stacked_drains"]),
            "memo_hits": int(d.stats["memo_hits"]),
            "memo_misses": int(d.stats["memo_misses"]),
        }
        report.per_bucket.append(bucket_stats)
        report.drains += 1
        report.launches += bucket_stats["launches"]
        report.compiles += bucket_stats["compiles"]
        report.stacked_drains += bucket_stats["stacked"]
        report.memo_hits += bucket_stats["memo_hits"]
        report.memo_misses += bucket_stats["memo_misses"]

    def _drain_chunk(
        self, chunk: List[_Pending]
    ) -> Tuple[Dispatcher, DrainHandle]:
        faults.fire(
            "serve.drain",
            rids=[p.future.rid for p in chunk],
            op=chunk[0].op.name,
            size=len(chunk),
        )
        d = Dispatcher(graph=self.graph, mesh=self.mesh)
        for p in chunk:
            d.submit_task(
                GTask(p.op, None, [dd.root_view() for dd in p.datas])
            )
        return d, d.run_async()

    def _dispatch_finite_probes(self, chunk: List[_Pending]) -> List[list]:
        """Dispatch (without blocking) the chunk's finiteness reduces.

        Lane-isolated and cheap: members of a stacked drain share one
        ``StackedEpoch``, so finiteness is ONE fused all-reduce over the
        ``(B, nr, nc, br, bc)`` epoch grid yielding a per-lane mask —
        nothing is de-gridded, healthy lanes stay lazily extracted.  The
        reduces are dispatched IMMEDIATELY after the chunk's own launch
        (before any later drain could donate this epoch's grid forward,
        DESIGN.md §12) but materialized only at the deferred-validation
        fence in ``_finalize_chunk``."""
        epoch_probes: Dict[int, jnp.ndarray] = {}
        probes: List[list] = []
        for p in chunk:
            member = []
            for dd in p.datas:
                lane = dd.lane
                if lane is not None:
                    ep, li = lane
                    probe = epoch_probes.get(id(ep))
                    if probe is None:
                        probe = jnp.isfinite(ep.grid).all(axis=(1, 2, 3, 4))
                        epoch_probes[id(ep)] = probe
                    member.append((probe, li))
                elif dd.in_grid_epoch:
                    member.append((jnp.isfinite(dd.grid).all(), None))
                elif dd.has_value:
                    member.append((jnp.isfinite(dd.value).all(), None))
            probes.append(member)
        return probes

    def _materialize_probes(
        self, probes: List[list], report: TickReport
    ) -> set:
        """Block on the deferred finiteness probes; returns the indices of
        chunk members with any non-finite result datum.  The blocked time
        is the tick's ``host_idle_us`` contribution — with overlap on it is
        paid ONCE, after every bucket has launched, instead of between
        buckets.  Device-side execution failures surface here (the probes
        depend on the program outputs), which is exactly the in-flight
        failure path of ``_finalize_chunk``."""
        t0 = time.perf_counter()
        host: Dict[int, np.ndarray] = {}
        bad = set()
        for i, member in enumerate(probes):
            for probe, li in member:
                arr = host.get(id(probe))
                if arr is None:
                    arr = np.asarray(probe)
                    host[id(probe)] = arr
                ok = bool(arr[li]) if li is not None else bool(arr)
                if not ok:
                    bad.add(i)
                    break
        report.host_idle_us += (time.perf_counter() - t0) * 1e6
        return bad

    # -- self-healing: watchdog, breakers, degradation (DESIGN.md §14) ---------
    def _watchdog_fence(
        self,
        item: _Launched,
        report: TickReport,
        retried: Dict[tuple, List[_Pending]],
        tick_no: int,
    ) -> bool:
        """Bounded readiness fence over one launched chunk; True iff the
        chunk became ready within ``watchdog_s``.

        XLA fences are not interruptible-by-value, so the budget is a
        polling deadline over ``handle.is_ready()``.  On timeout the
        drain's memo keys are invalidated (this execution can no longer
        vouch for them) and every member future fails with
        ``DrainStalledError`` — no bisect (the whole fence is stalled, not
        one request) and no retry (a re-drain would queue behind the very
        computation that stalled; only process restart reclaims the
        device, which is the honest limit of a host-side watchdog)."""
        chunk = item.chunk
        t0 = time.perf_counter()
        deadline = time.monotonic() + self.watchdog_s
        stalled = False
        try:
            # the stall site fires BEFORE the first readiness poll, so an
            # injected delay_s fault deterministically blows the budget
            faults.fire(
                "drain.stall",
                rids=[p.future.rid for p in chunk],
                op=chunk[0].op.name,
                size=len(chunk),
            )
            while not item.handle.is_ready():
                if time.monotonic() >= deadline:
                    stalled = True
                    break
                time.sleep(min(0.001, self.watchdog_s / 10))
            stalled = stalled or time.monotonic() >= deadline
        except Exception as e:  # noqa: BLE001 — a raising stall fault
            report.host_idle_us += (time.perf_counter() - t0) * 1e6
            item.handle.invalidate_memo()
            for p in chunk:
                self._fail_or_retry(
                    item.sig, p, e, report, retried, tick_no,
                    wrap=InflightError,
                )
            return False
        report.host_idle_us += (time.perf_counter() - t0) * 1e6
        if not stalled:
            return True
        report.watchdog_fires += 1
        item.handle.invalidate_memo()
        self._note_chunk_failure(item.sig, tick_no, report)
        for p in chunk:
            self._finish_fail(
                p,
                DrainStalledError(
                    f"request rid={p.future.rid} ({p.op.name}): drain fence "
                    f"not ready within the {self.watchdog_s:.3f}s watchdog "
                    f"budget ({len(chunk)}-request chunk)"
                ),
                report,
            )
        return False

    def _bucket_cap(self, sig: tuple) -> int:
        """The bucket's effective batch cap: ``max_batch`` halved once per
        degradation level (still a power of two), floored at 1."""
        deg = self._degraded.get(sig)
        if deg is None:
            return self.max_batch
        return max(1, self.max_batch >> deg.level)

    def _oom_degrade(self, sig: tuple, report: TickReport) -> None:
        """One device-OOM launch: halve the bucket's cap (until 1) and
        shed half the drain memo — compiled programs for the old, larger
        chunk sizes are exactly the entries pressure wants back."""
        report.oom_events += 1
        deg = self._degraded.setdefault(sig, _Degrade())
        if (self.max_batch >> deg.level) > 1:
            deg.level += 1
        deg.healthy = 0
        drain_memo_pressure()

    def _note_chunk_failure(
        self, sig: tuple, tick_no: int, report: TickReport
    ) -> None:
        """Account one isolated drain failure against the bucket's breaker.

        Called at the single-request isolation leaf (and for a stalled
        chunk), NOT at every bisect level — so one poisoned request in a
        healthy chunk contributes one failure per tick, and its healthy
        bucket-mates' successes reset the count before it can accumulate.
        A failure during HALF_OPEN (the probe failed) re-trips immediately.
        """
        br = self._breakers.setdefault(sig, _Breaker())
        br.failures += 1
        if br.state == "half_open" or (
            br.state == "closed" and br.failures >= self.breaker_threshold
        ):
            br.state = "open"
            br.opened_tick = tick_no
            report.breaker_trips += 1

    def _note_chunk_success(self, sig: tuple, report: TickReport) -> None:
        """One chunk drained clean: reset the breaker's failure count
        (closing it if open/half-open — the probe succeeded) and advance
        the bucket's degradation recovery."""
        br = self._breakers.get(sig)
        if br is not None:
            br.failures = 0
            if br.state != "closed":
                br.state = "closed"
                br.round_trips += 1
                report.breaker_closes += 1
        deg = self._degraded.get(sig)
        if deg is not None:
            deg.healthy += 1
            if deg.healthy >= self.degrade_recovery:
                deg.level -= 1
                deg.healthy = 0
                if deg.level <= 0:
                    del self._degraded[sig]

    # -- health + graceful shutdown (DESIGN.md §14) ----------------------------
    def health(self) -> str:
        """Server health: DRAINING once ``drain()`` started, DEGRADED while
        any breaker is not closed or any bucket runs below its full batch
        cap, HEALTHY otherwise."""
        if self._draining:
            return "DRAINING"
        if any(br.state != "closed" for br in self._breakers.values()) or any(
            deg.level > 0 for deg in self._degraded.values()
        ):
            return "DEGRADED"
        return "HEALTHY"

    def breakers(self) -> Dict[tuple, Dict[str, Any]]:
        """Per-signature breaker snapshot (state, consecutive failures,
        completed open->closed round trips) for introspection and gates."""
        return {
            sig: {
                "state": br.state,
                "failures": br.failures,
                "round_trips": br.round_trips,
            }
            for sig, br in self._breakers.items()
        }

    def breaker_round_trips(self) -> int:
        """Total completed open -> half_open -> closed breaker cycles."""
        return sum(br.round_trips for br in self._breakers.values())

    def drain(self, max_ticks: int = 1024) -> List[TickReport]:
        """Graceful shutdown: reject all new submits, then tick until the
        queue (including backoff-held retries) is flushed.  Every queued
        future ends resolved or typed-failed.  ``max_ticks`` bounds the
        flush (a safety rail — retry budgets are finite, so the queue
        drains well before it); returns the per-tick reports."""
        self._draining = True
        reports: List[TickReport] = []
        while self.pending() and len(reports) < max_ticks:
            reports.append(self.tick())
        return reports

    def _fail_or_retry(
        self,
        sig: tuple,
        p: _Pending,
        e: Exception,
        report: TickReport,
        retried: Dict[tuple, List[_Pending]],
        tick_no: int,
        wrap: type = DrainError,
    ) -> None:
        """One isolated failing request: consume retry budget or fail typed.

        ``wrap`` types the terminal error for non-``ServeError`` causes:
        ``DrainError`` for synchronous drain failures, ``InflightError``
        when the failure surfaced at deferred (in-flight) resolution.
        Every call is one isolated drain failure, so it also feeds the
        bucket's breaker (DESIGN.md §14)."""
        self._note_chunk_failure(sig, tick_no, report)
        if not isinstance(e, _NON_RETRYABLE) and p.retries_left > 0:
            p.retries_left -= 1
            p.attempts += 1
            cap = self.retry_backoff * (2 ** (p.attempts - 1))
            # full jitter (armed via retry_jitter_seed): uniform in [1, cap]
            # instead of the deterministic cap, so a bucket's worth of
            # synchronized retries spreads across the backoff window
            delay = cap if self._jitter_rng is None else self._jitter_rng.randint(1, cap)
            p.not_before = tick_no + delay
            p.rebuild_datas()  # the failed drain may have mutated them
            retried.setdefault(sig, []).append(p)
            report.retried += 1
            return
        if isinstance(e, ServeError):
            err = e
        else:
            err = wrap(
                f"request rid={p.future.rid} ({p.op.name}) drain failed "
                f"after {p.attempts + 1} attempt(s): {e}"
            )
            err.__cause__ = e
        self._finish_fail(p, err, report)

    def _finish_fail(
        self,
        p: _Pending,
        err: ServeError,
        report: TickReport,
        expired: bool = False,
    ) -> None:
        p.future._fail(err)
        report.requests += 1
        if expired:
            report.expired += 1
        else:
            report.failed += 1

    def _record_latency(self, report: TickReport, ms: float) -> None:
        # the rolling window is a maxlen deque: appends evict the oldest
        # sample in O(1), so a long-running server never accumulates
        self._latencies.append(ms)
        # per-tick percentiles over THIS tick's resolved set, tracked
        # separately (the rolling window may already have evicted part of
        # a large tick's own samples)
        self._tick_lat.append(ms)
        arr = np.asarray(self._tick_lat)
        report.p50_ms = float(np.percentile(arr, 50))
        report.p99_ms = float(np.percentile(arr, 99))
