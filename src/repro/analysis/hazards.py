"""Hazard analysis: independent re-derivation of the dependence DAG.

The drain-memo, fusion, and stacking machinery all rest on one claim: the
``DepTracker`` edge DAG orders every true data dependence of a scope.  A
missing edge is a *race* — two conflicting accesses the scheduler is free
to reorder or fuse into one launch, producing plausible-but-wrong floats
that no end-to-end test reliably catches.  This pass re-derives the ground
truth from first principles and cross-checks the tracker (DESIGN.md §11):

1. Recompute every task's block-level read/write footprint straight from
   ``GTask.accesses()`` — (datum, region, level, access mode), nothing
   shared with the tracker's incremental last-writer/readers state.
2. Re-derive the full conflict relation by exact rectangle overlap: a pair
   of accesses conflicts iff the regions of the SAME datum overlap and at
   least one writes (RAW / WAR / WAW by program order and modes).
3. Cross-check: every conflicting pair must be *ordered* by the tracker
   DAG — connected by a path in program-order direction (direct edges are
   not required: the tracker legitimately drops transitively implied
   edges, e.g. WAW chains through the last writer).  A conflicting pair
   with no path is a RACE -> ``ScheduleVerificationError``.
4. Converse check: every tracker edge must be implied by some conflict
   path.  A tracker edge between truly independent tasks is not a
   correctness bug but *lost parallelism* — the fusion pass will refuse
   legal merges — reported as a ``LostParallelismWarning``.

The pass is deliberately O(accesses^2) per datum (exact, no uniform-grid
fast path): its job is to distrust every shortcut the production tracker
takes.  Verify mode only runs it on non-replay drains, where Python task
expansion dominates anyway; replayed drains re-execute a verified capture
and pay nothing (DESIGN.md §11 cost model).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

from ..core.task import GTask
from ..core.versioning import TaskDag
from ..errors import ScheduleVerificationError


class LostParallelismWarning(UserWarning):
    """A tracker edge orders two provably independent tasks (spurious
    dependence): correct but pessimal — fusion/slotting lose parallelism."""


@dataclass(frozen=True)
class Conflict:
    """One true dependence: ``pred`` must run before ``succ``."""

    kind: str  # "RAW" | "WAR" | "WAW"
    pred: int  # task id, earlier in program order
    succ: int  # task id, later in program order
    data_name: str
    region: Tuple[int, int, int, int]  # succ-side (r0, c0, rows, cols)


@dataclass
class HazardReport:
    """Outcome of one scope's hazard cross-check."""

    n_tasks: int
    n_conflicts: int
    races: List[Conflict] = field(default_factory=list)
    spurious: List[Tuple[int, int]] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.races


def _conflict_kind(pred_writes: bool, succ_writes: bool) -> str:
    if pred_writes:
        # successor reads after the write (RAW) or overwrites it (WAW)
        return "WAW" if succ_writes else "RAW"
    return "WAR"  # successor writes over a region the predecessor read


def recompute_conflicts(tasks: Sequence[GTask]) -> List[Conflict]:
    """The ground-truth dependence relation of a scope, from footprints.

    Program order is task submission order (ascending ``GTask.id`` — ids
    are allocated monotonically at construction, which the dispatcher does
    in submission order).  For each datum, every ordered pair of accesses
    with overlapping regions and at least one write is a dependence.
    Within one task, multiple accesses to the same datum collapse to the
    strongest mode per region pair (a task never races itself).
    """
    order = sorted(tasks, key=lambda t: t.id)
    # datum id -> [(task id, region, writes, data name)] in program order
    per_datum: Dict[int, List[tuple]] = {}
    for t in order:
        for view, mode in t.accesses():
            per_datum.setdefault(view.data.id, []).append(
                (t.id, view.region, mode.writes, view.data.name)
            )
    conflicts: List[Conflict] = []
    seen = set()
    for accesses in per_datum.values():
        for j in range(len(accesses)):
            tj, rj, wj, name = accesses[j]
            for i in range(j):
                ti, ri, wi, _ = accesses[i]
                if ti == tj or not (wi or wj):
                    continue
                if not ri.overlaps(rj):
                    continue
                kind = _conflict_kind(wi, wj)
                key = (ti, tj, kind)
                if key in seen:
                    continue
                seen.add(key)
                conflicts.append(
                    Conflict(
                        kind, ti, tj, name, (rj.r0, rj.c0, rj.rows, rj.cols)
                    )
                )
    return conflicts


def analyze_hazards(
    tasks: Sequence[GTask],
    dag: TaskDag,
    raise_on_race: bool = True,
    warn_on_spurious: bool = True,
) -> HazardReport:
    """Cross-check ``dag`` (the tracker's edge DAG) against the recomputed
    ground truth; see the module docstring for the two directions.

    Raises ``ScheduleVerificationError`` on the first detected race set
    (all races are gathered into one message) unless ``raise_on_race`` is
    False; spurious edges warn ``LostParallelismWarning`` and are returned
    on the report either way.
    """
    conflicts = recompute_conflicts(tasks)
    report = HazardReport(n_tasks=len(tasks), n_conflicts=len(conflicts))

    # direction 1: every true dependence must be a tracker path
    for c in conflicts:
        if not dag.path(c.pred, c.succ):
            report.races.append(c)

    # direction 2: every tracker edge must be implied by a conflict path.
    # Build the true DAG from the conflict pairs and reuse TaskDag's bitset
    # reachability — the same machinery, fed independent inputs.
    true_edges: Dict[int, set] = {}
    true_preds: Dict[int, set] = {}
    for c in conflicts:
        true_edges.setdefault(c.pred, set()).add(c.succ)
        true_preds.setdefault(c.succ, set()).add(c.pred)
    true_dag = TaskDag(dict(dag.tasks), true_edges, true_preds)
    for pred, succs in dag.edges.items():
        for succ in succs:
            if not true_dag.path(pred, succ):
                report.spurious.append((pred, succ))

    if report.spurious and warn_on_spurious:
        pairs = ", ".join(f"{a}->{b}" for a, b in report.spurious[:5])
        warnings.warn(
            f"tracker orders {len(report.spurious)} independent task "
            f"pair(s) ({pairs}{'...' if len(report.spurious) > 5 else ''}): "
            f"correct but loses parallelism",
            LostParallelismWarning,
            stacklevel=2,
        )
    if report.races and raise_on_race:
        ops = dag.tasks
        lines = []
        for c in report.races[:5]:
            po = ops[c.pred].op.name if c.pred in ops else "?"
            so = ops[c.succ].op.name if c.succ in ops else "?"
            lines.append(
                f"{c.kind} on {c.data_name}{list(c.region)}: "
                f"task {c.pred} ({po}) -> task {c.succ} ({so}) unordered"
            )
        first = report.races[0]
        raise ScheduleVerificationError(
            "hazards",
            f"{len(report.races)} race(s) — dependence(s) missing from the "
            f"versioning DAG: " + "; ".join(lines),
            pair=(first.pred, first.succ),
        )
    return report


__all__ = [
    "Conflict",
    "HazardReport",
    "LostParallelismWarning",
    "analyze_hazards",
    "recompute_conflicts",
]
