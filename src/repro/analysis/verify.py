"""Schedule/plan verifier: machine-checked legality of a ``SchedulePlan``.

The WaveProgram scheduling pass (DESIGN.md §2) states its invariants in
prose; this module proves them for every concrete plan (DESIGN.md §11):

    V1  Every fused group's members are mutually independent — no path in
        the scope's ``TaskDag`` connects two tasks sharing one launch.
    V2  Slot order is a valid topological order of the quotient DAG: every
        predecessor of a task sits in a strictly earlier issue slot.
    V3  No two same-slot groups touch overlapping grid blocks with a write
        involved: writes are pairwise block-disjoint across a slot, and no
        group reads a block a slot-mate writes (in-slot trace order is a
        free lookahead choice, so any such overlap would be order-dependent).
    V4  A group's scatter index vector contains no duplicate write slots:
        two rows of one ``.at[idx].set`` landing on the same (root, block)
        would silently last-write-win.
    V5  Stacked (B-lane) programs keep lanes block-disjoint: no data handle
        appears in two lanes or two root slots (``verify_stacked_members``).

Verdicts are cached on the plan's structural key *plus* a digest of its
block-index arrays (the structural key deliberately excludes indices —
they are traced arguments — but V3/V4 legality depends on them), so a
structurally repeated drain verifies once; memo replays never reach the
verifier at all (DESIGN.md §11 cost model).
"""

from __future__ import annotations

import hashlib
from typing import Dict, Iterator, List, Sequence, Tuple

from ..core.task import GTask
from ..core.versioning import TaskDag
from ..errors import ScheduleVerificationError

# verified-plan verdict cache: structural key + index digest -> True.
# Only successful verdicts are cached (a failing plan must keep failing
# loudly); process-global like the compiled-program cache.
_VERIFIED: Dict[tuple, bool] = {}
_STATS = {"verified": 0, "cache_hits": 0}


def verifier_stats() -> Dict[str, int]:
    """Process-global verify counters (bench/CI observability)."""
    return dict(_STATS, cached=len(_VERIFIED))


def clear_verified_cache() -> None:
    _VERIFIED.clear()


def _plan_groups_with_members(plan) -> Iterator[tuple]:
    """Yield (slot_idx, group, member tasks) — ``plan.tasks`` is flat in
    exactly the order the planner appended groups, so group boundaries are
    recovered from each group's size."""
    pos = 0
    for si, slot in enumerate(plan.slots):
        for g in slot:
            members = plan.tasks[pos : pos + g.size]
            pos += g.size
            yield si, g, members


def _group_blocks(g, arg: int) -> List[Tuple[int, int, int]]:
    """(root slot, block row, block col) rows of one argument's index
    vector, resolved through the group's per-segment root slots."""
    rows: List[Tuple[int, int, int]] = []
    idx = g.idxs[arg]
    off = 0
    for seg_slots, size in g.segments:
        root = seg_slots[arg]
        for k in range(off, off + size):
            rows.append((root, int(idx[k, 0]), int(idx[k, 1])))
        off += size
    return rows


def _idx_digest(plan) -> bytes:
    h = hashlib.sha1()
    for g in plan.groups():
        for ix in g.idxs:
            h.update(ix.tobytes())
    return h.digest()


def verify_plan(plan, dag: TaskDag, cache: bool = True) -> bool:
    """Prove V1–V4 for ``plan`` against its scope's ``dag``.

    Returns True (possibly from the verdict cache); raises
    ``ScheduleVerificationError`` naming the violated invariant and the
    offending task pair / block coordinate otherwise.
    """
    key = None
    if cache:
        key = (plan.key, _idx_digest(plan))
        if key in _VERIFIED:
            _STATS["cache_hits"] += 1
            return True

    owner: Dict[int, Tuple[int, int]] = {}  # task id -> (slot, group index)
    groups = list(_plan_groups_with_members(plan))
    for gi, (si, g, members) in enumerate(groups):
        for t in members:
            owner[t.id] = (si, gi)

    # V1: intra-group independence (both directions; ids are monotone in
    # program order but the check must not assume that)
    for _, g, members in groups:
        for j in range(len(members)):
            for i in range(j):
                a, b = members[i], members[j]
                if dag.path(a.id, b.id) or dag.path(b.id, a.id):
                    raise ScheduleVerificationError(
                        "verify_plan.group_independence",
                        f"fused {g.op.name} group contains dependent tasks "
                        f"— one launch cannot order them",
                        pair=(a.id, b.id),
                    )

    # V2: slot order topologically valid against the task DAG
    for si, g, members in groups:
        for t in members:
            for p in dag.preds.get(t.id, ()):
                if p not in owner:
                    continue  # predecessor outside this plan's waves
                ps, _ = owner[p]
                if ps >= si:
                    raise ScheduleVerificationError(
                        "verify_plan.slot_order",
                        f"task {t.id} ({g.op.name}) issued at slot {si} "
                        f"but its predecessor sits at slot {ps}",
                        pair=(p, t.id),
                    )

    # V3 + V4: block-level read/write sets per slot.  All arguments count
    # as reads (a pure-WRITE overlap is a WAW and is caught by the write
    # sets either way), write_pos arguments as writes.
    for si, slot_groups in enumerate(plan.slots):
        seen_writes: Dict[Tuple[int, int, int], int] = {}  # block -> group
        reads_per_group: List[set] = []
        writes_per_group: List[set] = []
        for g in slot_groups:
            reads = set()
            writes = set()
            for a in range(len(g.idxs)):
                rows = _group_blocks(g, a)
                reads.update(rows)
                if a in g.write_pos:
                    if len(set(rows)) != len(rows):
                        dup = [r for r in rows if rows.count(r) > 1][0]
                        raise ScheduleVerificationError(
                            "verify_plan.duplicate_write",
                            f"{g.op.name} group scatters twice to root "
                            f"{dup[0]} block ({dup[1]},{dup[2]}) in one "
                            f"launch (last-write-wins would be silent)",
                        )
                    writes.update(rows)
            reads_per_group.append(reads)
            writes_per_group.append(writes)
        for gi, g in enumerate(slot_groups):
            for block in writes_per_group[gi]:
                prev = seen_writes.get(block)
                if prev is not None:
                    raise ScheduleVerificationError(
                        "verify_plan.slot_write_overlap",
                        f"slot {si}: {slot_groups[prev].op.name} and "
                        f"{g.op.name} groups both write root {block[0]} "
                        f"block ({block[1]},{block[2]})",
                    )
                seen_writes[block] = gi
        for gi, g in enumerate(slot_groups):
            for gj, other in enumerate(slot_groups):
                if gi == gj:
                    continue
                clash = reads_per_group[gi] & writes_per_group[gj]
                if clash:
                    block = sorted(clash)[0]
                    raise ScheduleVerificationError(
                        "verify_plan.slot_read_write_overlap",
                        f"slot {si}: {g.op.name} group reads root "
                        f"{block[0]} block ({block[1]},{block[2]}) that "
                        f"the {other.op.name} group writes in the same "
                        f"slot (in-slot order is unconstrained)",
                    )

    _STATS["verified"] += 1
    if key is not None:
        _VERIFIED[key] = True
    return True


def verify_stacked_members(member_lists: Sequence[Sequence]) -> bool:
    """V5: lanes of a stacked drain must be block-disjoint, which at the
    whole-root granularity the stacker uses means no ``GData`` handle may
    appear in two lanes or in two root slots — an aliased lane would make
    two lanes scatter into one buffer.
    """
    seen: Dict[int, Tuple[int, int]] = {}
    for slot, members in enumerate(member_lists):
        for lane, d in enumerate(members):
            prev = seen.get(d.id)
            if prev is not None:
                raise ScheduleVerificationError(
                    "verify_stacked.lane_alias",
                    f"datum {d.name} appears as (slot {prev[0]}, lane "
                    f"{prev[1]}) and (slot {slot}, lane {lane}) of one "
                    f"stacked drain — lanes must be disjoint",
                )
            seen[d.id] = (slot, lane)
    return True


__all__ = [
    "clear_verified_cache",
    "verifier_stats",
    "verify_plan",
    "verify_stacked_members",
]
