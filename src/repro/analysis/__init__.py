"""Static analysis over schedules, dependence DAGs, and the op registry.

Three passes (DESIGN.md §11), all above the executor layer — the paper's
unified-interface claim is that data-dependency tracking, not the executor,
guarantees correctness, so legality is checked at the layer that owns it:

- ``hazards``:  re-derive RAW/WAR/WAW dependences from task footprints and
  cross-check the ``DepTracker`` DAG (missing edge = race, spurious edge =
  lost parallelism).
- ``verify``:   prove a ``SchedulePlan``'s fusion/slot/scatter invariants
  and stacked-lane disjointness.
- ``lint_ops``: AST + signature contract checks over every registered
  Operation (split purity, mode/arity, leaf coherence).

Runtime wiring: ``Dispatcher(verify=True)`` or ``REPRO_VERIFY=1`` runs the
hazard and plan passes on every non-replay drain; memo replays re-execute a
verified capture and skip verification entirely.
"""

from .hazards import (
    Conflict,
    HazardReport,
    LostParallelismWarning,
    analyze_hazards,
    recompute_conflicts,
)
from .lint_ops import LintIssue, lint_operation, lint_or_raise, lint_registry
from .verify import (
    clear_verified_cache,
    verifier_stats,
    verify_plan,
    verify_stacked_members,
)

__all__ = [
    "Conflict",
    "HazardReport",
    "LintIssue",
    "LostParallelismWarning",
    "analyze_hazards",
    "clear_verified_cache",
    "lint_operation",
    "lint_or_raise",
    "lint_registry",
    "recompute_conflicts",
    "verifier_stats",
    "verify_plan",
    "verify_stacked_members",
]
