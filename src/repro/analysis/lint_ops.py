"""Operation-algebra linter: static contract checks over the registry.

Every Operation promises the hook contract in ``core/operation.py``; the
runtime silently assumes it.  Three of those promises are checkable without
running a drain (DESIGN.md §11), and breaking any of them produces bugs
that end-to-end numerics may not catch:

    L1  **Split purity.**  A ``memoizable=True`` split must be a pure
        function of argument *geometry* — the drain memo replays captured
        schedules on fresh data, so a split that reads ``.value`` (or the
        resident ``.grid``, or wall clock / RNG state) makes replay wrong.
        Checked by AST walk over ``split`` and every same-module helper it
        calls (the composed-op pattern: ``LuSolveOp.split`` delegates to
        ``_expand_*``).
    L2  **Mode/arity consistency.**  ``default_modes(n)`` must yield one
        ``Access`` per leaf argument, and at least one write mode — the
        leaf convention returns one array per write-mode argument, so an
        all-READ op has no output and a mode/arity mismatch scatters
        results to the wrong blocks.
    L3  **Leaf/batched-leaf signature coherence.**  The jnp and pallas
        leaves must take the same argument count, ``batched_leaf_fn`` must
        be buildable, and (with ``execute=True``) a smoke evaluation on
        tiny blocks must return exactly one same-shape array per write
        argument, for both the plain and the batched form.

``lint_registry`` runs all checks over every registered op;
``lint_or_raise`` wraps the result in ``repro.errors.LintError`` for
programmatic gates (``scripts/lint_ops.py`` is the CLI).
"""

from __future__ import annotations

import ast
import inspect
import textwrap
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

from ..core.operation import Operation, OpRegistry
from ..core.task import Access
from ..errors import LintError

#: attribute reads that make a split value-dependent: the root array
#: itself (``.value``/``._value``), the resident grid epoch, or the
#: stacked-lane state.  Geometry attributes (region, level, partitions,
#: shape) are exactly what a pure split IS allowed to read.
_IMPURE_ATTRS = frozenset(
    {"value", "_value", "grid", "_grid", "lane", "_lane"}
)
#: module roots whose use inside a split means external state (time, RNG)
_IMPURE_MODULES = frozenset({"random", "time", "os"})


@dataclass(frozen=True)
class LintIssue:
    op: str
    check: str  # "L1" | "L2" | "L3"
    detail: str

    def __str__(self) -> str:
        return f"{self.op}: [{self.check}] {self.detail}"


class _PurityVisitor(ast.NodeVisitor):
    """Collect impure constructs in one function's AST."""

    def __init__(self):
        self.hits: List[str] = []

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if node.attr in _IMPURE_ATTRS:
            self.hits.append(f"reads .{node.attr}")
        # numpy/jax RNG or wall clock through a module attribute chain
        root = node
        chain = [node.attr]
        while isinstance(root.value, ast.Attribute):
            root = root.value
            chain.append(root.attr)
        if isinstance(root.value, ast.Name):
            base = root.value.id
            if base in _IMPURE_MODULES:
                self.hits.append(f"calls {base}.{'.'.join(reversed(chain))}")
            if base in ("np", "numpy", "jax") and "random" in chain:
                self.hits.append(f"uses {base} RNG")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        # zero-argument ``.get()`` is the GView value read; dict.get(key)
        # style calls always carry arguments and stay legal
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "get"
            and not node.args
            and not node.keywords
        ):
            self.hits.append("calls .get() (GView value read)")
        self.generic_visit(node)


def _callee_functions(fn: Callable, tree: ast.AST) -> List[Callable]:
    """Same-module plain functions ``fn``'s body calls by name — the
    composed-split helper pattern; one level of resolution, recursion is
    handled by the caller's visited set."""
    module = inspect.getmodule(fn)
    if module is None:
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            target = getattr(module, node.func.id, None)
            if inspect.isfunction(target):
                out.append(target)
    return out


def _split_purity_issues(op: Operation) -> List[LintIssue]:
    split = type(op).split
    if split is Operation.split:  # leaf-only op: nothing to check
        return []
    issues: List[LintIssue] = []
    seen = set()
    stack: List[Callable] = [split]
    while stack:
        fn = stack.pop()
        code = getattr(fn, "__code__", None)
        if code is None or code in seen:
            continue
        seen.add(code)
        try:
            src = textwrap.dedent(inspect.getsource(fn))
            tree = ast.parse(src)
        except (OSError, TypeError, SyntaxError):
            issues.append(
                LintIssue(op.name, "L1", f"split source unavailable ({fn})")
            )
            continue
        visitor = _PurityVisitor()
        visitor.visit(tree)
        where = fn.__name__
        issues.extend(
            LintIssue(
                op.name,
                "L1",
                f"memoizable split is value-dependent: {where} {hit}",
            )
            for hit in visitor.hits
        )
        stack.extend(_callee_functions(fn, tree))
    return issues


def _leaf_arity(fn: Callable) -> Optional[int]:
    try:
        params = inspect.signature(fn).parameters.values()
    except (ValueError, TypeError):
        return None
    if any(
        p.kind
        in (inspect.Parameter.VAR_POSITIONAL, inspect.Parameter.VAR_KEYWORD)
        for p in params
    ):
        return None
    return len(
        [
            p
            for p in params
            if p.kind
            in (
                inspect.Parameter.POSITIONAL_ONLY,
                inspect.Parameter.POSITIONAL_OR_KEYWORD,
            )
        ]
    )


def _smoke_blocks(n_args: int, size: int = 4):
    """Tiny well-conditioned blocks every algebra leaf accepts: strictly
    diagonally dominant square blocks (factorizable pivot-free, invertible
    triangles) with distinct off-diagonal content per argument."""
    import jax.numpy as jnp
    import numpy as np

    blocks = []
    for a in range(n_args):
        rng = np.random.default_rng(a)
        m = rng.uniform(-0.1, 0.1, (size, size)).astype(np.float32)
        np.fill_diagonal(m, 2.0 + a)
        blocks.append(jnp.asarray(m))
    return blocks


def lint_operation(op: Operation, execute: bool = False) -> List[LintIssue]:
    """All L1–L3 issues for one Operation (empty list == clean)."""
    issues: List[LintIssue] = []

    # L1: split purity (only meaningful for memoizable ops — a
    # memoizable=False op has *declared* its split value-dependent)
    if op.memoizable:
        issues.extend(_split_purity_issues(op))

    # L2: modes vs leaf arity
    try:
        leaf = op.leaf_fn("jnp")
    except NotImplementedError:
        issues.append(LintIssue(op.name, "L2", "no jnp leaf_fn"))
        return issues
    n = _leaf_arity(leaf)
    if n is None:
        issues.append(
            LintIssue(op.name, "L2", "jnp leaf arity is not statically fixed")
        )
        return issues
    modes = list(op.default_modes(n))
    if len(modes) != n:
        issues.append(
            LintIssue(
                op.name,
                "L2",
                f"default_modes({n}) yields {len(modes)} modes for a "
                f"{n}-argument leaf",
            )
        )
        return issues
    if not all(isinstance(m, Access) for m in modes):
        issues.append(LintIssue(op.name, "L2", "non-Access entry in modes"))
        return issues
    write_pos = [i for i, m in enumerate(modes) if m.writes]
    if not write_pos:
        issues.append(
            LintIssue(
                op.name,
                "L2",
                "no write-mode argument: the leaf convention returns one "
                "array per write arg, so this op can produce no output",
            )
        )

    # L3: jnp/pallas/batched signature coherence
    try:
        pallas_leaf = op.leaf_fn("pallas")
    except NotImplementedError:
        pallas_leaf = None
    if pallas_leaf is not None:
        pn = _leaf_arity(pallas_leaf)
        if pn is not None and pn != n:
            issues.append(
                LintIssue(
                    op.name,
                    "L3",
                    f"pallas leaf takes {pn} args, jnp leaf takes {n}",
                )
            )
    try:
        batched = op.batched_leaf_fn("jnp")
    except Exception as e:  # noqa: BLE001 — any failure is the finding
        issues.append(
            LintIssue(op.name, "L3", f"batched_leaf_fn('jnp') failed: {e}")
        )
        batched = None

    if execute and write_pos and not issues:
        import jax.numpy as jnp

        blocks = _smoke_blocks(n)
        try:
            outs = leaf(*blocks)
        except Exception as e:  # noqa: BLE001
            issues.append(
                LintIssue(op.name, "L3", f"jnp leaf smoke eval raised: {e}")
            )
            return issues
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        if len(outs) != len(write_pos):
            issues.append(
                LintIssue(
                    op.name,
                    "L3",
                    f"leaf returns {len(outs)} arrays for {len(write_pos)} "
                    f"write-mode args {write_pos}",
                )
            )
            return issues
        for out, a in zip(outs, write_pos):
            if tuple(out.shape) != tuple(blocks[a].shape):
                issues.append(
                    LintIssue(
                        op.name,
                        "L3",
                        f"leaf output for arg {a} has shape "
                        f"{tuple(out.shape)} != block {tuple(blocks[a].shape)}",
                    )
                )
        if batched is not None:
            stacked = [jnp.stack([b, b]) for b in blocks]
            try:
                bouts = batched(*stacked)
            except Exception as e:  # noqa: BLE001
                issues.append(
                    LintIssue(op.name, "L3", f"batched smoke eval raised: {e}")
                )
                return issues
            if not isinstance(bouts, (tuple, list)):
                bouts = (bouts,)
            if len(bouts) != len(write_pos) or any(
                tuple(o.shape) != tuple(s.shape)
                for o, s in zip(bouts, (stacked[a] for a in write_pos))
            ):
                issues.append(
                    LintIssue(
                        op.name,
                        "L3",
                        "batched leaf output count/shape mismatch vs "
                        "write-mode args",
                    )
                )
    return issues


def lint_registry(
    names: Optional[Sequence[str]] = None, execute: bool = False
) -> List[LintIssue]:
    """Lint every registered Operation (or the named subset)."""
    issues: List[LintIssue] = []
    for name in names if names is not None else OpRegistry.names():
        issues.extend(lint_operation(OpRegistry.get(name), execute=execute))
    return issues


def lint_or_raise(
    names: Optional[Sequence[str]] = None, execute: bool = False
) -> int:
    """Raise ``LintError`` on any issue; returns the op count checked."""
    checked = list(names if names is not None else OpRegistry.names())
    issues = lint_registry(checked, execute=execute)
    if issues:
        raise LintError(issues)
    return len(checked)


__all__ = [
    "LintIssue",
    "lint_operation",
    "lint_or_raise",
    "lint_registry",
]
