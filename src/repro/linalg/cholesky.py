"""The paper's application + technical layers for Cholesky (Fig. 2a).

``utp_cholesky`` is the technical-layer subroutine (lines 19-25): it creates
the root POTRF task and submits it to the dispatcher.  ``run_cholesky`` is
the whole application program: define data + partitions, call the
subroutine, wait for completion — identical for every task-flow graph.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import Dispatcher, GData, GTask
from ..core.data import from_grid
from .ops import POTRF


def utp_cholesky(dispatcher: Dispatcher, A: GData) -> GTask:
    task = GTask(POTRF, None, [A.root_view()])
    dispatcher.submit_task(task)
    return task


# de-grid + lower-triangle extraction fused into one compiled program (the
# drained root is still grid-resident; see lu._unpack_lu_grid)
_tril_grid = jax.jit(lambda g: jnp.tril(from_grid(g)))


def run_cholesky(
    a: jnp.ndarray,
    graph: str = "g2",
    partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
    mesh=None,
) -> jnp.ndarray:
    """Factorize SPD ``a``; returns the lower factor L (upper zeroed)."""
    d = Dispatcher(graph=graph, mesh=mesh)
    A = GData(a.shape, partitions=partitions, dtype=a.dtype, value=jnp.asarray(a))
    utp_cholesky(d, A)
    d.run()
    if A.in_grid_epoch:
        return _tril_grid(A.grid)
    return jnp.tril(A.value)
