"""Blocked linear-algebra Operations (paper Fig. 2b) on the UTP core.

Two operation families, each closed under hierarchical splitting
(DESIGN.md §6).  The Cholesky family:

    POTRF(A)       A -> L L^T (lower factor written back into A)
    TRSM(L, B)     B <- B @ inv(L)^T
    SYRK(A, C)     C <- C - A @ A^T
    GEMM(A, B, C)  C <- C - A @ B^T

and the LU family (pivot-free, Doolittle: L unit-lower, U non-unit upper):

    GETRF(A)         A -> L\\U packed in place
    TRSML(L, B)      B <- inv(L) @ B     (left, lower, unit-diagonal)
    TRSMU(U, B)      B <- B @ inv(U)     (right, upper, non-unit)
    TRSMUL(U, B)     B <- inv(U) @ B     (left, upper, non-unit)
    GEMMNN(A, B, C)  C <- C - A @ B

plus one *composed* workload over the LU family (DESIGN.md §4):

    LUSOLVE(A, B)    A -> L\\U packed;  B <- inv(A) @ B

``split`` reproduces the blocked expansions (left-looking Cholesky per the
paper's Fig. 2b, right-looking LU); every child is again a member of its
family, so the same code splits level-1 blocks into level-2 tiles (the
DuctTeip-over-SuperGlue hierarchy).  LUSOLVE's split emits the factor
expansion followed by the forward (TRSML) and backward (TRSMUL) block
substitutions into ONE scope, so data versioning orders the whole
factor+solve pipeline as a single task DAG — one WaveProgram per drain.
``leaf_fn``/``batched_leaf_fn`` provide the jnp (cpuBLAS analog) and Pallas
(cuBLAS analog) leaves through the unified operation interface; the
executors never special-case an op.
"""

from __future__ import annotations

from typing import Callable

import jax

from ..core.operation import Operation, OpRegistry
from ..core.task import Access, GTask
from ..kernels import ops as kops
from ..kernels import ref as kref


class PotrfOp(Operation):
    name = "potrf"

    def default_modes(self, n):
        return [Access.READWRITE]

    def leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return lambda a: kops.potrf(a)
        return kref.potrf

    def batched_leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return kops.batched_potrf
        return jax.vmap(self.leaf_fn(backend))

    def grid_fused_fn(self, backend: str):
        return kops.GRID_FUSED[self.name] if backend == "pallas" else None

    def split(self, task: GTask, submit) -> None:
        # Paper Fig. 2(b): left-looking blocked Cholesky on A's next level.
        A = task.args[0]
        n = A.row_part_num()
        for i in range(n):
            for j in range(i):
                submit(GTask(SYRK, task, [A(i, j), A(i, i)]))
                for k in range(i + 1, n):
                    submit(GTask(GEMM, task, [A(k, j), A(i, j), A(k, i)]))
            submit(GTask(POTRF, task, [A(i, i)]))
            for j in range(i + 1, n):
                submit(GTask(TRSM, task, [A(i, i), A(j, i)]))


class TrsmOp(Operation):
    name = "trsm"

    def default_modes(self, n):
        return [Access.READ, Access.READWRITE]

    def leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return lambda l, b: kops.trsm(l, b)
        return kref.trsm

    def batched_leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return kops.batched_trsm
        return jax.vmap(self.leaf_fn(backend))

    def grid_fused_fn(self, backend: str):
        return kops.GRID_FUSED[self.name] if backend == "pallas" else None

    def split(self, task: GTask, submit) -> None:
        # X L^T = B blocked: X(p,i) = (B(p,i) - sum_{k<i} X(p,k) L(i,k)^T) L(i,i)^-T
        L, B = task.args
        n = L.row_part_num()
        m = B.row_part_num()
        for i in range(n):
            for p in range(m):
                for k in range(i):
                    submit(GTask(GEMM, task, [B(p, k), L(i, k), B(p, i)]))
                submit(GTask(TRSM, task, [L(i, i), B(p, i)]))


class SyrkOp(Operation):
    name = "syrk"

    def default_modes(self, n):
        return [Access.READ, Access.READWRITE]

    def leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return lambda a, c: kops.syrk(a, c)
        return kref.syrk

    def batched_leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return kops.batched_syrk
        return jax.vmap(self.leaf_fn(backend))

    def grid_fused_fn(self, backend: str):
        return kops.GRID_FUSED[self.name] if backend == "pallas" else None

    def split(self, task: GTask, submit) -> None:
        # C -= A A^T blocked over C's grid; diagonal uses SYRK, rest GEMM.
        A, C = task.args
        n = C.row_part_num()
        kk = A.col_part_num()
        for i in range(n):
            for j in range(n):
                for k in range(kk):
                    if i == j:
                        submit(GTask(SYRK, task, [A(i, k), C(i, i)]))
                    else:
                        submit(GTask(GEMM, task, [A(i, k), A(j, k), C(i, j)]))


class GemmOp(Operation):
    name = "gemm"

    def default_modes(self, n):
        return [Access.READ, Access.READ, Access.READWRITE]

    def leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return lambda a, b, c: kops.gemm(a, b, c)
        return kref.gemm

    def batched_leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return kops.batched_gemm
        return jax.vmap(self.leaf_fn(backend))

    def grid_fused_fn(self, backend: str):
        return kops.GRID_FUSED[self.name] if backend == "pallas" else None

    def split(self, task: GTask, submit) -> None:
        # C -= A B^T blocked
        A, B, C = task.args
        m = C.row_part_num()
        n = C.col_part_num()
        kk = A.col_part_num()
        for i in range(m):
            for j in range(n):
                for k in range(kk):
                    submit(GTask(GEMM, task, [A(i, k), B(j, k), C(i, j)]))


# --------------------------------------------------------------------------
# Blocked expansions of the LU family, shared between the per-op splits and
# the composed LUSOLVE split (which emits all three into one scope).  Each
# is a pure function of argument geometry (the drain-memo contract).
# --------------------------------------------------------------------------
def _expand_getrf(task: GTask, A, submit) -> None:
    # Right-looking blocked LU on A's next level: factor the diagonal
    # block, solve the U row panel (left/lower) and the L column panel
    # (right/upper), then one Schur rank-b update of the trailing blocks.
    n = A.row_part_num()
    for k in range(n):
        submit(GTask(GETRF, task, [A(k, k)]))
        for j in range(k + 1, n):
            submit(GTask(TRSML, task, [A(k, k), A(k, j)]))
        for i in range(k + 1, n):
            submit(GTask(TRSMU, task, [A(k, k), A(i, k)]))
        for i in range(k + 1, n):
            for j in range(k + 1, n):
                submit(GTask(GEMMNN, task, [A(i, k), A(k, j), A(i, j)]))


def _expand_trsml(task: GTask, L, B, submit) -> None:
    # X(i,q) = inv(L(i,i)) (B(i,q) - sum_{k<i} L(i,k) X(k,q)): block
    # forward substitution down B's rows, for every column of blocks.
    n = L.row_part_num()
    m = B.col_part_num()
    for i in range(n):
        for q in range(m):
            for k in range(i):
                submit(GTask(GEMMNN, task, [L(i, k), B(k, q), B(i, q)]))
            submit(GTask(TRSML, task, [L(i, i), B(i, q)]))


def _expand_trsmul(task: GTask, U, B, submit) -> None:
    # X(i,q) = inv(U(i,i)) (B(i,q) - sum_{k>i} U(i,k) X(k,q)): block
    # backward substitution up B's rows.  Descending submission order makes
    # versioning read the FINAL X(k,q) (k > i), not the forward-pass value.
    n = U.row_part_num()
    m = B.col_part_num()
    for i in reversed(range(n)):
        for q in range(m):
            for k in range(i + 1, n):
                submit(GTask(GEMMNN, task, [U(i, k), B(k, q), B(i, q)]))
            submit(GTask(TRSMUL, task, [U(i, i), B(i, q)]))


class GetrfOp(Operation):
    name = "getrf"

    def default_modes(self, n):
        return [Access.READWRITE]

    def leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return lambda a: kops.getrf(a)
        return kref.getrf

    def batched_leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return kops.batched_getrf
        return jax.vmap(self.leaf_fn(backend))

    def grid_fused_fn(self, backend: str):
        return kops.GRID_FUSED[self.name] if backend == "pallas" else None

    def split(self, task: GTask, submit) -> None:
        _expand_getrf(task, task.args[0], submit)


class TrsmLowerOp(Operation):
    """B <- inv(L) @ B, L unit-lower (forward substitution, left side)."""

    name = "trsml"

    def default_modes(self, n):
        return [Access.READ, Access.READWRITE]

    def leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return lambda l, b: kops.trsml(l, b)
        return kref.trsml

    def batched_leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return kops.batched_trsml
        return jax.vmap(self.leaf_fn(backend))

    def grid_fused_fn(self, backend: str):
        return kops.GRID_FUSED[self.name] if backend == "pallas" else None

    def split(self, task: GTask, submit) -> None:
        _expand_trsml(task, task.args[0], task.args[1], submit)


class TrsmUpperOp(Operation):
    """B <- B @ inv(U), U upper non-unit (backward substitution, right side)."""

    name = "trsmu"

    def default_modes(self, n):
        return [Access.READ, Access.READWRITE]

    def leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return lambda u, b: kops.trsmu(u, b)
        return kref.trsmu

    def batched_leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return kops.batched_trsmu
        return jax.vmap(self.leaf_fn(backend))

    def grid_fused_fn(self, backend: str):
        return kops.GRID_FUSED[self.name] if backend == "pallas" else None

    def split(self, task: GTask, submit) -> None:
        # X(q,j) = (B(q,j) - sum_{k<j} X(q,k) U(k,j)) inv(U(j,j)): block
        # substitution across B's columns, for every row of blocks.
        U, B = task.args
        n = U.col_part_num()
        m = B.row_part_num()
        for j in range(n):
            for q in range(m):
                for k in range(j):
                    submit(GTask(GEMMNN, task, [B(q, k), U(k, j), B(q, j)]))
                submit(GTask(TRSMU, task, [U(j, j), B(q, j)]))


class TrsmUpperLeftOp(Operation):
    """B <- inv(U) @ B, U upper non-unit (backward substitution, left side).

    The fourth TRSM orientation — the one that closes ``A x = b``: after a
    pivot-free LU, ``x = inv(U) @ inv(L) @ b`` is one TRSML followed by one
    TRSMUL.  Like the other solve leaves it reads only its own triangle
    (plus the diagonal), so packed L\\U blocks pass through unmasked.
    """

    name = "trsmul"

    def default_modes(self, n):
        return [Access.READ, Access.READWRITE]

    def leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return lambda u, b: kops.trsmul(u, b)
        return kref.trsmul

    def batched_leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return kops.batched_trsmul
        return jax.vmap(self.leaf_fn(backend))

    def grid_fused_fn(self, backend: str):
        return kops.GRID_FUSED[self.name] if backend == "pallas" else None

    def split(self, task: GTask, submit) -> None:
        _expand_trsmul(task, task.args[0], task.args[1], submit)


class LuSolveOp(Operation):
    """Composed workload: factor A pivot-free and solve A X = B, in place.

    ``split`` emits the full right-looking LU expansion followed by the
    forward (TRSML) and backward (TRSMUL) block substitutions — all into
    ONE scope, so data versioning orders the pipeline as a single task DAG
    and the dispatcher compiles the whole factor+solve drain into one
    WaveProgram, where the cross-wave fusion pass overlaps early solve
    groups with late factor groups (DESIGN.md §4).  Every child is a plain
    member of the LU family; the executors never see LUSOLVE below the
    root level.
    """

    name = "lu_solve"

    def default_modes(self, n):
        # A -> packed L\U in place; B -> X in place
        return [Access.READWRITE, Access.READWRITE]

    def leaf_fn(self, backend: str) -> Callable:
        # only reached when the root runs unsplit (g1, or 1-level data):
        # factor + both substitutions on the whole matrices
        if backend == "pallas":
            return lambda a, b: kops.lu_solve(a, b)
        return kref.lu_solve

    def split(self, task: GTask, submit) -> None:
        A, B = task.args
        _expand_getrf(task, A, submit)
        _expand_trsml(task, A, B, submit)
        _expand_trsmul(task, A, B, submit)


class GemmNNOp(Operation):
    name = "gemmnn"

    def default_modes(self, n):
        return [Access.READ, Access.READ, Access.READWRITE]

    def leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return lambda a, b, c: kops.gemmnn(a, b, c)
        return kref.gemmnn

    def batched_leaf_fn(self, backend: str) -> Callable:
        if backend == "pallas":
            return kops.batched_gemmnn
        return jax.vmap(self.leaf_fn(backend))

    def grid_fused_fn(self, backend: str):
        return kops.GRID_FUSED[self.name] if backend == "pallas" else None

    def split(self, task: GTask, submit) -> None:
        # C -= A B blocked: C(i,j) -= sum_k A(i,k) B(k,j)
        A, B, C = task.args
        m = C.row_part_num()
        n = C.col_part_num()
        kk = A.col_part_num()
        for i in range(m):
            for j in range(n):
                for k in range(kk):
                    submit(GTask(GEMMNN, task, [A(i, k), B(k, j), C(i, j)]))


POTRF = OpRegistry.register(PotrfOp())
TRSM = OpRegistry.register(TrsmOp())
SYRK = OpRegistry.register(SyrkOp())
GEMM = OpRegistry.register(GemmOp())
GETRF = OpRegistry.register(GetrfOp())
TRSML = OpRegistry.register(TrsmLowerOp())
TRSMU = OpRegistry.register(TrsmUpperOp())
TRSMUL = OpRegistry.register(TrsmUpperLeftOp())
GEMMNN = OpRegistry.register(GemmNNOp())
LUSOLVE = OpRegistry.register(LuSolveOp())
