"""Application + technical layers for LU and triangular solve (DESIGN.md §6).

Mirrors ``cholesky.py``: ``utp_getrf`` / ``utp_solve`` are the technical-
layer subroutines (create one root task, submit it); ``run_lu`` /
``run_solve`` are whole application programs — define data + partitions,
call the subroutine, drain.  They run unmodified under every task-flow
graph g1–g4 with zero changes to executor code: the dispatcher only ever
sees Operations.

Conventions (pivot-free Doolittle, see ``linalg/ops.py``):

    run_lu(a)                -> (L, U) with L unit-lower, U upper, L@U == a
    run_solve(a, b)          -> x with tril(a, unit) @ x == b
    run_solve(a, b, lower=False) -> x with x @ triu(a) == b

``run_solve`` reads only the relevant triangle of ``a`` (the leaves mask
the other triangle), so a packed L\\U factor from ``run_lu`` can be passed
straight back in for forward/backward substitution.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core import Dispatcher, GData, GTask
from ..core.data import from_grid
from .ops import GETRF, TRSML, TRSMU


def utp_getrf(dispatcher: Dispatcher, A: GData) -> GTask:
    task = GTask(GETRF, None, [A.root_view()])
    dispatcher.submit_task(task)
    return task


@jax.jit
def _unpack_lu(packed: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    l = jnp.tril(packed, -1) + jnp.eye(packed.shape[0], dtype=packed.dtype)
    return l, jnp.triu(packed)


@jax.jit
def _unpack_lu_grid(grid: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    # de-grid + unpack in ONE compiled program: a drained root is still
    # grid-resident, and unpacking it unjitted costs three full-matrix
    # passes on the hot repeated-drain path (benchmarks time run_lu whole)
    return _unpack_lu(from_grid(grid))


def _unpack(A: GData) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if A.in_grid_epoch:
        return _unpack_lu_grid(A.grid)
    return _unpack_lu(A.value)


def utp_solve(dispatcher: Dispatcher, A: GData, B: GData, lower: bool = True) -> GTask:
    op = TRSML if lower else TRSMU
    task = GTask(op, None, [A.root_view(), B.root_view()])
    dispatcher.submit_task(task)
    return task


def run_lu(
    a: jnp.ndarray,
    graph: str = "g2",
    partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
    mesh=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pivot-free blocked LU of ``a``; returns (L, U) unpacked.

    ``a`` must admit LU without pivoting (e.g. diagonally dominant or
    already factored-friendly); there is no singular-pivot detection, as in
    the paper's fixed task-flow expansion.
    """
    d = Dispatcher(graph=graph, mesh=mesh)
    A = GData(a.shape, partitions=partitions, dtype=a.dtype, value=jnp.asarray(a))
    utp_getrf(d, A)
    d.run()
    return _unpack(A)


def run_lu_many(
    mats: Sequence[jnp.ndarray],
    graph: str = "g2",
    partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
    mesh=None,
) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Pivot-free blocked LU of several matrices in ONE dispatcher drain.

    The multi-root drain (ROADMAP item): every factorization is submitted
    as its own root task, the scheduler interleaves the independent task
    DAGs, and the dependency-exact fusion pass merges their same-signature
    groups into shared batched launches — one compiled program, one
    dispatch, for the whole set (DESIGN.md §2).
    """
    d = Dispatcher(graph=graph, mesh=mesh)
    roots = []
    for a in mats:
        A = GData(a.shape, partitions=partitions, dtype=a.dtype, value=jnp.asarray(a))
        utp_getrf(d, A)
        roots.append(A)
    d.run()
    return [_unpack(A) for A in roots]


def run_solve(
    a: jnp.ndarray,
    b: jnp.ndarray,
    lower: bool = True,
    graph: str = "g2",
    partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
    b_partitions: Tuple[Tuple[int, int], ...] = None,
    mesh=None,
) -> jnp.ndarray:
    """Blocked triangular solve as a task workload.

    ``lower=True``: x = inv(tril(a, unit-diagonal)) @ b (forward subst.).
    ``lower=False``: x = b @ inv(triu(a)) (backward substitution from the
    right).  ``b_partitions`` defaults to ``partitions``; give it explicitly
    for non-square block counts (b's row grid must match a's for lower,
    its column grid for upper).
    """
    d = Dispatcher(graph=graph, mesh=mesh)
    A = GData(a.shape, partitions=partitions, dtype=a.dtype, value=jnp.asarray(a))
    B = GData(
        b.shape,
        partitions=partitions if b_partitions is None else b_partitions,
        dtype=b.dtype,
        value=jnp.asarray(b),
    )
    utp_solve(d, A, B, lower=lower)
    d.run()
    return B.value
