"""Application + technical layers for LU, triangular solve, and the
end-to-end ``lu_solve`` drain (DESIGN.md §4/§6).

Mirrors ``cholesky.py``: ``utp_getrf`` / ``utp_solve`` / ``utp_lu_solve``
are the technical-layer subroutines (create one root task, submit it);
``run_lu`` / ``run_solve`` / ``run_lu_solve`` / ``run_inv`` are whole
application programs — define data + partitions, call the subroutine,
drain.  They run unmodified under every task-flow graph g1–g4 with zero
changes to executor code: the dispatcher only ever sees Operations.

Conventions (pivot-free Doolittle, see ``linalg/ops.py``):

    run_lu(a)                -> (L, U) with L unit-lower, U upper, L@U == a
    run_solve(a, b)          -> x with tril(a, unit) @ x == b
    run_solve(a, b, lower=False)              -> x with x @ triu(a) == b
    run_solve(a, b, lower=False, side="left") -> x with triu(a) @ x == b
    run_lu_solve(a, b)       -> x with a @ x == b  (factor+solve, ONE drain)
    run_inv(a)               -> inv(a)             (lu_solve against I)

``run_solve`` reads only the relevant triangle of ``a`` (the leaves mask
the other triangle), so a packed L\\U factor from ``run_lu`` can be passed
straight back in for forward/backward substitution.  ``run_lu_solve``
composes all of that as ONE dispatcher drain: LU panel tasks, L-solve
tasks, and U-solve tasks are versioned into a single task DAG and compiled
into a single WaveProgram (the composed LUSOLVE operation, DESIGN.md §4).
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core import Dispatcher, GData, GTask
from ..core.data import from_grid
from ..errors import NumericalError
from .ops import GETRF, LUSOLVE, TRSML, TRSMU, TRSMUL


def check_finite_result(name: str, *arrays: jnp.ndarray) -> None:
    """Raise ``NumericalError`` if any result array is non-finite.

    The pivot-free expansions have no singular-pivot detection (the paper's
    fixed task-flow shape), so a zero pivot silently propagates inf/NaN
    through the trailing updates; ``check_finite=True`` on the run_* entry
    points turns that into a typed error instead of serving garbage
    (DESIGN.md §10).  Opt-in: the check forces materialization (de-grids a
    resident result), which the hot replay paths must not pay by default.
    """
    for a in arrays:
        if a is not None and not bool(jnp.isfinite(a).all()):
            raise NumericalError(
                f"{name}: non-finite values in result (singular pivot or "
                f"overflow; input not factorizable without pivoting?)"
            )


def utp_getrf(dispatcher: Dispatcher, A: GData) -> GTask:
    task = GTask(GETRF, None, [A.root_view()])
    dispatcher.submit_task(task)
    return task


@jax.jit
def _unpack_lu(packed: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    l = jnp.tril(packed, -1) + jnp.eye(packed.shape[0], dtype=packed.dtype)
    return l, jnp.triu(packed)


@jax.jit
def _unpack_lu_grid(grid: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    # de-grid + unpack in ONE compiled program: a drained root is still
    # grid-resident, and unpacking it unjitted costs three full-matrix
    # passes on the hot repeated-drain path (benchmarks time run_lu whole)
    return _unpack_lu(from_grid(grid))


def _unpack(A: GData) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if A.in_grid_epoch:
        return _unpack_lu_grid(A.grid)
    return _unpack_lu(A.value)


def utp_solve(
    dispatcher: Dispatcher,
    A: GData,
    B: GData,
    lower: bool = True,
    side: Optional[str] = None,
) -> GTask:
    """Submit one triangular-solve root task (technical layer).

    ``side`` defaults to the algebra's native orientation per triangle:
    "left" for lower (TRSML, forward substitution) and "right" for upper
    (TRSMU).  ``lower=False, side="left"`` selects TRSMUL — the left-upper
    backward substitution that closes ``A x = b`` end-to-end.
    """
    if side is None:
        side = "left" if lower else "right"
    if lower:
        if side != "left":
            raise ValueError("lower solves are left-sided (TRSML) only")
        op = TRSML
    else:
        if side not in ("left", "right"):
            raise ValueError(f"side must be 'left' or 'right', got {side!r}")
        op = TRSMUL if side == "left" else TRSMU
    task = GTask(op, None, [A.root_view(), B.root_view()])
    dispatcher.submit_task(task)
    return task


def utp_lu_solve(dispatcher: Dispatcher, A: GData, B: GData) -> GTask:
    """Submit ONE composed factor+solve root task (LUSOLVE, DESIGN.md §4).

    A single root keeps the whole expansion in one scope: the dispatcher
    versions LU panel tasks, forward-substitution tasks, and backward-
    substitution tasks into one task DAG and compiles one WaveProgram for
    the entire pipeline (instead of three barrier-separated drains).
    """
    task = GTask(LUSOLVE, None, [A.root_view(), B.root_view()])
    dispatcher.submit_task(task)
    return task


def run_lu(
    a: jnp.ndarray,
    graph: str = "g2",
    partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
    mesh=None,
    check_finite: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pivot-free blocked LU of ``a``; returns (L, U) unpacked.

    ``a`` must admit LU without pivoting (e.g. diagonally dominant or
    already factored-friendly); the task-flow expansion itself has no
    singular-pivot detection (the paper's fixed shape), but
    ``check_finite=True`` validates the drained factor and raises
    ``NumericalError`` instead of returning inf/NaN (DESIGN.md §10).
    """
    d = Dispatcher(graph=graph, mesh=mesh)
    A = GData(a.shape, partitions=partitions, dtype=a.dtype, value=jnp.asarray(a))
    utp_getrf(d, A)
    d.run()
    if check_finite:
        check_finite_result("run_lu", A.value)
    return _unpack(A)


def run_lu_many(
    mats: Sequence[jnp.ndarray],
    graph: str = "g2",
    partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
    mesh=None,
) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Pivot-free blocked LU of several matrices in ONE dispatcher drain.

    The multi-root drain (ROADMAP item): every factorization is submitted
    as its own root task, the scheduler interleaves the independent task
    DAGs, and the dependency-exact fusion pass merges their same-signature
    groups into shared batched launches — one compiled program, one
    dispatch, for the whole set (DESIGN.md §2).  Stacking is deliberately
    OFF here: this is the per-root *segment fusion* form (the matrices may
    even have different shapes), and the measured baseline the stacked
    ``run_lu_batched`` is compared against (DESIGN.md §7).
    """
    d = Dispatcher(graph=graph, mesh=mesh, stack_roots=False)
    roots = []
    for a in mats:
        A = GData(a.shape, partitions=partitions, dtype=a.dtype, value=jnp.asarray(a))
        utp_getrf(d, A)
        roots.append(A)
    d.run()
    return [_unpack(A) for A in roots]


def run_lu_batched(
    mats: Sequence[jnp.ndarray],
    graph: str = "g2",
    partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
    mesh=None,
) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Pivot-free blocked LU of N same-geometry matrices as ONE *stacked*
    batched drain (DESIGN.md §7).

    All matrices must share shape/dtype; the dispatcher detects the
    homogeneous root stream, stacks the roots along a new leading batch
    dimension padded to a pow2 bucket, and expands/compiles the task graph
    ONCE — launch count and compiled-program count are flat in N (any N
    hits one of O(log N) bucket programs), unlike ``run_lu_many`` whose
    fused groups still carry one gather/scatter segment per root.
    """
    d = Dispatcher(graph=graph, mesh=mesh)
    roots = []
    for a in mats:
        A = GData(a.shape, partitions=partitions, dtype=a.dtype, value=jnp.asarray(a))
        utp_getrf(d, A)
        roots.append(A)
    d.run()
    return [_unpack(A) for A in roots]


def run_solve(
    a: jnp.ndarray,
    b: jnp.ndarray,
    lower: bool = True,
    graph: str = "g2",
    partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
    b_partitions: Tuple[Tuple[int, int], ...] = None,
    mesh=None,
    side: Optional[str] = None,
    check_finite: bool = False,
) -> jnp.ndarray:
    """Blocked triangular solve as a task workload.

    ``lower=True``: x = inv(tril(a, unit-diagonal)) @ b (forward subst.).
    ``lower=False``: x = b @ inv(triu(a)) (backward substitution from the
    right), or x = inv(triu(a)) @ b with ``side="left"`` (the left-upper
    TRSMUL orientation).  ``b_partitions`` defaults to ``partitions``; give
    it explicitly for non-square block counts (b's row grid must match a's
    for left-sided solves, its column grid for the right-sided one).
    """
    d = Dispatcher(graph=graph, mesh=mesh)
    A = GData(a.shape, partitions=partitions, dtype=a.dtype, value=jnp.asarray(a))
    B = GData(
        b.shape,
        partitions=partitions if b_partitions is None else b_partitions,
        dtype=b.dtype,
        value=jnp.asarray(b),
    )
    utp_solve(d, A, B, lower=lower, side=side)
    d.run()
    if check_finite:
        check_finite_result("run_solve", B.value)
    return B.value


def run_lu_solve(
    a: jnp.ndarray,
    b: jnp.ndarray,
    graph: str = "g2",
    partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
    b_partitions: Tuple[Tuple[int, int], ...] = None,
    mesh=None,
    check_finite: bool = False,
) -> jnp.ndarray:
    """Solve ``a @ x == b`` by pivot-free LU — factor AND solve in ONE drain.

    The whole pipeline (LU panel tasks, forward-substitution tasks,
    backward-substitution tasks) is submitted as one composed LUSOLVE root,
    so it is versioned into one task DAG, compiled into one WaveProgram,
    and replayed via the drain memo on structurally repeated calls — the
    same single-drain/zero-recompile behaviour ``run_lu`` has, now for the
    full solve (DESIGN.md §4).  Matches ``jax.scipy.linalg.lu_solve`` on
    inputs where partial pivoting selects P == I (e.g. column-diagonally-
    dominant ``a``); like ``run_lu``, the expansion has no singular-pivot
    detection, but ``check_finite=True`` raises ``NumericalError`` on a
    non-finite solution instead of returning it (DESIGN.md §10).

    ``b`` may be a matrix ``(n, m)`` or a vector ``(n,)``; ``b_partitions``
    defaults to ``partitions`` with the column counts collapsed to 1 for a
    vector right-hand side.
    """
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    if b.shape[0] != a.shape[0]:
        raise ValueError(f"shape mismatch: a {a.shape} vs b {b.shape}")
    vec = b.ndim == 1
    b2 = b[:, None] if vec else b
    if b_partitions is None:
        b_partitions = tuple(
            (pr, 1 if vec else pc) for pr, pc in partitions
        )
    d = Dispatcher(graph=graph, mesh=mesh)
    A = GData(a.shape, partitions=partitions, dtype=a.dtype, value=a)
    B = GData(b2.shape, partitions=b_partitions, dtype=b2.dtype, value=b2)
    utp_lu_solve(d, A, B)
    d.run()
    x = B.value
    if check_finite:
        check_finite_result("run_lu_solve", x)
    return x[:, 0] if vec else x


def run_lu_solve_batched(
    mats: Sequence[jnp.ndarray],
    rhss: Sequence[jnp.ndarray],
    graph: str = "g2",
    partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
    b_partitions: Tuple[Tuple[int, int], ...] = None,
    mesh=None,
) -> List[jnp.ndarray]:
    """Solve N same-geometry systems ``a_i @ x_i == b_i`` in ONE stacked
    drain (DESIGN.md §7): N composed LUSOLVE roots stack into a single
    batched WaveProgram — the serving hot path ``BatchServer`` drains per
    tick.  Geometry rules follow ``run_lu_solve`` (vector or matrix b)."""
    if len(mats) != len(rhss):
        raise ValueError(f"{len(mats)} matrices vs {len(rhss)} right-hand sides")
    d = Dispatcher(graph=graph, mesh=mesh)
    outs = []
    for a, b in zip(mats, rhss):
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        if b.shape[0] != a.shape[0]:
            raise ValueError(f"shape mismatch: a {a.shape} vs b {b.shape}")
        vec = b.ndim == 1
        b2 = b[:, None] if vec else b
        bp = b_partitions
        if bp is None:
            bp = tuple((pr, 1 if vec else pc) for pr, pc in partitions)
        A = GData(a.shape, partitions=partitions, dtype=a.dtype, value=a)
        B = GData(b2.shape, partitions=bp, dtype=b2.dtype, value=b2)
        utp_lu_solve(d, A, B)
        outs.append((B, vec))
    d.run()
    return [B.value[:, 0] if vec else B.value for B, vec in outs]


def run_inv(
    a: jnp.ndarray,
    graph: str = "g2",
    partitions: Tuple[Tuple[int, int], ...] = ((4, 4),),
    mesh=None,
) -> jnp.ndarray:
    """Matrix inverse via LU: ``run_lu_solve(a, I)`` — a second application
    program over the same composed pipeline (A X = I), showing the family
    is closed: no new operations, no executor changes."""
    a = jnp.asarray(a)
    eye = jnp.eye(a.shape[0], dtype=a.dtype)
    return run_lu_solve(a, eye, graph=graph, partitions=partitions, mesh=mesh)
