"""Blocked dense linear algebra on the UTP core (the paper's technical +
application layers, DESIGN.md §1/§6).

Application programs (define data, submit, drain — identical under every
task-flow graph g1–g4):

    run_cholesky(a)     lower Cholesky factor of SPD ``a``
    run_lu(a)           pivot-free blocked LU -> (L, U)
    run_lu_many(mats)   several LUs in ONE multi-root drain (segment fusion)
    run_lu_batched(mats)          N same-geometry LUs, ONE stacked program
    run_solve(a, b)     blocked triangular solve (TRSML / TRSMU / TRSMUL)
    run_lu_solve(a, b)  factor + forward + backward solve in ONE drain
    run_lu_solve_batched(mats, rhss)  N systems, ONE stacked program
    run_inv(a)          matrix inverse via the same composed pipeline

Technical-layer subroutines (``utp_*``) create one root task on an existing
dispatcher, for composing several workloads into one drain.  The operation
singletons (POTRF .. LUSOLVE) are the registry entries the dispatcher and
executors operate on — see ``linalg/ops.py`` for the algebra.
"""

from .cholesky import run_cholesky, utp_cholesky
from .lu import (
    run_inv,
    run_lu,
    run_lu_batched,
    run_lu_many,
    run_lu_solve,
    run_lu_solve_batched,
    run_solve,
    utp_getrf,
    utp_lu_solve,
    utp_solve,
)
from .ops import (
    GEMM,
    GEMMNN,
    GETRF,
    LUSOLVE,
    POTRF,
    SYRK,
    TRSM,
    TRSML,
    TRSMU,
    TRSMUL,
)

__all__ = [
    "GEMM",
    "GEMMNN",
    "GETRF",
    "LUSOLVE",
    "POTRF",
    "SYRK",
    "TRSM",
    "TRSML",
    "TRSMU",
    "TRSMUL",
    "run_cholesky",
    "run_inv",
    "run_lu",
    "run_lu_batched",
    "run_lu_many",
    "run_lu_solve",
    "run_lu_solve_batched",
    "run_solve",
    "utp_cholesky",
    "utp_getrf",
    "utp_lu_solve",
    "utp_solve",
]
