from .cholesky import run_cholesky, utp_cholesky
from .lu import run_lu, run_lu_many, run_solve, utp_getrf, utp_solve
from .ops import GEMM, GEMMNN, GETRF, POTRF, SYRK, TRSM, TRSML, TRSMU

__all__ = [
    "GEMM",
    "GEMMNN",
    "GETRF",
    "POTRF",
    "SYRK",
    "TRSM",
    "TRSML",
    "TRSMU",
    "run_cholesky",
    "run_lu",
    "run_lu_many",
    "run_solve",
    "utp_cholesky",
    "utp_getrf",
    "utp_solve",
]
