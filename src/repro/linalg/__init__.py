from .cholesky import run_cholesky, utp_cholesky
from .ops import GEMM, POTRF, SYRK, TRSM

__all__ = ["GEMM", "POTRF", "SYRK", "TRSM", "run_cholesky", "utp_cholesky"]
