"""Deterministic, seedable fault injection at named runtime sites.

The recovery paths of the drain/serving stack (DESIGN.md §10) are only
trustworthy if every one of them is exercisable on demand.  Production code
is instrumented at a small set of NAMED SITES; a test (or the CI fault
gate) arms a site with ``inject(...)`` and the instrumented code raises,
corrupts, or diverts exactly as specified — deterministically by default
(fire on the Nth match), or probabilistically with a seeded RNG.

    with faults.inject("executor.launch", RuntimeError("device lost")):
        run_lu(a)          # raises: the launch site fired

    with faults.inject("serve.drain", NumericalError("poisoned"),
                       when=lambda ctx: 7 in ctx["rids"], times=None):
        srv.tick()         # every drain containing request 7 fails

    with faults.inject("drain.stall", delay_s=0.2):
        srv.tick()         # the fence site SLEEPS 200ms (a hung drain)

Effects compose per fault: ``delay_s`` sleeps at the site first, then
``exc`` (if any) raises — a delay-only fault models a slow/hung path
without failing it, which is what the watchdog budget (DESIGN.md §14)
must catch.

Sites (armed by name; arming an unknown name is an error):

    leaf.fn                 resolving a group's leaf kernel at program
                            build time raises (bad kernel / trace failure)
    executor.launch         a compiled WaveProgram launch raises before
                            executing (ctx: batch, n_tasks, replay)
    executor.output         a completed program's output grids are passed
                            through ``corrupt`` (default: all-NaN) —
                            non-finite corruption without a raise
    memo.capture            recording a ProgramRecord into the drain
                            capture raises (mid-drain, after the program
                            ran) — exercises memo-cleanliness invariants
    split.value_dependent   boolean site: a matched task split is treated
                            as value-dependent (non-memoizable), forcing
                            the ``_StackedAbort`` collect-mode fallback
    serve.drain             a ``BatchServer`` chunk drain raises before
                            dispatching (ctx: rids, op, size) — the
                            request-attributable failure bisection hunts
    drain.inflight          an overlapped drain fails while its epoch is
                            still in flight (DESIGN.md §12): fired at the
                            deferred resolution fence — ``DrainHandle.
                            wait()`` (ctx: epochs, leaves) and the serving
                            finalize step (ctx: rids, op, size, pending) —
                            after the program was dispatched, exercising
                            memo invalidation and the no-half-resolved-
                            futures invariant
    drain.stall             the fence over an overlapped drain hangs:
                            fired inside ``DrainHandle.wait`` and the
                            serving end-of-tick fence BEFORE readiness is
                            polled (ctx: rids/op/size or epochs/leaves),
                            so a ``delay_s`` fault here makes the fence
                            blow its wall-clock budget — the hung-drain
                            watchdog (DESIGN.md §14) must surface
                            ``DrainStalledError``
    launch.oom              a compiled-program launch fails with device
                            OOM (ctx: batch, n_tasks, replay) — arm with
                            ``ResourceExhausted`` (or any exception whose
                            text matches XLA's RESOURCE_EXHAUSTED) to
                            exercise adaptive degradation: cap halving,
                            memo pressure shedding, split re-drains
                            (DESIGN.md §14)

Plan-mutation sites (DESIGN.md §11) — boolean sites whose consuming code
CORRUPTS the schedule instead of raising, so the static verifier can be
proven to detect exactly the bug class it claims to:

    plan.drop_edge          the leaf scope's tracker DAG loses every
                            in-edge of one task (a missed dependence —
                            the race ``analyze_hazards`` must catch)
    plan.merge_groups       the fusion pass force-merges two DEPENDENT
                            same-signature groups into one launch (the
                            illegal fusion ``verify_plan`` V1 must catch)
    plan.alias_lane         a stacked drain aliases lane 1 of every root
                            slot to lane 0's data (the overlap
                            ``verify_stacked_members`` V5 must catch)

Pure stdlib; importable from production code with near-zero cost when no
fault is armed (one module-flag check per site call).
"""

from __future__ import annotations

import random
import threading
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, List, Optional

KNOWN_SITES = frozenset(
    {
        "leaf.fn",
        "executor.launch",
        "executor.output",
        "memo.capture",
        "split.value_dependent",
        "serve.drain",
        "drain.inflight",
        "drain.stall",
        "launch.oom",
        "plan.drop_edge",
        "plan.merge_groups",
        "plan.alias_lane",
    }
)


class Fault:
    """One armed fault: firing rule + effect + observability counters.

    ``matches`` counts site hits that passed ``when``; ``fired`` counts the
    subset that actually took effect (after ``after``/``times``/``p``).
    ``log`` keeps the ctx dict of every firing when ``record=True`` — a
    pure probe (``exc=None, record=True``) observes a site without
    perturbing it, which tests use to assert drain order.
    """

    def __init__(
        self,
        site: str,
        exc: Optional[BaseException] = None,
        *,
        when: Optional[Callable[[dict], bool]] = None,
        times: Optional[int] = 1,
        after: int = 0,
        p: float = 1.0,
        seed: int = 0,
        corrupt: Optional[Callable[[Any], Any]] = None,
        record: bool = False,
        delay_s: float = 0.0,
    ):
        if site not in KNOWN_SITES:
            raise ValueError(
                f"unknown fault site {site!r}; known: {sorted(KNOWN_SITES)}"
            )
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"fault probability must be in [0, 1], got {p}")
        if delay_s < 0:
            raise ValueError(f"fault delay_s must be >= 0, got {delay_s}")
        self.site = site
        self.exc = exc
        self.delay_s = delay_s
        self.when = when
        self.times = times
        self.after = after
        self.p = p
        self.corrupt = corrupt
        self.record = record
        self._rng = random.Random(seed)
        self.matches = 0
        self.fired = 0
        self.log: List[dict] = []

    def _take(self, ctx: dict) -> bool:
        """Decide (and account) whether this fault fires for ``ctx``."""
        if self.when is not None and not self.when(ctx):
            return False
        self.matches += 1
        if self.matches <= self.after:
            return False
        if self.times is not None and self.fired >= self.times:
            return False
        if self.p < 1.0 and self._rng.random() >= self.p:
            return False
        self.fired += 1
        if self.record:
            self.log.append(dict(ctx))
        return True

    def _raise(self) -> None:
        """Apply the fault's effects: sleep ``delay_s`` first (a slow/hung
        path), then raise ``exc`` if armed (a failing one)."""
        if self.delay_s > 0:
            time.sleep(self.delay_s)
        exc = self.exc
        if callable(exc) and not isinstance(exc, BaseException):
            exc = exc()
        if exc is not None:
            raise exc


_LOCK = threading.Lock()
_ACTIVE: Dict[str, List[Fault]] = {}
_ENABLED = False  # fast-path flag: sites bail on this before any lookup


def active() -> bool:
    """True iff any fault is currently armed."""
    return _ENABLED


@contextmanager
def inject(
    site: str,
    exc: Optional[BaseException] = None,
    *,
    when: Optional[Callable[[dict], bool]] = None,
    times: Optional[int] = 1,
    after: int = 0,
    p: float = 1.0,
    seed: int = 0,
    corrupt: Optional[Callable[[Any], Any]] = None,
    record: bool = False,
    delay_s: float = 0.0,
):
    """Arm ``site`` for the duration of the ``with`` block; yields the
    ``Fault`` so the caller can assert on ``fired``/``matches``/``log``.

    ``times=1`` (default) fires once then disarms logically — the standard
    transient-fault shape; ``times=None`` fires on every match — the
    deterministic poisoned-request shape.  ``after=k`` skips the first k
    matches; ``p``/``seed`` make firing probabilistic but reproducible.
    ``delay_s`` sleeps at the site before (optionally) raising — a
    delay-only fault (``exc=None``) models a slow or hung path, the shape
    the watchdog budget hunts (DESIGN.md §14).
    """
    fault = Fault(
        site,
        exc,
        when=when,
        times=times,
        after=after,
        p=p,
        seed=seed,
        corrupt=corrupt,
        record=record,
        delay_s=delay_s,
    )
    global _ENABLED
    with _LOCK:
        _ACTIVE.setdefault(site, []).append(fault)
        _ENABLED = True
    try:
        yield fault
    finally:
        with _LOCK:
            lst = _ACTIVE.get(site)
            if lst and fault in lst:  # robust to a reset() mid-block
                lst.remove(fault)
                if not lst:
                    del _ACTIVE[site]
            _ENABLED = bool(_ACTIVE)


def reset() -> None:
    """Disarm everything (test-teardown safety net)."""
    global _ENABLED
    with _LOCK:
        _ACTIVE.clear()
        _ENABLED = False


def fire(site: str, **ctx) -> None:
    """Raising site: raise the armed fault's exception if one fires."""
    if not _ENABLED:
        return
    for fault in _ACTIVE.get(site, ()):
        if fault._take(ctx):
            fault._raise()


def fires(site: str, **ctx) -> bool:
    """Boolean site: True if any armed fault fires (no raise)."""
    if not _ENABLED:
        return False
    hit = False
    for fault in _ACTIVE.get(site, ()):
        if fault._take(ctx):
            fault._raise()  # raising faults still raise here
            hit = True
    return hit


def _nan_like(value):
    import jax.numpy as jnp

    if isinstance(value, (tuple, list)):
        return type(value)(_nan_like(v) for v in value)
    return jnp.full_like(value, jnp.nan)


def corrupt(site: str, value, **ctx):
    """Corruption site: pass ``value`` through each firing fault's
    ``corrupt`` callable (default: replace every array with NaNs)."""
    if not _ENABLED:
        return value
    for fault in _ACTIVE.get(site, ()):
        if fault._take(ctx):
            fn = fault.corrupt if fault.corrupt is not None else _nan_like
            value = fn(value)
    return value


def mutate_drop_edges(dag):
    """``plan.drop_edge`` mutation: remove EVERY in-edge of the first task
    (smallest id) that has predecessors, returning ``(task_id, dropped
    pred ids)`` or None if the DAG is edge-free.

    Dropping all in-edges (not just one) makes detection a guarantee, not
    an accident of DAG shape: a single dropped edge can be transitively
    implied by the remaining edges, in which case the schedule is still
    correct and the verifier rightly stays quiet.  With indegree forced to
    zero no path can reach the task at all, so each of its former direct
    predecessors (every one a true conflict — the tracker only records
    conflicts) becomes an unordered conflicting pair.  Duck-typed over
    ``TaskDag``; must be applied to a freshly built DAG (before its bitset
    reachability is computed/cached)."""
    for tid in sorted(dag.tasks):
        preds = dag.preds.get(tid)
        if preds:
            dropped = sorted(preds)
            for p in dropped:
                dag.edges[p].discard(tid)
            preds.clear()
            return tid, dropped
    return None


__all__ = [
    "Fault",
    "KNOWN_SITES",
    "active",
    "corrupt",
    "fire",
    "fires",
    "inject",
    "mutate_drop_edges",
    "reset",
]
