"""Test-support subsystems that ship with the runtime (not under tests/):
deterministic fault injection (``repro.testing.faults``) is imported by
production code at named sites, so recovery paths are exercisable on demand
from tests, CI gates, and chaos drills alike (DESIGN.md §10), and
``repro.testing.proptest`` is the offline fallback property-test engine
that keeps the hypothesis property modules running (never skipped) in
containers where hypothesis cannot be installed (DESIGN.md §13)."""

from . import faults, proptest

__all__ = ["faults", "proptest"]
