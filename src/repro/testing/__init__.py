"""Test-support subsystems that ship with the runtime (not under tests/):
deterministic fault injection (``repro.testing.faults``) is imported by
production code at named sites, so recovery paths are exercisable on demand
from tests, CI gates, and chaos drills alike (DESIGN.md §10)."""

from . import faults

__all__ = ["faults"]
