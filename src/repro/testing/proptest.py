"""Minimal hypothesis-compatible property-test engine (offline fallback).

The CI container is offline, so ``pip install hypothesis`` can fail; the
two property-test modules used to ``importorskip`` and silently stop
running (ISSUE 9).  This module implements the small hypothesis subset
those tests use — ``given``, ``settings``, and the ``strategies``
combinators ``integers`` / ``booleans`` / ``sampled_from`` / ``lists`` /
``permutations`` / ``composite`` — so property tests ALWAYS collect and
run.  Import it the compatibility way::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:  # offline container: vendored fallback engine
        from repro.testing.proptest import given, settings, strategies as st

Semantics: each test draws ``max_examples`` examples from a
deterministically seeded PRNG (seed = test name), so a run is exactly
reproducible and CI never flakes on random draws.  On failure the
falsifying example is attached to the exception.  No shrinking — the
real hypothesis, when present, wins the import and provides it.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib
from typing import Any, Callable, Sequence

DEFAULT_MAX_EXAMPLES = 50


class Strategy:
    """A value generator: ``example(rng)`` draws one value."""

    def __init__(self, draw_fn: Callable[[random.Random], Any], label: str = ""):
        self._draw = draw_fn
        self._label = label or "strategy"

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)), f"{self._label}.map")

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<proptest.{self._label}>"


class _Strategies:
    """The ``strategies as st`` namespace."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return Strategy(
            lambda rng: rng.randint(min_value, max_value),
            f"integers({min_value}, {max_value})",
        )

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rng: bool(rng.getrandbits(1)), "booleans()")

    @staticmethod
    def sampled_from(elements: Sequence[Any]) -> Strategy:
        elements = list(elements)
        if not elements:
            raise ValueError("sampled_from requires a non-empty sequence")
        return Strategy(lambda rng: rng.choice(elements), "sampled_from")

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
        def draw(rng: random.Random):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]

        return Strategy(draw, f"lists[{min_size}..{max_size}]")

    @staticmethod
    def permutations(values: Sequence[Any]) -> Strategy:
        values = list(values)

        def draw(rng: random.Random):
            out = list(values)
            rng.shuffle(out)
            return out

        return Strategy(draw, "permutations")

    @staticmethod
    def composite(fn: Callable[..., Any]) -> Callable[..., Strategy]:
        """``@st.composite`` — ``fn(draw, *args)`` builds one example."""

        @functools.wraps(fn)
        def builder(*args, **kwargs) -> Strategy:
            def draw_one(rng: random.Random):
                return fn(lambda s: s.example(rng), *args, **kwargs)

            return Strategy(draw_one, f"composite:{fn.__name__}")

        return builder


strategies = _Strategies()
st = strategies


def settings(*, max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Decorator: attach run settings to a ``given``-wrapped test.

    ``deadline`` (and any other keyword) is accepted and ignored — wall
    deadlines are a flake source on shared CI boxes, which is why every
    caller in this repo already passes ``deadline=None``."""

    def apply(fn):
        fn._proptest_max_examples = max_examples
        return fn

    return apply


def given(*arg_strategies: Strategy, **kw_strategies: Strategy):
    """Decorator: run the test once per drawn example.

    Mirrors hypothesis' call convention: positional strategies append to
    the test's positional args, keyword strategies pass by name."""

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_proptest_max_examples", DEFAULT_MAX_EXAMPLES)
            # deterministic per-test seed: stable across runs and machines
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for i in range(n):
                drawn = [s.example(rng) for s in arg_strategies]
                drawn_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *drawn, **kwargs, **drawn_kw)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (#{i + 1}/{n}, seed={seed}): "
                        f"args={drawn!r} kwargs={drawn_kw!r}"
                    ) from e

        # mimic hypothesis' wrapper shape: plugins (e.g. anyio's) probe
        # `obj.hypothesis.inner_test` to find the undecorated function
        wrapper.hypothesis = type("_Marker", (), {"inner_test": fn})()
        # strip the strategy-supplied parameters from the visible
        # signature, or pytest would demand them as fixtures; positional
        # strategies fill from the rightmost parameter (as in hypothesis)
        params = list(inspect.signature(fn).parameters.values())
        if arg_strategies:
            params = params[: -len(arg_strategies)]
        params = [p for p in params if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(params)
        wrapper.__dict__.pop("__wrapped__", None)
        return wrapper

    return decorate


__all__ = ["Strategy", "given", "settings", "st", "strategies"]
