"""LR schedules (warmup + cosine / WSD)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return fn


def wsd(peak: float, warmup: int, total: int, decay_frac: float = 0.1):
    """Warmup-Stable-Decay."""
    decay_start = int(total * (1 - decay_frac))

    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
        dec = peak * (1.0 - prog)
        return jnp.where(step < warmup, warm, jnp.where(step < decay_start, peak, dec))

    return fn
