"""AdamW with global-norm clipping and configurable moment dtype.

Moment dtype matters at scale: fp32 m/v for a 340B model is 2.7 TB of
optimizer state; bf16 moments halve it (DESIGN.md §8).  Master params stay
fp32; the forward/backward casts to the compute dtype.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple, Union

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: Union[float, Callable[[jnp.ndarray], jnp.ndarray]] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32  # bf16 halves optimizer HBM at scale


def init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, dtype=cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), dtype=jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def update(
    grads, state, params, cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12)) if cfg.clip_norm else 1.0
    lr = cfg.lr(count) if callable(cfg.lr) else jnp.asarray(cfg.lr, jnp.float32)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g) * (1 - b2)
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (step + cfg.weight_decay * p32)
        return (
            p32.astype(p.dtype),
            m32.astype(cfg.state_dtype),
            v32.astype(cfg.state_dtype),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([t[0] for t in new])
    new_m = treedef.unflatten([t[1] for t in new])
    new_v = treedef.unflatten([t[2] for t in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
