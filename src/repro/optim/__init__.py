from .adamw import AdamWConfig, global_norm, init, update
from .schedule import warmup_cosine, wsd

__all__ = ["AdamWConfig", "global_norm", "init", "update", "warmup_cosine", "wsd"]
