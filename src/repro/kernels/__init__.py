"""Pallas TPU kernels (compute hot spots) with jnp oracles in ``ref``."""

from . import ops, ref

__all__ = ["ops", "ref"]
