"""Jit'd public wrappers around the Pallas kernels.

Single-tile convenience entry points (used by the inline executor and unit
tests) plus the batched entry points the wave executors launch directly.
``interpret`` resolves automatically: compiled on TPU, interpreter on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import tile_linalg
from .flash_attention import flash_attention
from .tile_linalg import (
    GRID_FUSED,
    batched_gemm,
    batched_gemmnn,
    batched_getrf,
    batched_potrf,
    batched_syrk,
    batched_trsm,
    batched_trsml,
    batched_trsmu,
    batched_trsmul,
    default_interpret,
    grid_gemm,
    grid_gemmnn,
    grid_getrf,
    grid_potrf,
    grid_syrk,
    grid_trsm,
    grid_trsml,
    grid_trsmu,
    grid_trsmul,
    matmul,
)


@functools.partial(jax.jit, static_argnames=("interpret",))
def potrf(a: jnp.ndarray, interpret=None) -> jnp.ndarray:
    return batched_potrf(a[None], interpret=interpret)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def trsm(l: jnp.ndarray, b: jnp.ndarray, interpret=None) -> jnp.ndarray:
    return batched_trsm(l[None], b[None], interpret=interpret)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def syrk(a: jnp.ndarray, c: jnp.ndarray, interpret=None) -> jnp.ndarray:
    return batched_syrk(a[None], c[None], interpret=interpret)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gemm(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, interpret=None) -> jnp.ndarray:
    return batched_gemm(a[None], b[None], c[None], interpret=interpret)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def getrf(a: jnp.ndarray, interpret=None) -> jnp.ndarray:
    return batched_getrf(a[None], interpret=interpret)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def trsml(l: jnp.ndarray, b: jnp.ndarray, interpret=None) -> jnp.ndarray:
    return batched_trsml(l[None], b[None], interpret=interpret)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def trsmu(u: jnp.ndarray, b: jnp.ndarray, interpret=None) -> jnp.ndarray:
    return batched_trsmu(u[None], b[None], interpret=interpret)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def trsmul(u: jnp.ndarray, b: jnp.ndarray, interpret=None) -> jnp.ndarray:
    return batched_trsmul(u[None], b[None], interpret=interpret)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def lu_solve(a: jnp.ndarray, b: jnp.ndarray, interpret=None):
    """Single-tile factor + forward/backward substitution (LUSOLVE leaf).

    Returns ``(packed, x)``, mirroring ``ref.lu_solve`` — one updated array
    per READWRITE argument of the composed operation."""
    packed = batched_getrf(a[None], interpret=interpret)[0]
    y = batched_trsml(packed[None], b[None], interpret=interpret)[0]
    x = batched_trsmul(packed[None], y[None], interpret=interpret)[0]
    return packed, x


@functools.partial(jax.jit, static_argnames=("interpret",))
def gemmnn(
    a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, interpret=None
) -> jnp.ndarray:
    return batched_gemmnn(a[None], b[None], c[None], interpret=interpret)[0]


__all__ = [
    "GRID_FUSED",
    "grid_gemm",
    "grid_gemmnn",
    "grid_getrf",
    "grid_potrf",
    "grid_syrk",
    "grid_trsm",
    "grid_trsml",
    "grid_trsmu",
    "grid_trsmul",
    "batched_gemm",
    "batched_gemmnn",
    "batched_getrf",
    "batched_potrf",
    "batched_syrk",
    "batched_trsm",
    "batched_trsml",
    "batched_trsmu",
    "batched_trsmul",
    "default_interpret",
    "flash_attention",
    "gemm",
    "gemmnn",
    "getrf",
    "lu_solve",
    "matmul",
    "potrf",
    "syrk",
    "trsm",
    "trsml",
    "trsmu",
    "trsmul",
]
