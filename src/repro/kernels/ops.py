"""Jit'd public wrappers around the Pallas kernels.

Single-tile convenience entry points (used by the inline executor and unit
tests) plus the batched entry points the wave executors launch directly.
``interpret`` resolves automatically: compiled on TPU, interpreter on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import tile_linalg
from .flash_attention import flash_attention
from .tile_linalg import (
    GRID_FUSED,
    batched_gemm,
    batched_potrf,
    batched_syrk,
    batched_trsm,
    default_interpret,
    grid_gemm,
    grid_potrf,
    grid_syrk,
    grid_trsm,
    matmul,
)


@functools.partial(jax.jit, static_argnames=("interpret",))
def potrf(a: jnp.ndarray, interpret=None) -> jnp.ndarray:
    return batched_potrf(a[None], interpret=interpret)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def trsm(l: jnp.ndarray, b: jnp.ndarray, interpret=None) -> jnp.ndarray:
    return batched_trsm(l[None], b[None], interpret=interpret)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def syrk(a: jnp.ndarray, c: jnp.ndarray, interpret=None) -> jnp.ndarray:
    return batched_syrk(a[None], c[None], interpret=interpret)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gemm(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, interpret=None) -> jnp.ndarray:
    return batched_gemm(a[None], b[None], c[None], interpret=interpret)[0]


__all__ = [
    "GRID_FUSED",
    "grid_gemm",
    "grid_potrf",
    "grid_syrk",
    "grid_trsm",
    "batched_gemm",
    "batched_potrf",
    "batched_syrk",
    "batched_trsm",
    "default_interpret",
    "flash_attention",
    "gemm",
    "matmul",
    "potrf",
    "syrk",
    "trsm",
]
