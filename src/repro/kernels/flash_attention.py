"""Causal GQA flash attention as a Pallas TPU kernel (prefill hot spot).

Canonical TPU formulation: grid ``(B, Hq, nq, nk)`` with the KV dimension
innermost; a VMEM fp32 accumulator plus running max/denominator implement
the online softmax across KV block revisits.  Causal and sliding-window
masks prune whole KV blocks with ``pl.when`` (no MXU work for fully masked
blocks) and mask partially-covered blocks element-wise.

GQA is native: the KV ``BlockSpec`` index map sends query head ``h`` to KV
head ``h // (Hq // Hkv)`` — no ``jnp.repeat`` materialization.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tile_linalg import _resolve

NEG_INF = float("-inf")


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: int,
    bq: int,
    bk: int,
    nk: int,
):
    ki = pl.program_id(3)
    q_start = pl.program_id(2) * bq
    k_start = ki * bk

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # prune KV blocks with no unmasked element for this Q block
    needed = jnp.bool_(True)
    if causal:
        needed &= k_start <= q_start + bq - 1
    if window > 0:
        needed &= k_start + bk - 1 > q_start - window

    @pl.when(needed)
    def _compute():
        q = q_ref[...][0, 0].astype(jnp.float32) * scale  # (bq, D)
        k = k_ref[...][0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[...][0, 0].astype(jnp.float32)  # (bk, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

        qpos = q_start + jnp.arange(bq)[:, None]
        kpos = k_start + jnp.arange(bk)[None, :]
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...][:, 0]
        l_prev = l_ref[...][:, 0]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        m_safe = jnp.where(jnp.isneginf(m_cur), 0.0, m_cur)
        p = jnp.exp(s - m_safe[:, None])  # fully-masked rows -> exp(-inf)=0
        alpha = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
        l_cur = alpha * l_prev + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_cur[:, None]
        l_ref[...] = l_cur[:, None]

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...][:, 0]
        o = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)[:, None]
        o_ref[...] = o[None, None].astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # (B, Hq, S, D)
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,  # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    g = Hq // Hkv
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nk = S // bk
    scale = (D ** -0.5) if scale is None else scale

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        bq=bq,
        bk=bk,
        nk=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, S // bq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=_resolve(interpret),
    )(q, k, v)
