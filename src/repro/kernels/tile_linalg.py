"""Pallas TPU tile kernels for blocked dense linear algebra.

These are the leaves of the UTP task hierarchy (the paper's cuBLAS wrapper
analog).  Every kernel is *batched*: it takes a stack of tiles ``(n, b, b)``
and maps the batch over the Pallas grid, so a whole wave of independent
same-shaped tasks becomes ONE kernel launch (DESIGN.md §2: wave batching).

TPU adaptation notes:
  - tiles live in VMEM via explicit ``BlockSpec``s; ``b`` should be a
    multiple of 128 so the MXU sees aligned matmuls (tests sweep smaller
    shapes in interpret mode where alignment is not enforced);
  - POTRF/TRSM are column-recurrences (O(b) steps of rank-1/matvec work on
    the VPU); they are only ever applied to the O(p) diagonal/panel tiles
    while the O(p^3) trailing updates (SYRK/GEMM) are single MXU matmuls.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _resolve(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else interpret


def _tile_spec(b: int):
    return pl.BlockSpec((1, b, b), lambda i: (i, 0, 0))


# --------------------------------------------------------------------------
# POTRF: batched lower Cholesky of (n, b, b) tiles
# --------------------------------------------------------------------------
def _potrf_kernel(a_ref, l_ref):
    a = a_ref[...][0].astype(jnp.float32)
    b = a.shape[-1]
    idx = jnp.arange(b)

    def body(j, L):
        # s[i] = sum_{k<j} L[i,k] * L[j,k]  (columns >= j of L are still zero)
        s = L @ L[j]
        djj = jnp.sqrt(a[j, j] - s[j])
        col = (a[:, j] - s) / djj
        col = jnp.where(idx > j, col, 0.0)
        col = col.at[j].set(djj)
        return L.at[:, j].set(col)

    L = lax.fori_loop(0, b, body, jnp.zeros_like(a))
    l_ref[...] = L[None].astype(l_ref.dtype)


def batched_potrf(a: jnp.ndarray, *, interpret: Optional[bool] = None) -> jnp.ndarray:
    n, b, _ = a.shape
    return pl.pallas_call(
        _potrf_kernel,
        grid=(n,),
        in_specs=[_tile_spec(b)],
        out_specs=_tile_spec(b),
        out_shape=jax.ShapeDtypeStruct((n, b, b), a.dtype),
        interpret=_resolve(interpret),
    )(a)


# --------------------------------------------------------------------------
# TRSM: batched X = B @ inv(L)^T  (right, lower-triangular, transposed)
# --------------------------------------------------------------------------
def _trsm_kernel(l_ref, b_ref, x_ref):
    L = l_ref[...][0].astype(jnp.float32)
    B = b_ref[...][0].astype(jnp.float32)
    nb = L.shape[-1]

    def body(j, X):
        # (X L^T)[:, j] = sum_{k<=j} X[:,k] L[j,k]; cols >= j of X still zero
        s = X @ L[j]
        col = (B[:, j] - s) / L[j, j]
        return X.at[:, j].set(col)

    X = lax.fori_loop(0, nb, body, jnp.zeros_like(B))
    x_ref[...] = X[None].astype(x_ref.dtype)


def batched_trsm(
    l: jnp.ndarray, b: jnp.ndarray, *, interpret: Optional[bool] = None
) -> jnp.ndarray:
    n, nb, _ = l.shape
    return pl.pallas_call(
        _trsm_kernel,
        grid=(n,),
        in_specs=[_tile_spec(nb), _tile_spec(nb)],
        out_specs=_tile_spec(nb),
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        interpret=_resolve(interpret),
    )(l, b)


# --------------------------------------------------------------------------
# SYRK: batched C - A @ A^T   /   GEMM: batched C - A @ B^T  (MXU matmuls)
# --------------------------------------------------------------------------
def _syrk_kernel(a_ref, c_ref, o_ref):
    a = a_ref[...][0]
    c = c_ref[...][0].astype(jnp.float32)
    upd = c - jnp.dot(a, a.T, preferred_element_type=jnp.float32)
    o_ref[...] = upd[None].astype(o_ref.dtype)


def batched_syrk(
    a: jnp.ndarray, c: jnp.ndarray, *, interpret: Optional[bool] = None
) -> jnp.ndarray:
    n, b, _ = a.shape
    return pl.pallas_call(
        _syrk_kernel,
        grid=(n,),
        in_specs=[_tile_spec(b), _tile_spec(b)],
        out_specs=_tile_spec(b),
        out_shape=jax.ShapeDtypeStruct(c.shape, c.dtype),
        interpret=_resolve(interpret),
    )(a, c)


def _gemm_kernel(a_ref, b_ref, c_ref, o_ref):
    a = a_ref[...][0]
    b = b_ref[...][0]
    c = c_ref[...][0].astype(jnp.float32)
    upd = c - jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    o_ref[...] = upd[None].astype(o_ref.dtype)


def batched_gemm(
    a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, *, interpret: Optional[bool] = None
) -> jnp.ndarray:
    n, nb, _ = a.shape
    return pl.pallas_call(
        _gemm_kernel,
        grid=(n,),
        in_specs=[_tile_spec(nb), _tile_spec(nb), _tile_spec(nb)],
        out_specs=_tile_spec(nb),
        out_shape=jax.ShapeDtypeStruct(c.shape, c.dtype),
        interpret=_resolve(interpret),
    )(a, b, c)


# --------------------------------------------------------------------------
# General tiled matmul with K-revisiting and a VMEM fp32 accumulator —
# the canonical MXU pattern (used standalone and by benchmarks).
# --------------------------------------------------------------------------
def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _fin():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, bm, bn, bk)
    nk = k // bk
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=_resolve(interpret),
    )(a, b)
