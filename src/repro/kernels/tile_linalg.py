"""Pallas TPU tile kernels for blocked dense linear algebra.

These are the leaves of the UTP task hierarchy (the paper's cuBLAS wrapper
analog).  Every kernel is *batched*: it takes a stack of tiles ``(n, b, b)``
and maps the batch over the Pallas grid, so a whole wave of independent
same-shaped tasks becomes ONE kernel launch (DESIGN.md §2: wave batching).

TPU adaptation notes:
  - tiles live in VMEM via explicit ``BlockSpec``s; ``b`` should be a
    multiple of 128 so the MXU sees aligned matmuls (tests sweep smaller
    shapes in interpret mode where alignment is not enforced);
  - POTRF/TRSM are column-recurrences (O(b) steps of rank-1/matvec work on
    the VPU); they are only ever applied to the O(p) diagonal/panel tiles
    while the O(p^3) trailing updates (SYRK/GEMM) are single MXU matmuls.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _resolve(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else interpret


def _tile_spec(b: int):
    return pl.BlockSpec((1, b, b), lambda i: (i, 0, 0))


def _stack_spec(shape):
    """BlockSpec for one (possibly non-square) tile of an (n, br, bc) stack."""
    return pl.BlockSpec((1,) + tuple(shape[1:]), lambda i: (i, 0, 0))


# --------------------------------------------------------------------------
# Tile bodies — pure (b, b) math shared by the batched per-tile kernels and
# the fused grid kernels below.
# --------------------------------------------------------------------------
def _potrf_tile(a: jnp.ndarray) -> jnp.ndarray:
    a = a.astype(jnp.float32)
    b = a.shape[-1]
    idx = jnp.arange(b)

    def body(j, L):
        # s[i] = sum_{k<j} L[i,k] * L[j,k]  (columns >= j of L are still zero)
        s = L @ L[j]
        djj = jnp.sqrt(a[j, j] - s[j])
        col = (a[:, j] - s) / djj
        col = jnp.where(idx > j, col, 0.0)
        col = col.at[j].set(djj)
        return L.at[:, j].set(col)

    return lax.fori_loop(0, b, body, jnp.zeros_like(a))


def _trsm_tile(L: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    L = L.astype(jnp.float32)
    B = B.astype(jnp.float32)
    nb = L.shape[-1]

    def body(j, X):
        # (X L^T)[:, j] = sum_{k<=j} X[:,k] L[j,k]; cols >= j of X still zero
        s = X @ L[j]
        col = (B[:, j] - s) / L[j, j]
        return X.at[:, j].set(col)

    return lax.fori_loop(0, nb, body, jnp.zeros_like(B))


def _getrf_tile(a: jnp.ndarray) -> jnp.ndarray:
    """Pivot-free right-looking LU of one tile; L\\U packed (unit L implicit).

    Column recurrence on the VPU: scale column k below the pivot, then one
    masked rank-1 update of the trailing submatrix — O(b) steps, mirroring
    ``_potrf_tile``.
    """
    a = a.astype(jnp.float32)
    b = a.shape[-1]
    idx = jnp.arange(b)

    def body(k, m):
        col = jnp.where(idx > k, m[:, k] / m[k, k], m[:, k])
        m = m.at[:, k].set(col)
        l = jnp.where(idx > k, col, 0.0)
        u = jnp.where(idx > k, m[k, :], 0.0)
        return m - l[:, None] * u[None, :]

    return lax.fori_loop(0, b, body, a)


def _trsml_tile(L: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """X = inv(L) @ B with L unit-lower (stored diagonal/upper ignored).

    Row recurrence: X[i] = B[i] - L[i] @ X.  Rows >= i of X are still zero,
    so the packed block's diagonal and upper junk multiply zeros — no
    masking needed (same trick as ``_trsm_tile``).
    """
    L = L.astype(jnp.float32)
    B = B.astype(jnp.float32)
    nb = L.shape[-1]

    def body(i, X):
        return X.at[i].set(B[i] - L[i] @ X)

    return lax.fori_loop(0, nb, body, jnp.zeros_like(B))


def _trsmu_tile(U: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """X = B @ inv(U) with U upper non-unit (stored lower junk ignored).

    Column recurrence: X[:, j] = (B[:, j] - X @ U[:, j]) / U[j, j]; columns
    >= j of X are still zero, masking U's sub-diagonal content.
    """
    U = U.astype(jnp.float32)
    B = B.astype(jnp.float32)
    nb = U.shape[-1]

    def body(j, X):
        s = X @ U[:, j]
        return X.at[:, j].set((B[:, j] - s) / U[j, j])

    return lax.fori_loop(0, nb, body, jnp.zeros_like(B))


def _trsmul_tile(U: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """X = inv(U) @ B with U upper non-unit (stored lower junk ignored).

    Bottom-up row recurrence: X[i] = (B[i] - U[i] @ X) / U[i, i].  Rows
    <= i of X are still zero when row i is computed, so U's sub-diagonal
    content multiplies zeros — packed L\\U blocks pass unmasked (same trick
    as ``_trsml_tile``, run in reverse row order).
    """
    U = U.astype(jnp.float32)
    B = B.astype(jnp.float32)
    nb = U.shape[-1]

    def body(j, X):
        i = nb - 1 - j
        s = U[i] @ X
        return X.at[i].set((B[i] - s) / U[i, i])

    return lax.fori_loop(0, nb, body, jnp.zeros_like(B))


def _gemmnn_tile(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    return c.astype(jnp.float32) - jnp.dot(
        a, b, preferred_element_type=jnp.float32
    )


def _syrk_tile(a: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    return c.astype(jnp.float32) - jnp.dot(
        a, a.T, preferred_element_type=jnp.float32
    )


def _gemm_tile(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    return c.astype(jnp.float32) - jnp.dot(
        a, b.T, preferred_element_type=jnp.float32
    )


# --------------------------------------------------------------------------
# POTRF: batched lower Cholesky of (n, b, b) tiles
# --------------------------------------------------------------------------
def _potrf_kernel(a_ref, l_ref):
    L = _potrf_tile(a_ref[...][0])
    l_ref[...] = L[None].astype(l_ref.dtype)


def batched_potrf(a: jnp.ndarray, *, interpret: Optional[bool] = None) -> jnp.ndarray:
    n, b, _ = a.shape
    return pl.pallas_call(
        _potrf_kernel,
        grid=(n,),
        in_specs=[_tile_spec(b)],
        out_specs=_tile_spec(b),
        out_shape=jax.ShapeDtypeStruct((n, b, b), a.dtype),
        interpret=_resolve(interpret),
    )(a)


# --------------------------------------------------------------------------
# TRSM: batched X = B @ inv(L)^T  (right, lower-triangular, transposed)
# --------------------------------------------------------------------------
def _trsm_kernel(l_ref, b_ref, x_ref):
    X = _trsm_tile(l_ref[...][0], b_ref[...][0])
    x_ref[...] = X[None].astype(x_ref.dtype)


def batched_trsm(
    l: jnp.ndarray, b: jnp.ndarray, *, interpret: Optional[bool] = None
) -> jnp.ndarray:
    n, nb, _ = l.shape
    return pl.pallas_call(
        _trsm_kernel,
        grid=(n,),
        in_specs=[_tile_spec(nb), _tile_spec(nb)],
        out_specs=_tile_spec(nb),
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        interpret=_resolve(interpret),
    )(l, b)


# --------------------------------------------------------------------------
# SYRK: batched C - A @ A^T   /   GEMM: batched C - A @ B^T  (MXU matmuls)
# --------------------------------------------------------------------------
def _syrk_kernel(a_ref, c_ref, o_ref):
    upd = _syrk_tile(a_ref[...][0], c_ref[...][0])
    o_ref[...] = upd[None].astype(o_ref.dtype)


def batched_syrk(
    a: jnp.ndarray, c: jnp.ndarray, *, interpret: Optional[bool] = None
) -> jnp.ndarray:
    n, b, _ = a.shape
    return pl.pallas_call(
        _syrk_kernel,
        grid=(n,),
        in_specs=[_tile_spec(b), _tile_spec(b)],
        out_specs=_tile_spec(b),
        out_shape=jax.ShapeDtypeStruct(c.shape, c.dtype),
        interpret=_resolve(interpret),
    )(a, c)


def _gemm_kernel(a_ref, b_ref, c_ref, o_ref):
    upd = _gemm_tile(a_ref[...][0], b_ref[...][0], c_ref[...][0])
    o_ref[...] = upd[None].astype(o_ref.dtype)


def batched_gemm(
    a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, *, interpret: Optional[bool] = None
) -> jnp.ndarray:
    n, nb, _ = a.shape
    return pl.pallas_call(
        _gemm_kernel,
        grid=(n,),
        in_specs=[_tile_spec(nb), _tile_spec(nb), _tile_spec(nb)],
        out_specs=_tile_spec(nb),
        out_shape=jax.ShapeDtypeStruct(c.shape, c.dtype),
        interpret=_resolve(interpret),
    )(a, b, c)


# --------------------------------------------------------------------------
# GETRF: batched pivot-free LU  /  TRSML: batched inv(L) @ B (left, unit-
# lower)  /  TRSMU: batched B @ inv(U) (right, upper)  /  GEMMNN: batched
# C - A @ B — the LU operation family (DESIGN.md §6)
# --------------------------------------------------------------------------
def _getrf_kernel(a_ref, o_ref):
    o_ref[...] = _getrf_tile(a_ref[...][0])[None].astype(o_ref.dtype)


def batched_getrf(a: jnp.ndarray, *, interpret: Optional[bool] = None) -> jnp.ndarray:
    n, b, _ = a.shape
    return pl.pallas_call(
        _getrf_kernel,
        grid=(n,),
        in_specs=[_tile_spec(b)],
        out_specs=_tile_spec(b),
        out_shape=jax.ShapeDtypeStruct((n, b, b), a.dtype),
        interpret=_resolve(interpret),
    )(a)


def _trsml_kernel(l_ref, b_ref, x_ref):
    X = _trsml_tile(l_ref[...][0], b_ref[...][0])
    x_ref[...] = X[None].astype(x_ref.dtype)


def batched_trsml(
    l: jnp.ndarray, b: jnp.ndarray, *, interpret: Optional[bool] = None
) -> jnp.ndarray:
    n, nb, _ = l.shape
    # b tiles may be non-square (e.g. a blocked vector right-hand side)
    return pl.pallas_call(
        _trsml_kernel,
        grid=(n,),
        in_specs=[_tile_spec(nb), _stack_spec(b.shape)],
        out_specs=_stack_spec(b.shape),
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        interpret=_resolve(interpret),
    )(l, b)


def _trsmu_kernel(u_ref, b_ref, x_ref):
    X = _trsmu_tile(u_ref[...][0], b_ref[...][0])
    x_ref[...] = X[None].astype(x_ref.dtype)


def batched_trsmu(
    u: jnp.ndarray, b: jnp.ndarray, *, interpret: Optional[bool] = None
) -> jnp.ndarray:
    n, nb, _ = u.shape
    return pl.pallas_call(
        _trsmu_kernel,
        grid=(n,),
        in_specs=[_tile_spec(nb), _stack_spec(b.shape)],
        out_specs=_stack_spec(b.shape),
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        interpret=_resolve(interpret),
    )(u, b)


def _trsmul_kernel(u_ref, b_ref, x_ref):
    X = _trsmul_tile(u_ref[...][0], b_ref[...][0])
    x_ref[...] = X[None].astype(x_ref.dtype)


def batched_trsmul(
    u: jnp.ndarray, b: jnp.ndarray, *, interpret: Optional[bool] = None
) -> jnp.ndarray:
    n, nb, _ = u.shape
    return pl.pallas_call(
        _trsmul_kernel,
        grid=(n,),
        in_specs=[_tile_spec(nb), _stack_spec(b.shape)],
        out_specs=_stack_spec(b.shape),
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        interpret=_resolve(interpret),
    )(u, b)


def _gemmnn_kernel(a_ref, b_ref, c_ref, o_ref):
    upd = _gemmnn_tile(a_ref[...][0], b_ref[...][0], c_ref[...][0])
    o_ref[...] = upd[None].astype(o_ref.dtype)


def batched_gemmnn(
    a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, *, interpret: Optional[bool] = None
) -> jnp.ndarray:
    n = a.shape[0]
    return pl.pallas_call(
        _gemmnn_kernel,
        grid=(n,),
        in_specs=[_stack_spec(a.shape), _stack_spec(b.shape), _stack_spec(c.shape)],
        out_specs=_stack_spec(c.shape),
        out_shape=jax.ShapeDtypeStruct(c.shape, c.dtype),
        interpret=_resolve(interpret),
    )(a, b, c)


# --------------------------------------------------------------------------
# Fused grid kernels (DESIGN.md §2, grid-resident epoch).
#
# Gather -> compute -> scatter in ONE kernel over the resident
# ``(nr, nc, br, bc)`` grid: per-task block coordinates arrive as
# scalar-prefetched ``(n, 2)`` int32 arrays, the BlockSpec index maps DMA the
# addressed blocks straight from the grid into VMEM, and the output aliases
# the written arg's grid so the scatter is in place — no gathered tile
# stacks ever materialize in HBM.  Callers must pass exact (unpadded) group
# sizes: tasks in a group are independent, so distinct write blocks are
# guaranteed, but duplicated trailing indices would re-read their own
# scatter for read-write operations.
# --------------------------------------------------------------------------
def make_grid_fused(tile_fn, arity: int, write_arg: int):
    """Build a fused gather/compute/scatter entry point for ``tile_fn``.

    ``tile_fn(*tiles) -> tile`` is the pure per-tile body; ``write_arg`` is
    the argument whose grid receives the result (and whose blocks the output
    aliases).  Returns ``call(idxs, grids, *, interpret=None) -> new grid``.

    ``call`` accepts either resident single-workload grids
    ``(nr, nc, br, bc)`` or *stacked* grids ``(B, nr, nc, br, bc)`` holding B
    structurally identical workloads (DESIGN.md §7): the stacked form runs
    the same kernel body under a leading batch grid dimension — grid
    ``(B, n)`` — with the per-lane block-index array shared by every lane,
    so a batch of B costs one launch and no extra index traffic.
    """

    def kernel(*refs):
        in_refs = refs[arity : 2 * arity]
        o_ref = refs[2 * arity]
        out = tile_fn(*(r[0, 0] for r in in_refs))
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)

    def kernel_stacked(*refs):
        in_refs = refs[arity : 2 * arity]
        o_ref = refs[2 * arity]
        out = tile_fn(*(r[0, 0, 0] for r in in_refs))
        o_ref[0, 0, 0, :, :] = out.astype(o_ref.dtype)

    def _imap(a: int):
        def imap(i, *idx_refs):
            r = idx_refs[a]
            return (r[i, 0], r[i, 1], 0, 0)

        return imap

    def _imap_stacked(a: int):
        def imap(b, i, *idx_refs):
            r = idx_refs[a]
            return (b, r[i, 0], r[i, 1], 0, 0)

        return imap

    def call(idxs, grids, *, interpret: Optional[bool] = None):
        assert len(idxs) == arity and len(grids) == arity
        n = idxs[0].shape[0]
        from jax.experimental.pallas import tpu as pltpu

        stacked = grids[write_arg].ndim == 5
        if stacked:
            grid = (grids[write_arg].shape[0], n)
            body, imap_of, lead = kernel_stacked, _imap_stacked, (1, 1, 1)
        else:
            grid = (n,)
            body, imap_of, lead = kernel, _imap, (1, 1)
        in_specs = [
            pl.BlockSpec(lead + grids[a].shape[-2:], imap_of(a))
            for a in range(arity)
        ]
        spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=arity,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                lead + grids[write_arg].shape[-2:], imap_of(write_arg)
            ),
        )
        wg = grids[write_arg]
        return pl.pallas_call(
            body,
            grid_spec=spec,
            out_shape=jax.ShapeDtypeStruct(wg.shape, wg.dtype),
            input_output_aliases={arity + write_arg: 0},
            interpret=_resolve(interpret),
        )(*idxs, *grids)

    return call


grid_potrf = make_grid_fused(_potrf_tile, arity=1, write_arg=0)
grid_trsm = make_grid_fused(_trsm_tile, arity=2, write_arg=1)
grid_syrk = make_grid_fused(_syrk_tile, arity=2, write_arg=1)
grid_gemm = make_grid_fused(_gemm_tile, arity=3, write_arg=2)
grid_getrf = make_grid_fused(_getrf_tile, arity=1, write_arg=0)
grid_trsml = make_grid_fused(_trsml_tile, arity=2, write_arg=1)
grid_trsmu = make_grid_fused(_trsmu_tile, arity=2, write_arg=1)
grid_trsmul = make_grid_fused(_trsmul_tile, arity=2, write_arg=1)
grid_gemmnn = make_grid_fused(_gemmnn_tile, arity=3, write_arg=2)

# op name -> (fused call, write_arg); consumed by the WaveProgram compiler
# when the backend is 'pallas' and the group writes exactly that argument.
GRID_FUSED = {
    "potrf": (grid_potrf, 0),
    "trsm": (grid_trsm, 1),
    "syrk": (grid_syrk, 1),
    "gemm": (grid_gemm, 2),
    "getrf": (grid_getrf, 0),
    "trsml": (grid_trsml, 1),
    "trsmu": (grid_trsmu, 1),
    "trsmul": (grid_trsmul, 1),
    "gemmnn": (grid_gemmnn, 2),
}


# --------------------------------------------------------------------------
# General tiled matmul with K-revisiting and a VMEM fp32 accumulator —
# the canonical MXU pattern (used standalone and by benchmarks).
# --------------------------------------------------------------------------
def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _fin():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, bm, bn, bk)
    nk = k // bk
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=_resolve(interpret),
    )(a, b)
