"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

Conventions match the blocked left-looking Cholesky (paper Fig. 2b):
    potrf(a)      -> lower Cholesky factor L of a
    trsm(l, b)    -> b @ inv(l)^T         (right, lower, transposed)
    syrk(a, c)    -> c - a @ a^T
    gemm(a, b, c) -> c - a @ b^T
All oracles compute in float32 and cast back to the input dtype.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular


def _f32(x):
    return x.astype(jnp.float32)


def potrf(a: jnp.ndarray) -> jnp.ndarray:
    return jnp.linalg.cholesky(_f32(a)).astype(a.dtype)


def trsm(l: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    x = solve_triangular(_f32(l), _f32(b).T, lower=True)
    return x.T.astype(b.dtype)


def syrk(a: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    return (_f32(c) - _f32(a) @ _f32(a).T).astype(c.dtype)


def gemm(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    return (_f32(c) - _f32(a) @ _f32(b).T).astype(c.dtype)


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return (_f32(a) @ _f32(b)).astype(a.dtype)


def flash_attention(
    q: jnp.ndarray,  # (B, Hq, S, D)
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,  # (B, Hkv, S, D)
    causal: bool = True,
    window: int = 0,  # 0 = global; >0 = local sliding window
    scale: float | None = None,
) -> jnp.ndarray:
    """Reference attention with GQA head-group broadcasting."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    kq = jnp.repeat(_f32(k), g, axis=1)
    vq = jnp.repeat(_f32(v), g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", _f32(q) * scale, kq)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= ki > qi - window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vq).astype(q.dtype)
