"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

Conventions match the blocked left-looking Cholesky (paper Fig. 2b):
    potrf(a)      -> lower Cholesky factor L of a
    trsm(l, b)    -> b @ inv(l)^T         (right, lower, transposed)
    syrk(a, c)    -> c - a @ a^T
    gemm(a, b, c) -> c - a @ b^T
and the blocked right-looking pivot-free LU (DESIGN.md §6):
    getrf(a)        -> packed L\\U factors (L unit-lower implicit, U upper)
    trsml(l, b)     -> inv(tril(l, unit)) @ b   (left, lower, unit-diagonal)
    trsmu(u, b)     -> b @ inv(triu(u))         (right, upper, non-unit)
    trsmul(u, b)    -> inv(triu(u)) @ b         (left, upper, non-unit)
    gemmnn(a, b, c) -> c - a @ b
    lu_solve(a, b)  -> (packed L\\U of a, x with a @ x == b)
All oracles compute in float32 and cast back to the input dtype.  The
triangular-solve oracles read only their own triangle (plus U's diagonal),
so packed L\\U blocks can be passed without masking.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular


def _f32(x):
    return x.astype(jnp.float32)


def potrf(a: jnp.ndarray) -> jnp.ndarray:
    return jnp.linalg.cholesky(_f32(a)).astype(a.dtype)


def trsm(l: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    x = solve_triangular(_f32(l), _f32(b).T, lower=True)
    return x.T.astype(b.dtype)


def syrk(a: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    return (_f32(c) - _f32(a) @ _f32(a).T).astype(c.dtype)


def gemm(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    return (_f32(c) - _f32(a) @ _f32(b).T).astype(c.dtype)


def getrf(a: jnp.ndarray) -> jnp.ndarray:
    """Pivot-free right-looking LU; returns L\\U packed into one matrix.

    Delegates to the shared pure-jnp tile body (``_getrf_tile`` uses no
    Pallas primitives): pivot-free LU has exactly one defined recurrence,
    so a re-implementation here could only diverge from it.  Independent
    coverage comes from ``jax.scipy.linalg.lu`` comparisons in test_lu.py.
    """
    from .tile_linalg import _getrf_tile

    return _getrf_tile(_f32(a)).astype(a.dtype)


def trsml(l: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    x = solve_triangular(_f32(l), _f32(b), lower=True, unit_diagonal=True)
    return x.astype(b.dtype)


def trsmu(u: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    # x @ u = b  <=>  u^T x^T = b^T (solve_triangular reads triu(u) only)
    x = solve_triangular(_f32(u), _f32(b).T, lower=False, trans="T")
    return x.T.astype(b.dtype)


def trsmul(u: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    # left-upper backward substitution (solve_triangular reads triu(u) only)
    x = solve_triangular(_f32(u), _f32(b), lower=False)
    return x.astype(b.dtype)


def gemmnn(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    return (_f32(c) - _f32(a) @ _f32(b)).astype(c.dtype)


def lu_solve(a: jnp.ndarray, b: jnp.ndarray):
    """Whole lu_solve pipeline on one block: factor then two substitutions.

    Returns ``(packed, x)`` — one updated array per READWRITE argument of
    the composed LUSOLVE operation (a is replaced by its packed L\\U factor,
    b by the solution of ``a @ x == b``)."""
    packed = getrf(a)
    return packed, trsmul(packed, trsml(packed, b))


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return (_f32(a) @ _f32(b)).astype(a.dtype)


def flash_attention(
    q: jnp.ndarray,  # (B, Hq, S, D)
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,  # (B, Hkv, S, D)
    causal: bool = True,
    window: int = 0,  # 0 = global; >0 = local sliding window
    scale: float | None = None,
) -> jnp.ndarray:
    """Reference attention with GQA head-group broadcasting."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    kq = jnp.repeat(_f32(k), g, axis=1)
    vq = jnp.repeat(_f32(v), g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", _f32(q) * scale, kq)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= ki > qi - window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vq).astype(q.dtype)
