"""llama4-maverick-400b-a17b [moe]: 128 experts top-1 + shared expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1
[hf:meta-llama/Llama-4-Maverick-17B-128E (shapes per Llama-4-Scout-17B-16E);
unverified]

head_dim=128, SwiGLU, RMSNorm.  Llama-4 interleaves: every other layer is
routed (top-1 of 128 experts + always-on shared expert), the rest dense.
"Early fusion" is the VLM frontend — backbone only here.  The 400B total /
17B active split is the EP stress test of the pool.  Full attention ->
``long_500k`` skipped.
"""

import jax.numpy as jnp

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    rope_theta=500_000.0,
    n_experts=128,
    top_k=1,
    moe_interleave=2,
    shared_expert=True,
    optim_state_dtype=jnp.bfloat16,
)
