"""pixtral-12b [vlm]: Pixtral-ViT frontend + Mistral-Nemo decoder.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409; unverified]

Backbone only — the Pixtral ViT is a STUB (``input_specs`` provides the
fused patch+text embedding sequence, see models/frontend.py).
head_dim=128, SwiGLU, RMSNorm, RoPE theta 1M.  Full attention ->
``long_500k`` skipped.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1_000_000.0,
    frontend="vision",
)
