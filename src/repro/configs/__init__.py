"""Architecture registry: the 10 assigned archs + their input-shape cells."""

from typing import Dict, List

from .base import SHAPES, ArchConfig, ShapeConfig
from .gemma3_12b import CONFIG as _gemma3
from .granite_moe_1b import CONFIG as _granite
from .llama4_maverick import CONFIG as _llama4
from .musicgen_large import CONFIG as _musicgen
from .nemotron4_340b import CONFIG as _nemotron
from .pixtral_12b import CONFIG as _pixtral
from .qwen3_32b import CONFIG as _qwen3
from .rwkv6_3b import CONFIG as _rwkv6
from .starcoder2_7b import CONFIG as _starcoder2
from .zamba2_2p7b import CONFIG as _zamba2

ARCHS: Dict[str, ArchConfig] = {
    c.name: c
    for c in (
        _musicgen,
        _rwkv6,
        _qwen3,
        _nemotron,
        _starcoder2,
        _gemma3,
        _zamba2,
        _granite,
        _llama4,
        _pixtral,
    )
}


def get_arch(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")


def cell_supported(cfg: ArchConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention (assignment rule)."""
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True


def all_cells() -> List[tuple]:
    """Every supported (arch, shape) cell — 33 of the nominal 40."""
    out = []
    for a in ARCHS.values():
        for s in SHAPES.values():
            if cell_supported(a, s):
                out.append((a, s))
    return out


__all__ = [
    "ARCHS",
    "SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "all_cells",
    "cell_supported",
    "get_arch",
    "get_shape",
]
