"""rwkv6-3b [ssm]: RWKV-6 "Finch" — attention-free, data-dependent decay.

32L d_model=2560 (attn-free) d_ff=8960 vocab=65536
[arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b]

Head size 64 -> 40 heads.  O(1) decode state (wkv state + token-shift
carries), so ``long_500k`` RUNS.  n_heads/n_kv recorded for bookkeeping
only (no attention layers).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="rwkv",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv=40,
    head_dim=64,
    d_ff=8960,
    vocab=65536,
    norm_type="layernorm",
    pos_type="none",
    rwkv_head_size=64,
    # Q=16 hillclimbed (§Perf cell C): the (B,Q,Q,H,K) pairwise tensor's
    # HBM traffic scales with Q; compute stays recurrence-dominated.
    rwkv_chunk=16,
    subquadratic=True,
)
