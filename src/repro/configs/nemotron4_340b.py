"""nemotron-4-340b [dense]: GQA + squared-ReLU MLP.

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000
[arXiv:2402.16819 (Nemotron-4); unverified]

head_dim=192, squared-ReLU (non-gated) MLP, LayerNorm, RoPE theta 10k.
The memory/collective stress test of the pool: 340B params demand FSDP
over the full data axis and bf16 optimizer moments (DESIGN.md §8).
Full attention -> ``long_500k`` skipped.
"""

import jax.numpy as jnp

from .base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv=8,
    head_dim=192,
    d_ff=73728,
    vocab=256000,
    mlp_type="relu2",
    norm_type="layernorm",
    rope_theta=10_000.0,
    optim_state_dtype=jnp.bfloat16,  # 2x HBM saving on m/v at 340B
    # microbatching REFUTED for fit (§Perf): per-microbatch grad reductions
    # scale collective time ~m x; 340B single-pod training runs multi-pod
    # (FSDP over ("pod","data")) instead — see EXPERIMENTS §Dry-run.
)
