"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention block.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B]

54 Mamba2 layers (expand 2, head dim P=64 -> 80 SSM heads, state N=64,
conv 4); ONE shared attention+MLP block (32-head MHA, d_ff 10240, GELU)
applied after every 6 Mamba layers — the weights are shared across all 9
invocations (the zamba2 parameter-sharing trick).  Simplification noted in
DESIGN.md: the shared-block input is the residual stream x (the published
model concatenates the original embeddings and applies a per-invocation
LoRA).  O(1) SSM decode state -> ``long_500k`` RUNS (the shared block's KV
cache is the only sequence-length state).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv=32,
    head_dim=80,
    d_ff=10240,
    vocab=32000,
    mlp_type="gelu",
    ssm_state=64,
    ssm_heads=80,
    ssm_conv=4,
    ssm_expand=2,
    ssm_chunk=128,
    hybrid_attn_every=6,
    subquadratic=True,
)
