"""starcoder2-7b [dense]: GQA + RoPE code model.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152
[arXiv:2402.19173; hf:bigcode/starcoder2-7b]

head_dim=128, non-gated GELU MLP, LayerNorm, RoPE theta 1e5.
Full attention -> ``long_500k`` skipped.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv=4,
    head_dim=128,
    d_ff=18432,
    vocab=49152,
    mlp_type="gelu",
    norm_type="layernorm",
    rope_theta=100_000.0,
)
