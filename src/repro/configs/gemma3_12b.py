"""gemma3-12b [dense]: 5:1 local:global attention, 128k context.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144
[hf:google/gemma-3-12b-pt (family config per google/gemma-3-1b-pt); unverified]

head_dim=256, gated-GELU, RMSNorm, qk-norm, tied embeddings with
sqrt(d_model) embedding scale.  Pattern LLLLLG (window 1024 locals, global
every 6th layer); local layers use rope theta 10k, globals 1M.
5/6 of layers are sub-quadratic and decode cost is linear -> ``long_500k``
RUNS (global layers keep a sequence-sharded cache; with
``windowed_cache=True`` local layers keep only a 1024-slot cache).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    mlp_type="geglu",
    qk_norm=True,
    tie_embeddings=True,
    embed_scale=True,
    local_per_global=5,
    local_window=1024,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    subquadratic=True,
)
