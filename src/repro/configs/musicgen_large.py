"""musicgen-large [audio]: decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32 = MHA) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf:facebook/musicgen-large]

Backbone only — the EnCodec frontend is a STUB (``input_specs`` provides
precomputed 50 Hz frame embeddings, see models/frontend.py).  MusicGen uses
a vanilla transformer decoder: LayerNorm, non-gated GELU MLP, sinusoidal
positions.  Full attention -> ``long_500k`` is skipped (DESIGN.md
§Arch-applicability).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    head_dim=64,
    d_ff=8192,
    vocab=2048,
    mlp_type="gelu",
    norm_type="layernorm",
    pos_type="sinusoidal",
    frontend="audio",
)
