"""qwen3-32b [dense]: GQA + per-head qk-norm.

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936
[hf:Qwen/Qwen3-32B (family config per hf:Qwen/Qwen3-8B); hf]

head_dim=128, SwiGLU, RMSNorm, RoPE theta 1M, untied embeddings.
Full attention -> ``long_500k`` skipped.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    d_ff=25600,
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
)
