"""granite-moe-1b-a400m [moe]: 32 fine-grained experts, top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]

head_dim=64, expert d_ff=512 (fine-grained), every layer routed, SwiGLU,
RMSNorm, tied embeddings.  Full attention -> ``long_500k`` skipped.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    tie_embeddings=True,
    rope_theta=10_000.0,
    n_experts=32,
    top_k=8,
    moe_interleave=1,
)
