"""Architecture + run configuration schema.

One ``ArchConfig`` instance per assigned architecture lives in
``configs/<id>.py`` with the exact published numbers; ``reduced()`` derives
the CPU smoke-test variant (same family, tiny dims).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'rwkv' | 'hybrid' | 'audio' | 'vlm'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    mlp_type: str = "swiglu"  # 'swiglu' | 'relu2' | 'geglu' | 'gelu'
    norm_type: str = "rmsnorm"  # 'rmsnorm' | 'layernorm'
    norm_eps: float = 1e-6
    pos_type: str = "rope"  # 'rope' | 'sinusoidal' | 'none'
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    rope_theta_local: float = 10_000.0  # sliding-window layers (gemma3)
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scale
    loss_chunk: int = 512  # chunked cross-entropy sequence-chunk length
    attn_q_chunk: int = 1024  # flash-style query-chunk for the no-cache path
    # ---- performance knobs (hillclimbed in EXPERIMENTS.md §Perf) ----------
    score_dtype: str = "f32"  # attention score/softmax dtype: 'f32' | 'bf16'
    # Megatron-SP (validated §Perf: qwen3 train mfu_bound +53%, rwkv6 +200%,
    # HBM/chip 133->18 GB): residual stream sharded on seq between blocks.
    seq_parallel: bool = True
    anchor_attn: bool = False  # pin q/k/v/o to the Megatron head-TP layout
    anchor_params: bool = False  # pin group param slices inside the scan
    cast_in_scan: bool = False  # cast group params INSIDE the scan body so
    # weight-grad cotangents leave the loop in bf16 (halved grad reductions)
    anchor_cast: bool = False  # pin the bf16 param copies to their stored
    # sharding (forces convert-then-gather instead of gather-then-convert)
    cast_params: bool = True  # cast >=2D params to compute dtype at step
    # start, so FSDP all-gathers move bf16, not fp32 master weights
    # attention pattern: 0 = all-global; else (local_per_global, window)
    local_per_global: int = 0
    local_window: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_interleave: int = 1  # 1 = every layer routed; 2 = alternate dense/MoE
    shared_expert: bool = False
    capacity_factor: float = 1.25
    moe_dispatch: str = "gather"  # 'gather' (scatter/gather) | 'dense' (one-hot einsum)
    moe_aux_weight: float = 0.01
    # SSM (Mamba2) / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 64
    hybrid_attn_every: int = 0  # zamba2: shared attn+mlp block every k ssm layers
    # RWKV6
    rwkv_head_size: int = 64
    rwkv_chunk: int = 32
    # modality frontend stub: None | 'audio' | 'vision'
    frontend: Optional[str] = None
    # numerics / execution
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    optim_state_dtype: Any = jnp.float32
    remat: str = "full"  # 'none' | 'full' | 'dots'
    scan_layers: bool = True
    use_pallas: bool = False
    fsdp: bool = True  # shard 'embed'-dim params over the data axis (ZeRO-3)
    microbatches: int = 1  # gradient-accumulation microbatches in train_step
    cache_dtype: Any = jnp.bfloat16
    # decode-cache sequence sharding: mesh axes the KV-cache seq dim is sharded
    # over ('auto' resolves per shape: long-context -> ('data','model'))
    windowed_cache: bool = False  # local layers keep only a window-sized cache
    # sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False

    # -- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def attn_window(self, layer: int) -> int:
        """Sliding window for layer (0 = global).  gemma3: 5 local : 1 global."""
        if self.local_per_global <= 0:
            return 0
        return 0 if (layer % (self.local_per_global + 1)) == self.local_per_global else self.local_window

    # Exact N (total and active) is computed from the parameter template —
    # see ``models.model.param_counts(cfg)`` — so every family (hybrid,
    # rwkv, moe interleaves) is counted from real shapes, not formulas.

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests (same group layout
        family, group size shrunk so 4-layer stacks stay divisible)."""
        hd = 16
        n_heads = max(2, min(4, self.n_heads))
        n_kv = max(1, min(n_heads, self.n_kv if self.n_kv < self.n_heads else n_heads))
        lpg = 1 if self.local_per_global > 0 else 0  # 1 local : 1 global
        group = max(
            1,
            2 if self.hybrid_attn_every else 0,
            lpg + 1 if lpg else 0,
            self.moe_interleave if self.is_moe else 0,
        )
        layers = 2 * group
        return replace(
            self,
            n_layers=layers,
            d_model=n_heads * hd,
            n_heads=n_heads,
            n_kv=n_kv,
            head_dim=hd,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.is_moe else 0,
            top_k=min(self.top_k, 2) if self.is_moe else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=4 if self.ssm_heads else 0,
            ssm_chunk=8,
            rwkv_head_size=16,
            rwkv_chunk=8,
            local_per_global=lpg,
            local_window=16 if self.local_window else 0,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            loss_chunk=32,
            compute_dtype=jnp.float32,
            cache_dtype=jnp.float32,
            remat="none",
        )


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
