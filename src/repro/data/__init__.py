from .pipeline import DataConfig, SyntheticLMDataset, sharded_batches

__all__ = ["DataConfig", "SyntheticLMDataset", "sharded_batches"]
