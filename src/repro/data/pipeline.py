"""Deterministic synthetic LM data pipeline with sharded host feed.

The stream has *learnable structure* (a fixed random bigram transition
table blended with noise) so end-to-end training drivers show a real,
monotonically falling loss instead of log(V) forever.  Determinism: batch
``i`` of a given (seed, config) is identical across restarts and across
hosts — restart-safe (checkpoint stores only the batch index) and
multi-host-safe (every host can materialize exactly its shard).

``sharded_batches`` yields jax arrays placed with the trainer's batch
sharding via ``jax.make_array_from_callback``, so each host only
materializes its addressable shards (the multi-host-ready path; on one
process it degenerates to device_put).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4  # bigram successors per token (lower = easier)
    noise: float = 0.05  # fraction of uniform-random tokens


class SyntheticLMDataset:
    """Deterministic bigram-structured token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed transition table: token t -> branching successors
        self.table = rng.integers(
            0, cfg.vocab, size=(cfg.vocab, cfg.branching), dtype=np.int64
        )

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        """Batch ``index`` (pure function of (seed, index))."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, index))
        B, S = cfg.global_batch, cfg.seq_len
        toks = np.empty((B, S + 1), dtype=np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=B)
        branch = rng.integers(0, cfg.branching, size=(B, S))
        noise = rng.random((B, S)) < cfg.noise
        noise_tok = rng.integers(0, cfg.vocab, size=(B, S))
        for s in range(S):
            nxt = self.table[toks[:, s], branch[:, s]]
            toks[:, s + 1] = np.where(noise[:, s], noise_tok[:, s], nxt)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


def make_global_array(host_batch: np.ndarray, sharding) -> jax.Array:
    """Build a (possibly multi-host) global array from the host batch."""
    return jax.make_array_from_callback(
        host_batch.shape, sharding, lambda idx: host_batch[idx]
    )


def sharded_batches(
    ds: SyntheticLMDataset,
    shardings: Dict[str, jax.sharding.Sharding],
    start_index: int = 0,
    embeds_cfg: Optional[ArchConfig] = None,
) -> Iterator[Dict[str, jax.Array]]:
    """Yield device-placed batches starting at ``start_index`` (restart-safe).

    For stub-frontend archs (``embeds_cfg.frontend`` set), tokens are mapped
    to deterministic synthetic embeddings host-side (the stub frontend).
    """
    i = start_index
    while True:
        host = ds.batch(i)
        out: Dict[str, jax.Array] = {}
        if embeds_cfg is not None and embeds_cfg.frontend:
            D = embeds_cfg.d_model
            rng = np.random.default_rng((ds.cfg.seed, 7, 0))
            proj = rng.standard_normal((ds.cfg.vocab, D)).astype(np.float32)
            proj /= np.sqrt(D)
            emb = proj[host["tokens"]].astype(
                jax.dtypes.canonicalize_dtype(embeds_cfg.compute_dtype)
            )
            out["embeds"] = make_global_array(emb, shardings["embeds"])
        else:
            out["tokens"] = make_global_array(host["tokens"], shardings["tokens"])
        if "labels" in shardings:
            out["labels"] = make_global_array(host["labels"], shardings["labels"])
        yield out
        i += 1
