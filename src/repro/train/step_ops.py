"""The LM train step expressed as a UTP task tree (paper §2.3 applied to
the framework's own training loop).

    TrainStepOp.split ->  [MicroGradOp x m]  ->  GradSumOp  ->  AdamOp
                           (reads params,          (reads grads_i*)   (RW params/opt)
                            batch block i,
                            writes grads_i)

The *same* submission code runs under two executor stacks, selected by the
task-flow graph — the paper's G1/G2 story on the LM side:

  ``eager``  (cpuBLAS-wrapper analog): every leaf task executes
             immediately, one XLA call per task.
  ``fused``  (the TPU-optimal plan): the dispatcher's wave schedule is
             COMPILED — all tasks trace into one jitted program, which is
             exactly the ``launch/steps.py`` train step.  This is the
             "whole program is a task tree" limit case from DESIGN.md §2.

Data handles are 1x1 (or mx1 for the microbatched input) ``GData``
surrogates: the UTP dependency machinery (versioning, waves) works on the
handles while the pytree values live in the executor's store.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..core import Access, Dispatcher, GData, GTask, Operation
from ..core.executors.base import Executor


# --------------------------------------------------------------------------
# tree-valued operations
# --------------------------------------------------------------------------
class TreeOp(Operation):
    """Operation whose leaves act on pytrees held in the executor store."""

    def run_tree(self, task: GTask, store: Dict[int, Any]) -> None:
        raise NotImplementedError


class MicroGradOp(TreeOp):
    name = "micrograd"

    def __init__(self, loss_fn: Callable):
        self.loss_fn = loss_fn

    def default_modes(self, n):
        return [Access.READ, Access.READ, Access.WRITE]  # params, batch_i, grads_i

    def run_tree(self, task, store):
        params = store[task.args[0].data.id]
        mb_index = task.args[1].block_index()[0]
        batch = store[task.args[1].data.id]
        mb = jax.tree.map(lambda x: x[mb_index], batch)
        (loss, metrics), g = jax.value_and_grad(self.loss_fn, has_aux=True)(
            params, mb
        )
        store[task.args[2].data.id] = g
        store.setdefault("metrics", []).append(metrics)


class GradSumOp(TreeOp):
    name = "gradsum"

    def default_modes(self, n):
        return [Access.READ] * (n - 1) + [Access.WRITE]

    def run_tree(self, task, store):
        parts = [store[v.data.id] for v in task.args[:-1]]
        s = parts[0]
        for p in parts[1:]:
            s = jax.tree.map(lambda a, b: a + b, s, p)
        n = float(len(parts))
        store[task.args[-1].data.id] = jax.tree.map(lambda a: a / n, s)


class AdamOp(TreeOp):
    name = "adam"

    def __init__(self, opt_cfg):
        self.opt_cfg = opt_cfg

    def default_modes(self, n):
        return [Access.READ, Access.READWRITE, Access.READWRITE]

    def run_tree(self, task, store):
        from .. import optim

        grads = store[task.args[0].data.id]
        params = store[task.args[1].data.id]
        opt = store[task.args[2].data.id]
        new_p, new_o, m = optim.update(grads, opt, params, self.opt_cfg)
        store[task.args[1].data.id] = new_p
        store[task.args[2].data.id] = new_o
        store.setdefault("metrics", []).append(m)


class TrainStepOp(TreeOp):
    """Root task: splits into the microbatch/reduce/update children.

    Intermediate handles (per-microbatch grads, the reduced grads) are
    created ONCE and reused across steps so the fused executor's compiled
    program is keyed on a stable structure — step 2 onward is a cache hit.
    """

    name = "train_step"

    def __init__(self, loss_fn, opt_cfg, microbatches: int):
        self.loss_fn = loss_fn
        self.opt_cfg = opt_cfg
        self.m = microbatches
        self._micrograd = MicroGradOp(loss_fn)
        self._gradsum = GradSumOp()
        self._adam = AdamOp(opt_cfg)
        self._grads = [GData((1, 1), name=f"grads{i}") for i in range(self.m)]
        self._total = GData((1, 1), name="grads")

    def default_modes(self, n):
        return [Access.READWRITE, Access.READWRITE, Access.READ]

    def can_split(self, task):
        return True

    def split(self, task, submit):
        params_v, opt_v, batch_v = task.args
        for i in range(self.m):
            submit(
                GTask(
                    self._micrograd,
                    task,
                    [params_v, batch_v(i, 0), self._grads[i].root_view()],
                )
            )
        submit(
            GTask(
                self._gradsum,
                task,
                [g.root_view() for g in self._grads] + [self._total.root_view()],
            )
        )
        submit(
            GTask(self._adam, task, [self._total.root_view(), params_v, opt_v])
        )


# --------------------------------------------------------------------------
# executors
# --------------------------------------------------------------------------
class EagerTreeExecutor(Executor):
    """One XLA dispatch per leaf task (the paper's immediate-execution leaf)."""

    name = "tree_eager"

    def __init__(self, store: Dict[int, Any], **kw):
        super().__init__(**kw)
        self.store = store

    def execute_wave(self, wave):
        for t in wave:
            t.op.run_tree(t, self.store)
            self.stats["tasks"] += 1
            self._finished(t)
        return len(wave)


class FusedTreeExecutor(Executor):
    """Compile the ENTIRE wave schedule into one jitted program.

    The dispatcher's level schedule fixes a topological order; tracing the
    tasks in that order through a functional store turns the task DAG into
    a single XLA computation — the TPU-optimal plan for the paper's
    configurable task flow.
    """

    name = "tree_fused"

    def __init__(self, store: Dict[int, Any], donate: bool = False, **kw):
        super().__init__(**kw)
        self.store = store
        self.donate = donate
        self._cache: Dict[Any, Callable] = {}

    def execute_waves(self, waves):
        order = [t for w in waves for t in w]
        key = tuple((t.op.name, tuple(v.data.id for v in t.args)) for t in order)
        # external inputs = handles READ before any task WRITES them; values
        # produced inside the schedule (microbatch grads etc.) must not leak
        # back in as arguments or the program signature grows call-to-call.
        written = set()
        ext = set()
        for t in order:
            for v, m in t.accesses():
                if m.reads and v.data.id not in written and v.data.id in self.store:
                    ext.add(v.data.id)
            for v in t.outputs():
                written.add(v.data.id)
        in_ids = sorted(ext)

        if key not in self._cache:
            def fused(vals: Dict[int, Any]):
                st: Dict[Any, Any] = dict(vals)
                for t in order:
                    t.op.run_tree(t, st)
                return {k: v for k, v in st.items() if k != "metrics"}, st.get(
                    "metrics", []
                )

            self._cache[key] = jax.jit(fused)
            self.stats["compiles"] += 1
        out, metrics = self._cache[key]({k: self.store[k] for k in in_ids})
        self.store.update(out)
        self.store["metrics"] = metrics
        for t in order:
            self.stats["tasks"] += 1
            self._finished(t)
        return len(order)

    def execute_wave(self, wave):  # pragma: no cover - waves run fused
        return self.execute_waves([wave])


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------
class UTPTrainStep:
    """Submit/run the train-step task tree through the UTP dispatcher.

    Handles, the root operation and the executor are created once; every
    call submits a fresh task tree over the SAME handles, so the fused
    executor's compiled program is reused (compile-once, run-many)."""

    def __init__(self, loss_fn, opt_cfg, microbatches: int = 1, executor: str = "fused"):
        self.loss_fn = loss_fn
        self.opt_cfg = opt_cfg
        self.m = microbatches
        self.executor_kind = executor
        self.op = TrainStepOp(loss_fn, opt_cfg, microbatches)
        self.h_params = GData((1, 1), name="params")
        self.h_opt = GData((1, 1), name="opt")
        self.h_batch = GData(
            (self.m, 1), partitions=((self.m, 1),), name="batch"
        )
        self.store: Dict[Any, Any] = {}
        self.executor = (
            FusedTreeExecutor(self.store)
            if executor == "fused"
            else EagerTreeExecutor(self.store)
        )

    def __call__(self, params, opt_state, batch):
        store = self.store
        store.pop("metrics", None)
        d = Dispatcher(graph="g2")  # graph name only picks split depth here
        self.executor.on_task_finished = d._on_finished
        d.executor = self.executor

        store[self.h_params.id] = params
        store[self.h_opt.id] = opt_state
        store[self.h_batch.id] = jax.tree.map(
            lambda x: x.reshape((self.m, x.shape[0] // self.m) + x.shape[1:]), batch
        )

        root = GTask(
            self.op,
            None,
            [
                self.h_params.root_view(),
                self.h_opt.root_view(),
                self.h_batch.root_view(),
            ],
        )
        d.submit_task(root)
        d.run()
        metrics = store.get("metrics", [])
        agg = {}
        if metrics:
            keys = metrics[0].keys()
            agg = {
                k: jnp.mean(jnp.stack([jnp.asarray(m[k]) for m in metrics if k in m]))
                for k in keys
            }
        return store[self.h_params.id], store[self.h_opt.id], agg
