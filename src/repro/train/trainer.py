"""Training loop with checkpoint/restart, failure recovery and straggler
watchdog — the step program comes from the SAME ``StepPlan`` the dry-run
compiles, so what we validate offline is what runs.

Fault-tolerance model (scaled from the 1000-node design to this harness):
  * **checkpoint/restart** — async atomic checkpoints every
    ``ckpt_every`` steps; on construction the trainer auto-resumes from the
    latest complete checkpoint (data iterator included: the synthetic
    pipeline is an indexed pure function, so the batch index IS the data
    state).
  * **step failure recovery** — a failing step (device error, NaN loss if
    ``abort_on_nan``) triggers restore-from-last-checkpoint and replay;
    ``max_failures`` bounds the retry budget.  On a real fleet the same
    hook receives the coordinator's "node died" signal; here failures are
    injectable for tests (``inject_failure``).
  * **straggler watchdog** — per-step wall times feed a rolling median;
    steps slower than ``straggler_factor`` x median are counted and
    surfaced (the production action — re-shard around the slow host via
    elastic restart — reuses the elastic ``Checkpointer.restore``).
  * **preemption** — SIGTERM triggers a synchronous final checkpoint.
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from .. import optim
from ..configs.base import ArchConfig, ShapeConfig
from ..data.pipeline import DataConfig, SyntheticLMDataset, sharded_batches
from ..launch import sharding as shlib
from ..launch.steps import StepPlan, make_train_step
from ..models.model import build_model
from .checkpoint import Checkpointer


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    seed: int = 0
    abort_on_nan: bool = True
    max_failures: int = 3
    straggler_factor: float = 3.0


@dataclass
class StepStats:
    times: List[float] = field(default_factory=list)
    stragglers: int = 0

    def record(self, dt: float, factor: float) -> bool:
        """Returns True if this step counts as a straggler."""
        med = float(np.median(self.times)) if self.times else dt
        self.times.append(dt)
        if len(self.times) > 200:
            self.times.pop(0)
        if len(self.times) > 5 and dt > factor * med:
            self.stragglers += 1
            return True
        return False


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeConfig,
        mesh,
        tcfg: Optional[TrainerConfig] = None,
        opt_cfg: Optional[optim.AdamWConfig] = None,
        data_cfg: Optional[DataConfig] = None,
    ):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.tcfg = tcfg or TrainerConfig()
        self.model = build_model(cfg)
        self.opt_cfg = opt_cfg or optim.AdamWConfig(state_dtype=cfg.optim_state_dtype)
        self.plan: StepPlan = make_train_step(cfg, mesh, shape, opt_cfg=self.opt_cfg)
        self.step_fn = self.plan.jitted()
        self.ckpt = Checkpointer(self.tcfg.ckpt_dir, keep=self.tcfg.ckpt_keep)
        self.stats = StepStats()
        self.data_cfg = data_cfg or DataConfig(
            vocab=cfg.vocab, seq_len=shape.seq_len, global_batch=shape.global_batch,
            seed=self.tcfg.seed,
        )
        self.dataset = SyntheticLMDataset(self.data_cfg)
        self._preempted = False
        self.metrics_log: List[Dict[str, float]] = []

    # -- state ----------------------------------------------------------------
    def init_state(self):
        rules = shlib.train_rules(self.cfg)
        p_shard = shlib.tree_shardings(
            self.model.logical, self.model.abstract(), self.mesh, rules
        )
        with self.mesh:
            params = jax.jit(
                self.model.init, out_shardings=p_shard
            )(jax.random.PRNGKey(self.tcfg.seed))
            opt_state = jax.jit(
                lambda p: optim.init(p, self.opt_cfg),
                out_shardings={"m": p_shard, "v": p_shard,
                               "count": shlib.replicated(self.mesh)},
            )(params)
        return params, opt_state

    def state_shardings(self):
        return self.plan.in_shardings[0], self.plan.in_shardings[1]

    # -- fault handling ---------------------------------------------------------
    def _install_sigterm(self, get_state):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # non-main thread (tests)

    # -- loop ------------------------------------------------------------------
    def train(
        self,
        inject_failure: Optional[Callable[[int], bool]] = None,
        on_metrics: Optional[Callable[[int, Dict[str, float]], None]] = None,
    ) -> Dict[str, Any]:
        t = self.tcfg
        start_step = 0
        params = opt_state = None
        if self.ckpt.latest_step() is not None:
            params, opt_state, start_step = self._restore()
            print(f"[trainer] resumed from step {start_step}")
        if params is None:
            params, opt_state = self.init_state()
        self._install_sigterm(lambda: (params, opt_state))

        b_shards = self.plan.in_shardings[2]
        batches = sharded_batches(
            self.dataset, b_shards, start_index=start_step, embeds_cfg=self.cfg
        )
        failures = 0
        step = start_step
        while step < t.steps and not self._preempted:
            batch = next(batches)
            t0 = time.time()
            try:
                if inject_failure is not None and inject_failure(step):
                    raise RuntimeError(f"injected failure at step {step}")
                with self.mesh:
                    params, opt_state, metrics = self.step_fn(
                        params, opt_state, batch
                    )
                loss = float(metrics["loss"])
                if t.abort_on_nan and not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
            except (RuntimeError, FloatingPointError) as e:
                failures += 1
                print(f"[trainer] step {step} failed ({e}); "
                      f"restoring (failure {failures}/{t.max_failures})")
                if failures > t.max_failures:
                    raise
                self.ckpt.wait()
                if self.ckpt.latest_step() is not None:
                    params, opt_state, step = self._restore()
                else:
                    params, opt_state = self.init_state()
                    step = 0
                batches = sharded_batches(
                    self.dataset, b_shards, start_index=step, embeds_cfg=self.cfg
                )
                continue
            dt = time.time() - t0
            slow = self.stats.record(dt, t.straggler_factor)
            step += 1
            m = {k: float(v) for k, v in metrics.items()}
            m["step_time_s"] = dt
            self.metrics_log.append({"step": step, **m})
            if on_metrics:
                on_metrics(step, m)
            if step % t.log_every == 0 or step == t.steps:
                print(
                    f"[trainer] step {step:5d} loss={m['loss']:.4f} "
                    f"acc={m.get('accuracy', 0):.3f} "
                    f"gnorm={m.get('grad_norm', 0):.2f} {dt*1e3:.0f}ms"
                    + (" STRAGGLER" if slow else "")
                )
            if step % t.ckpt_every == 0 or step == t.steps or self._preempted:
                self.ckpt.save_async(step, {"params": params, "opt": opt_state})
        self.ckpt.wait()
        if self._preempted:
            self.ckpt.save(step, {"params": params, "opt": opt_state})
            print(f"[trainer] preempted; checkpointed step {step}")
        return {
            "params": params,
            "opt_state": opt_state,
            "step": step,
            "metrics": self.metrics_log,
            "stragglers": self.stats.stragglers,
            "failures": failures,
        }

    def _restore(self):
        p_sh, o_sh = self.state_shardings()
        target = {
            "params": self.plan.args[0],
            "opt": self.plan.args[1],
        }
        shardings = {"params": p_sh, "opt": o_sh}
        state, step = self.ckpt.restore(target, shardings=shardings)
        return state["params"], state["opt"], step
