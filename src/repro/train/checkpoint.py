"""Fault-tolerant checkpointing: atomic, async, elastic-restorable.

Layout (one directory per step):

    <dir>/step_000120.tmp-<nonce>/   # written here first
        arrays.npz                   # flattened tree leaves (host numpy)
        meta.json                    # step, tree structure, shapes, checksum
    <dir>/step_000120/               # atomic rename after fsync

Properties needed at 1000-node scale, scaled to this harness:
  * **atomic**   — a crash mid-save never corrupts the latest checkpoint
    (tmp dir + rename; restore scans only completed dirs).
  * **async**    — ``save_async`` snapshots device arrays to host, then
    writes on a background thread; training continues immediately.
  * **elastic**  — arrays are stored *unsharded* (gathered host views), so
    restore can re-place onto a different mesh/sharding than the one that
    saved (``restore(..., shardings=new)``) — N pods -> M pods restart.
    (A per-shard layout with a global index is the production extension;
    the gathered layout is exact for single-host and documents the seam.)
  * **self-validating** — per-leaf CRCs catch torn/corrupt files.
  * **GC**       — keeps the most recent ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved_step: Optional[int] = None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state: Any, block: bool = True) -> None:
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        if block:
            self._write(step, host)
        else:
            self.wait()  # one in-flight save at a time
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True
            )
            self._thread.start()

    def save_async(self, step: int, state: Any) -> None:
        self.save(step, state, block=False)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any) -> None:
        flat = _flatten_with_paths(host_tree)
        treedef = jax.tree.structure(host_tree)
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp-{os.getpid()}-{time.time_ns()}"
        tmp.mkdir(parents=True)
        try:
            arrays = {k: np.asarray(v) for k, v in flat.items()}
            np.savez(tmp / "arrays.npz", **arrays)
            meta = {
                "step": step,
                "treedef": str(treedef),
                "keys": sorted(arrays),
                "crc": {
                    k: zlib.crc32(np.ascontiguousarray(v).tobytes())
                    for k, v in arrays.items()
                },
                "shapes": {k: list(v.shape) for k, v in arrays.items()},
                "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
                "time": time.time(),
            }
            with open(tmp / "meta.json", "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self.last_saved_step = step
            self._gc()
        finally:
            if tmp.exists():
                shutil.rmtree(tmp, ignore_errors=True)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and ".tmp" not in p.name:
                if (p / "meta.json").exists():
                    out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        target: Any,
        step: Optional[int] = None,
        shardings: Optional[Any] = None,
        validate: bool = True,
    ) -> Tuple[Any, int]:
        """Restore into the structure of ``target``.

        ``shardings``: optional tree matching ``target`` — device placement
        for the restored leaves (may describe a DIFFERENT mesh than the one
        that saved: elastic restart).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        meta = json.loads((d / "meta.json").read_text())
        arrays = np.load(d / "arrays.npz")
        if validate:
            for k, crc in meta["crc"].items():
                got = zlib.crc32(np.ascontiguousarray(arrays[k]).tobytes())
                if got != crc:
                    raise IOError(f"checkpoint {d} leaf {k}: CRC mismatch")
        flat_t = _flatten_with_paths(target)
        flat_s = _flatten_with_paths(shardings) if shardings is not None else {}
        out = {}
        for k, tgt in flat_t.items():
            if k not in arrays:
                raise KeyError(f"checkpoint missing leaf {k}")
            v = arrays[k]
            if tuple(v.shape) != tuple(tgt.shape):
                raise ValueError(f"{k}: shape {v.shape} != target {tgt.shape}")
            v = v.astype(tgt.dtype)
            sh = flat_s.get(k)
            out[k] = (
                jax.make_array_from_callback(v.shape, sh, lambda idx, v=v: v[idx])
                if sh is not None
                else jax.device_put(v)
            )
        # rebuild tree in target structure
        leaves_order = [
            out[k] for k in _flatten_with_paths(target)
        ]
        tree = jax.tree.unflatten(jax.tree.structure(target), leaves_order)
        return tree, step
