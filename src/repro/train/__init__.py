from .checkpoint import Checkpointer
from .step_ops import UTPTrainStep
from .trainer import Trainer, TrainerConfig

__all__ = ["Checkpointer", "Trainer", "TrainerConfig", "UTPTrainStep"]
