"""Mixture-of-Experts layer: top-k router + three dispatch strategies.

``ep``     (distributed): explicit expert parallelism under ``shard_map`` —
           tokens stay on their data shard, experts are sharded over the
           'model' mesh axis; every model shard builds the capacity buffer
           for *its* experts only and the combine is one ``psum`` over the
           model axis (the classic GShard dataflow, TPU-native: the psum is
           the same all-reduce a TP MLP already pays).  FSDP'd expert
           weights are all-gathered over the data axes inside the body
           (autodiff turns that into reduce-scatter for grads = ZeRO).
``gather`` (single-device default): capacity-bounded scatter/gather
           permutation — O(T·k·D) data movement, linear in tokens.
``dense``  : Mesh-TF style one-hot dispatch einsums — O(T·E·C) FLOPs, kept
           as the naive baseline the roofline analysis iterates against.

Router uses fp32 logits, softmax-after-top-k (Switch convention), and an
auxiliary load-balancing loss (returned, weighted by the caller).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..configs.base import ArchConfig
from .layers import PSpec


@dataclass(frozen=True)
class MoeCtx:
    """Parallel context: EP dispatch config + activation-sharding anchors.

    ``batch_axes``: mesh axes the token batch dim is sharded over.
    ``model_axis``: mesh axis experts/heads/d_ff are sharded over (TP axis).
    ``fsdp_axes``:  mesh axes weight d_model dims are sharded over.

    ``constrain_batch`` pins activations to the data-parallel layout
    (batch over batch_axes, everything else replicated).  Without these
    anchors the SPMD partitioner, seeing FSDP-sharded weights, is free to
    all-gather the batch and shard activations on d_model instead — a
    catastrophically collective-bound layout (observed in the qwen3
    baseline dry-run before anchoring).
    """

    mesh: Any
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: Optional[str] = "model"
    fsdp_axes: Tuple[str, ...] = ()
    # Megatron-style sequence parallelism: shard the residual stream's
    # sequence dim over this axis between blocks; the partitioner then
    # lowers TP boundary all-reduces into reduce-scatter + all-gather pairs
    # (half the bytes) and norms/elementwise run on S/tp shards.
    seq_axis: Optional[str] = None
    # Optional callable pinning a group's param slices to their stored
    # sharding inside the layer scan — anchors the BACKWARD cotangents so
    # weight grads reduce-scatter per group instead of all-reducing full
    # fp32 replicas (observed 489 GB/chip/step of waste without it).
    group_param_constraint: Optional[Any] = None

    def _baxes(self, dim: int) -> Tuple[str, ...]:
        if self.mesh is None:
            return ()
        axes = tuple(a for a in self.batch_axes if a in self.mesh.axis_names)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        while axes and dim % n != 0:
            axes = axes[:-1]
            n = 1
            for a in axes:
                n *= self.mesh.shape[a]
        return axes

    def constrain_batch(self, x: jnp.ndarray) -> jnp.ndarray:
        """Pin leading dim to batch_axes (+ seq dim to seq_axis when set)."""
        if self.mesh is None or x.ndim < 1:
            return x
        axes = self._baxes(x.shape[0])
        lead = axes if len(axes) > 1 else (axes[0] if axes else None)
        rest = [None] * (x.ndim - 1)
        if (
            self.seq_axis is not None
            and x.ndim >= 3
            and self.seq_axis in self.mesh.axis_names
            and x.shape[1] % self.mesh.shape[self.seq_axis] == 0
            and x.shape[1] >= self.mesh.shape[self.seq_axis]
        ):
            rest[0] = self.seq_axis
        spec = P(lead, *rest)
        from jax.sharding import NamedSharding

        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def constrain_heads(self, x: jnp.ndarray) -> jnp.ndarray:
        """(B, S, H, hd) attention activations: batch over batch_axes, heads
        over the TP axis (replicated when H doesn't divide), seq FULL — the
        canonical Megatron layout inside an attention block; prevents the
        partitioner from splitting the seq/chunk dims of the flash scan."""
        if self.mesh is None or x.ndim != 4:
            return x
        axes = self._baxes(x.shape[0])
        lead = axes if len(axes) > 1 else (axes[0] if axes else None)
        m = self.model_axis if self.model_axis in (self.mesh.axis_names or ()) else None
        if m is not None and (x.shape[2] % self.mesh.shape[m] != 0 or x.shape[2] < self.mesh.shape[m]):
            m = None
        from jax.sharding import NamedSharding

        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(lead, None, m, None))
        )

    def constrain_logits(self, x: jnp.ndarray) -> jnp.ndarray:
        """(..., V): batch over batch_axes, vocab over model_axis."""
        if self.mesh is None:
            return x
        axes = self._baxes(x.shape[0])
        lead = axes if len(axes) > 1 else (axes[0] if axes else None)
        m = self.model_axis if self.model_axis in (self.mesh.axis_names or ()) else None
        if m is not None and x.shape[-1] % self.mesh.shape[m] != 0:
            m = None
        spec = P(lead, *([None] * (x.ndim - 2)), m)
        from jax.sharding import NamedSharding

        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


def moe_template(cfg: ArchConfig) -> Dict[str, PSpec]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    t = {
        # router stays replicated: every shard routes its own tokens
        "router": PSpec((D, E), (None, None), scale=0.1),
        "wi": PSpec((E, D, F), ("experts", "embed", "mlp")),
        "wo": PSpec((E, F, D), ("experts", "mlp", "embed")),
    }
    if cfg.mlp_type in ("swiglu", "geglu"):
        t["wg"] = PSpec((E, D, F), ("experts", "embed", "mlp"))
    if cfg.shared_expert:
        t["shared_wi"] = PSpec((D, F), ("embed", "mlp"))
        t["shared_wg"] = PSpec((D, F), ("embed", "mlp"))
        t["shared_wo"] = PSpec((F, D), ("mlp", "embed"))
    return t


def _act(cfg: ArchConfig, up: jnp.ndarray, gate: Optional[jnp.ndarray]) -> jnp.ndarray:
    if cfg.mlp_type in ("swiglu", "geglu"):
        fn = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        return fn(gate.astype(jnp.float32)).astype(up.dtype) * up
    if cfg.mlp_type == "relu2":
        return jnp.square(jax.nn.relu(up.astype(jnp.float32))).astype(up.dtype)
    return jax.nn.gelu(up.astype(jnp.float32)).astype(up.dtype)


def _expert_ffn(cfg: ArchConfig, wi, wg, wo, h: jnp.ndarray) -> jnp.ndarray:
    """h: (E, C, D) -> (E, C, D), batched over experts (MXU grouped GEMM)."""
    up = jnp.einsum("ecd,edf->ecf", h, wi.astype(h.dtype))
    g = jnp.einsum("ecd,edf->ecf", h, wg.astype(h.dtype)) if wg is not None else None
    up = _act(cfg, up, g)
    return jnp.einsum("ecf,efd->ecd", up, wo.astype(h.dtype))


def _router(cfg: ArchConfig, router_w, xf: jnp.ndarray):
    """xf: (T, D). Returns (gates (T,k), idx (T,k), aux_loss)."""
    logits = jnp.einsum(
        "td,de->te", xf.astype(jnp.float32), router_w.astype(jnp.float32)
    )
    gates_all = jax.nn.softmax(logits, axis=-1)
    top_vals, idx = jax.lax.top_k(logits, cfg.top_k)
    gates = jax.nn.softmax(top_vals, axis=-1)  # renormalize over selected
    # load-balance aux (Switch): E * sum_e f_e * P_e
    E = cfg.n_experts
    onehot = jax.nn.one_hot(idx[:, 0], E)  # fraction by top-1 assignment
    f = onehot.mean(axis=0)
    pmean = gates_all.mean(axis=0)
    aux = E * jnp.sum(f * pmean)
    return gates, idx, aux


def _capacity(cfg: ArchConfig, n_tokens: int) -> int:
    return max(1, int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))


def _shared_expert(cfg: ArchConfig, p, x: jnp.ndarray) -> jnp.ndarray:
    up = jnp.einsum("...d,df->...f", x, p["shared_wi"].astype(x.dtype))
    g = jnp.einsum("...d,df->...f", x, p["shared_wg"].astype(x.dtype))
    up = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * up
    return jnp.einsum("...f,fd->...d", up, p["shared_wo"].astype(x.dtype))


# --------------------------------------------------------------------------
# public entry
# --------------------------------------------------------------------------
def moe_apply(
    cfg: ArchConfig, p, x: jnp.ndarray, ctx: Optional[MoeCtx] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss)."""
    B, S, D = x.shape
    use_ep = (
        ctx is not None
        and ctx.mesh is not None
        and ctx.model_axis is not None
        and ctx.model_axis in ctx.mesh.axis_names
        and cfg.n_experts % ctx.mesh.shape[ctx.model_axis] == 0
    )
    if use_ep:
        out, aux = _moe_ep(cfg, p, x, ctx)
    else:
        xf = x.reshape(B * S, D)
        gates, idx, aux = _router(cfg, p["router"], xf)
        C = _capacity(cfg, B * S)
        if cfg.moe_dispatch == "dense":
            out = _dense_dispatch(cfg, p, xf, gates, idx, C)
        else:
            out = _gather_dispatch(cfg, p, xf, gates, idx, C)
        out = out.reshape(B, S, D)
    if cfg.shared_expert:
        out = out + _shared_expert(cfg, p, x)
    return out, aux


# --------------------------------------------------------------------------
# EP: shard_map expert parallelism
# --------------------------------------------------------------------------
def _moe_ep(cfg: ArchConfig, p, x: jnp.ndarray, ctx: MoeCtx):
    mesh = ctx.mesh
    maxis = ctx.model_axis
    tp = mesh.shape[maxis]
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // tp
    B, S, D = x.shape
    F = cfg.d_ff
    gated = cfg.mlp_type in ("swiglu", "geglu")

    baxes = tuple(a for a in ctx.batch_axes if a in mesh.axis_names)
    bsz = 1
    for a in baxes:
        bsz *= mesh.shape[a]
    if B % max(bsz, 1) != 0:
        baxes = ()  # replicate batch (e.g. long-context B=1)
    B_loc = B // max(1, _prod(mesh.shape[a] for a in baxes))
    T_loc = B_loc * S
    C = _capacity(cfg, T_loc)

    faxes = tuple(
        a for a in ctx.fsdp_axes if a in mesh.axis_names and a not in (maxis,)
    )
    fsz = _prod(mesh.shape[a] for a in faxes)
    if D % max(fsz, 1) != 0 or not cfg.fsdp:
        faxes = ()
    d_spec = faxes if faxes else None

    x_spec = P(baxes if baxes else None, None, None)
    w_spec = P(maxis, d_spec, None)  # (E, D, F)
    wo_spec = P(maxis, None, d_spec)  # (E, F, D)

    def body(xl, router_w, wi, wg, wo):
        Bl, Sl, _ = xl.shape
        xf = xl.reshape(Bl * Sl, D)
        gates, idx, aux = _router(cfg, router_w, xf)
        rank = jax.lax.axis_index(maxis)
        e0 = rank * E_loc
        flat_e = idx.reshape(-1)  # (T*k,)
        local = (flat_e >= e0) & (flat_e < e0 + E_loc)
        le = jnp.where(local, flat_e - e0, E_loc)  # E_loc == "overflow expert"
        onehot = jax.nn.one_hot(le, E_loc + 1, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - 1)
        pos = jnp.take_along_axis(pos, le[:, None], axis=1)[:, 0]
        keep = local & (pos < C)
        dest = jnp.where(keep, le * C + pos, E_loc * C)
        src = jnp.repeat(xf, k, axis=0) if k > 1 else xf
        buf = jnp.zeros((E_loc * C + 1, D), xf.dtype).at[dest].set(src, mode="drop")
        # FSDP'd weights: gather the d_model shards (bwd = reduce-scatter)
        if faxes:
            wi_f = jax.lax.all_gather(wi, faxes, axis=1, tiled=True)
            wg_f = (
                jax.lax.all_gather(wg, faxes, axis=1, tiled=True) if gated else None
            )
            wo_f = jax.lax.all_gather(wo, faxes, axis=2, tiled=True)
        else:
            wi_f, wg_f, wo_f = wi, (wg if gated else None), wo
        h = _expert_ffn(cfg, wi_f, wg_f, wo_f, buf[: E_loc * C].reshape(E_loc, C, D))
        hflat = jnp.concatenate([h.reshape(E_loc * C, D), jnp.zeros((1, D), h.dtype)])
        back = hflat[dest] * gates.reshape(-1)[:, None].astype(h.dtype)
        out = back.reshape(Bl * Sl, k, D).sum(axis=1)
        out = jax.lax.psum(out, maxis)  # combine expert shards
        if baxes:
            aux = jax.lax.pmean(aux, baxes)  # replicate for out_spec P()
        return out.reshape(Bl, Sl, D), aux

    wg_in = p.get("wg") if gated else jnp.zeros((), x.dtype)
    out, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec if gated else P(), wo_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"], p["wi"], wg_in, p["wo"])
    return out, aux


def _prod(it) -> int:
    n = 1
    for v in it:
        n *= v
    return n


# --------------------------------------------------------------------------
# single-device dispatch strategies
# --------------------------------------------------------------------------
def _gather_dispatch(cfg, p, xf, gates, idx, C):
    """Permutation dispatch: scatter tokens to (E, C) slots, gather back."""
    T, D = xf.shape
    E, k = cfg.n_experts, cfg.top_k
    gated = cfg.mlp_type in ("swiglu", "geglu")
    flat_e = idx.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1  # 0-based slot per expert
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    dest = jnp.where(keep, flat_e * C + pos, E * C)  # overflow -> drop row
    buf = jnp.zeros((E * C + 1, D), xf.dtype)
    src = jnp.repeat(xf, k, axis=0) if k > 1 else xf
    buf = buf.at[dest].set(src, mode="drop")
    h = _expert_ffn(
        cfg, p["wi"], p.get("wg") if gated else None, p["wo"],
        buf[: E * C].reshape(E, C, D),
    )
    hflat = jnp.concatenate([h.reshape(E * C, D), jnp.zeros((1, D), h.dtype)])
    back = hflat[dest]  # (T*k, D)
    back = back * gates.reshape(-1)[:, None].astype(back.dtype)
    return back.reshape(T, k, D).sum(axis=1)


def _dense_dispatch(cfg, p, xf, gates, idx, C):
    """One-hot einsum dispatch (naive baseline for §Perf)."""
    T, D = xf.shape
    E, k = cfg.n_experts, cfg.top_k
    gated = cfg.mlp_type in ("swiglu", "geglu")
    onehot = jax.nn.one_hot(idx, E, dtype=xf.dtype)  # (T, k, E)
    cum = jnp.cumsum(onehot.reshape(T * k, E), axis=0).reshape(T, k, E)
    posmat = (cum - onehot) * onehot  # (T, k, E): 0-based slot id
    slot_oh = jax.nn.one_hot(posmat.sum(-1), C, dtype=xf.dtype) * (
        (posmat.sum(-1) < C)[..., None]
    ) * onehot.sum(-1, keepdims=True)
    disp = jnp.einsum("tke,tkc->ect", onehot, slot_oh)
    h_in = jnp.einsum("ect,td->ecd", disp, xf)
    h = _expert_ffn(cfg, p["wi"], p.get("wg") if gated else None, p["wo"], h_in)
    comb = jnp.einsum("tke,tkc,tk->ect", onehot, slot_oh, gates.astype(xf.dtype))
    return jnp.einsum("ect,ecd->td", comb, h)
