"""Decoder-stack assembly: heterogeneous layer *groups* scanned over depth.

Every assigned architecture is expressed as a repeating **group** of layers
(the scanned unit), so `lax.scan` sees a uniform body even when the depth
pattern is heterogeneous:

    dense / audio / vlm     group = 1 attention layer
    gemma3 (5 local:1 glob) group = 6 attention layers w/ static windows
    llama4  (interleaved)   group = [dense-MLP layer, MoE layer]
    granite (all-MoE)       group = 1 MoE layer
    rwkv6                   group = 1 RWKV block (time-mix + channel-mix)
    zamba2 (hybrid)         group = 6 Mamba2 layers + ONE shared attn+MLP
                            block (weights shared across groups = the
                            zamba2 "shared transformer block")

Static facts (window size, MoE-or-dense, kind) live in ``LayerDesc`` —
they differ *within* a group but are identical *across* groups, which is
exactly the scan-uniformity contract.

The paper hook: a group is the UTP split unit — `ForwardOp.split()` yields
one task per group; on TPU the dispatcher's plan fuses them back into one
scanned XLA while-loop (DESIGN.md §2, "whole program is a task tree").
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import attention_apply, attention_template
from .layers import (
    PSpec,
    mlp_apply,
    mlp_template,
    norm_apply,
    norm_template,
    stack_tree,
)
from .moe import moe_apply, moe_template
from .rwkv import rwkv_block_apply, rwkv_cache_shape, rwkv_template
from .ssm import mamba_apply, mamba_cache_shape, mamba_template


@dataclass(frozen=True)
class LayerDesc:
    kind: str  # 'attn' | 'rwkv' | 'mamba'
    window: int = 0  # sliding window (0 = global) for attn layers
    moe: bool = False  # MoE MLP instead of dense MLP


def group_layout(cfg: ArchConfig) -> List[LayerDesc]:
    """The static per-layer plan of one scanned group."""
    if cfg.family == "rwkv":
        return [LayerDesc("rwkv")]
    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every or cfg.n_layers
        return [LayerDesc("mamba") for _ in range(k)]
    if cfg.local_per_global > 0:
        g = cfg.local_per_global + 1
        return [
            LayerDesc(
                "attn",
                window=cfg.local_window if i < cfg.local_per_global else 0,
                moe=cfg.is_moe,
            )
            for i in range(g)
        ]
    if cfg.is_moe and cfg.moe_interleave > 1:
        # llama4-style: dense layer then routed layer, repeating
        return [
            LayerDesc("attn", moe=(i % cfg.moe_interleave == cfg.moe_interleave - 1))
            for i in range(cfg.moe_interleave)
        ]
    return [LayerDesc("attn", moe=cfg.is_moe)]


def n_groups(cfg: ArchConfig) -> int:
    layout = group_layout(cfg)
    if cfg.n_layers % len(layout) != 0:
        raise ValueError(
            f"{cfg.name}: n_layers={cfg.n_layers} not divisible by group size {len(layout)}"
        )
    return cfg.n_layers // len(layout)


def has_shared_block(cfg: ArchConfig) -> bool:
    return cfg.family == "hybrid" and cfg.hybrid_attn_every > 0


# --------------------------------------------------------------------------
# templates
# --------------------------------------------------------------------------
def _layer_template(cfg: ArchConfig, desc: LayerDesc) -> Dict[str, Any]:
    if desc.kind == "rwkv":
        return rwkv_template(cfg)
    if desc.kind == "mamba":
        return {"ln1": norm_template(cfg), "mamba": mamba_template(cfg)}
    t = {
        "ln1": norm_template(cfg),
        "attn": attention_template(cfg),
        "ln2": norm_template(cfg),
    }
    t["mlp"] = moe_template(cfg) if desc.moe else mlp_template(cfg)
    return t


def shared_block_template(cfg: ArchConfig) -> Dict[str, Any]:
    """zamba2 shared attention+MLP block (one copy, reused every group)."""
    return {
        "ln1": norm_template(cfg),
        "attn": attention_template(cfg),
        "ln2": norm_template(cfg),
        "mlp": mlp_template(cfg),
    }


def group_template(cfg: ArchConfig) -> Dict[str, Any]:
    return {"layers": [_layer_template(cfg, d) for d in group_layout(cfg)]}


def stack_template(cfg: ArchConfig) -> Dict[str, Any]:
    """Full decoder template: scanned groups + (optional) shared block."""
    t: Dict[str, Any] = {"groups": stack_tree(group_template(cfg), n_groups(cfg))}
    if has_shared_block(cfg):
        t["shared"] = shared_block_template(cfg)
    return t


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------
def _layer_cache_shape(
    cfg: ArchConfig, desc: LayerDesc, batch: int, max_seq: int
) -> Dict[str, Tuple[Tuple[int, ...], Tuple[Optional[str], ...], Any]]:
    """name -> (shape, logical axes, dtype) for one layer's decode state."""
    cd = cfg.cache_dtype
    if desc.kind == "rwkv":
        s = rwkv_cache_shape(cfg, batch)
        return {
            "wkv": (s["wkv"], ("batch", "heads", "head_dim", None), jnp.float32),
            "shift_tm": (s["shift_tm"], ("batch", "embed"), cd),
            "shift_cm": (s["shift_cm"], ("batch", "embed"), cd),
        }
    if desc.kind == "mamba":
        s = mamba_cache_shape(cfg, batch)
        return {
            "ssm": (s["ssm"], ("batch", "heads", "state", "head_dim"), jnp.float32),
            "conv_x": (s["conv_x"], ("batch", None, "heads", "head_dim"), cd),
            "conv_b": (s["conv_b"], ("batch", None, None, "state"), cd),
            "conv_c": (s["conv_c"], ("batch", None, None, "state"), cd),
        }
    seq = (
        min(max_seq, desc.window) if (cfg.windowed_cache and desc.window > 0) else max_seq
    )
    kv = (batch, seq, cfg.n_kv, cfg.hd)
    ax = ("batch", "seq", "kv_heads", "head_dim")
    return {"k": (kv, ax, cd), "v": (kv, ax, cd)}


def cache_layout(
    cfg: ArchConfig, batch: int, max_seq: int
) -> Tuple[Dict[str, Any], Dict[str, Any], Dict[str, Any]]:
    """Returns (shapes, logical, dtypes) trees for the whole stack's cache.

    Every leaf carries a leading ``n_groups`` dim (logical axis 'layers') so
    the scan can slice per group.
    """
    G = n_groups(cfg)
    layout = group_layout(cfg)
    shapes: Dict[str, Any] = {"layers": []}
    logical: Dict[str, Any] = {"layers": []}
    dtypes: Dict[str, Any] = {"layers": []}
    for d in layout:
        ls = _layer_cache_shape(cfg, d, batch, max_seq)
        shapes["layers"].append({k: (G,) + v[0] for k, v in ls.items()})
        logical["layers"].append({k: ("layers",) + v[1] for k, v in ls.items()})
        dtypes["layers"].append({k: v[2] for k, v in ls.items()})
    if has_shared_block(cfg):
        # the shared block runs once per group -> per-group KV cache
        kv = (G, batch, max_seq, cfg.n_kv, cfg.hd)
        ax = ("layers", "batch", "seq", "kv_heads", "head_dim")
        shapes["shared"] = {"k": kv, "v": kv}
        logical["shared"] = {"k": ax, "v": ax}
        dtypes["shared"] = {"k": cfg.cache_dtype, "v": cfg.cache_dtype}
    return shapes, logical, dtypes


def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    shapes, _, dtypes = cache_layout(cfg, batch, max_seq)
    return jax.tree.map(
        lambda s, dt: jnp.zeros(s, dt), shapes, dtypes,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int):
    shapes, _, dtypes = cache_layout(cfg, batch, max_seq)
    return jax.tree.map(
        lambda s, dt: jax.ShapeDtypeStruct(s, dt), shapes, dtypes,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def cache_logical(cfg: ArchConfig, batch: int = 1, max_seq: int = 8):
    _, logical, _ = cache_layout(cfg, batch, max_seq)
    return logical


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------
def _layer_apply(
    cfg: ArchConfig,
    desc: LayerDesc,
    p: Dict[str, Any],
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cache: Optional[Dict[str, Any]],
    cache_pos,
    moe_ctx,
) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]], jnp.ndarray]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if desc.kind == "rwkv":
        x, new_cache = rwkv_block_apply(cfg, p, x, cache)
        return x, new_cache, aux
    if desc.kind == "mamba":
        h, new_inner = mamba_apply(cfg, p["mamba"], norm_apply(cfg, p["ln1"], x), cache)
        return x + h, new_inner, aux
    # attention layer
    kv_cache = {"k": cache["k"], "v": cache["v"]} if cache is not None else None
    h, new_kv = attention_apply(
        cfg,
        p["attn"],
        norm_apply(cfg, p["ln1"], x),
        positions,
        window=desc.window,
        cache=kv_cache,
        cache_pos=cache_pos,
        ctx=moe_ctx,
    )
    x = x + h
    h2 = norm_apply(cfg, p["ln2"], x)
    if desc.moe:
        out, aux = moe_apply(cfg, p["mlp"], h2, ctx=moe_ctx)
    else:
        out = mlp_apply(cfg, p["mlp"], h2)
    x = x + out
    return x, new_kv, aux


def _group_apply(
    cfg: ArchConfig,
    layout: List[LayerDesc],
    p_group: Dict[str, Any],
    p_shared: Optional[Dict[str, Any]],
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cache_group: Optional[Dict[str, Any]],
    cache_pos,
    moe_ctx,
):
    new_cache: Dict[str, Any] = {"layers": []}
    aux_total = jnp.zeros((), jnp.float32)
    for i, desc in enumerate(layout):
        c_i = cache_group["layers"][i] if cache_group is not None else None
        x, nc, aux = _layer_apply(
            cfg, desc, p_group["layers"][i], x, positions, c_i, cache_pos, moe_ctx
        )
        new_cache["layers"].append(nc if nc is not None else {})
        aux_total = aux_total + aux
    if p_shared is not None:
        sc = cache_group.get("shared") if cache_group is not None else None
        x, nkv, _ = _layer_apply(
            cfg, LayerDesc("attn"), p_shared, x, positions, sc, cache_pos, moe_ctx
        )
        new_cache["shared"] = nkv if nkv is not None else {}
    if cache_group is None:
        return x, None, aux_total
    return x, new_cache, aux_total


def _remat_wrap(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)  # 'full'


def stack_apply(
    cfg: ArchConfig,
    params: Dict[str, Any],
    x: jnp.ndarray,  # (B, S, D) embedded input
    positions: jnp.ndarray,  # (B, S)
    cache: Optional[Dict[str, Any]] = None,
    cache_pos=None,
    moe_ctx=None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]], jnp.ndarray]:
    """Scan the layer groups. Returns (hidden, new_cache, aux_loss)."""
    layout = group_layout(cfg)
    p_shared = params.get("shared")
    G = n_groups(cfg)

    def body(carry, xs):
        h, aux = carry
        p_g, c_g = xs
        if cfg.cast_in_scan:
            # convert sits INSIDE the loop: the transpose (bf16 cotangent ->
            # fp32 master grad) lands outside, so per-group weight-grad
            # reductions move bf16, not fp32
            cd = cfg.compute_dtype
            p_g = jax.tree.map(
                lambda p: p.astype(cd)
                if jnp.issubdtype(p.dtype, jnp.floating) and p.ndim >= 2
                else p,
                p_g,
            )
        if moe_ctx is not None:
            # anchor the residual stream to the DP layout every group —
            # without this the partitioner may all-gather the batch to
            # chase the FSDP weight sharding (see MoeCtx docstring)
            h = moe_ctx.constrain_batch(h)
            if moe_ctx.group_param_constraint is not None:
                p_g = moe_ctx.group_param_constraint(p_g)
        h, new_c, aux_g = _group_apply(
            cfg, layout, p_g, p_shared, h, positions, c_g, cache_pos, moe_ctx
        )
        return (h, aux + aux_g), new_c

    body = _remat_wrap(cfg, body)

    if cfg.scan_layers:
        (x, aux), new_cache = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (params["groups"], cache)
        )
    else:
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for g in range(G):
            p_g = jax.tree.map(lambda a: a[g], params["groups"])
            c_g = jax.tree.map(lambda a: a[g], cache) if cache is not None else None
            (x, aux), nc = body((x, aux), (p_g, c_g))
            new_caches.append(nc)
        new_cache = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
            if cache is not None
            else None
        )
    return x, new_cache, aux
