"""LM substrate: composable model definitions for the assigned archs."""

from .model import Model, build_model, param_counts

__all__ = ["Model", "build_model", "param_counts"]
