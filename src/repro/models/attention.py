"""GQA attention: qk-norm, RoPE, sliding-window/global masks, KV cache.

Layouts: activations (B, S, H, hd); KV cache (B, Smax, Hkv, hd).
``window`` may be a *traced* scalar (0 = global) so a scanned layer stack
can mix local and global layers (gemma3's 5:1) without breaking scan
uniformity.  The Pallas flash kernel is used on TPU for the static-window
no-cache path (train/prefill); the jnp path is the portable fallback and
the dry-run target.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import PSpec, apply_rope, rms_norm, rope_embed

NEG_INF = -1e30


def attention_template(cfg: ArchConfig) -> Dict[str, PSpec]:
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    t = {
        "wq": PSpec((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": PSpec((D, Hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((D, Hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((H, hd, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        t["q_norm"] = PSpec((hd,), ("head_dim",), init="ones")
        t["k_norm"] = PSpec((hd,), ("head_dim",), init="ones")
    return t


def _qkv(cfg: ArchConfig, p, x, positions, window: int = 0):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.pos_type == "rope":
        # gemma3: sliding-window layers use the short (local) rope base
        theta = cfg.rope_theta_local if (isinstance(window, int) and window > 0) else cfg.rope_theta
        cos, sin = rope_embed(positions, cfg.hd, theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _sdpa(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Sk, Hkv, hd)
    v: jnp.ndarray,  # (B, Sk, Hkv, hd)
    q_pos: jnp.ndarray,  # (B, Sq)
    k_pos: jnp.ndarray,  # (B, Sk)
    k_valid: Optional[jnp.ndarray],  # (B, Sk) bool or None
    window,  # int or traced scalar; 0 = global
    score_dtype: str = "f32",
) -> jnp.ndarray:
    """Portable attention.  ``score_dtype='bf16'`` keeps the (Sq, Sk) score
    and probability tensors in bf16 — HALF the HBM traffic of the dominant
    intermediate (§Perf hillclimb; max-subtracted softmax keeps bf16 safe);
    the p@v contraction still accumulates in fp32."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    dt = jnp.bfloat16 if score_dtype == "bf16" else jnp.float32
    qg = q.reshape(B, Sq, Hkv, g, hd)
    logits = jnp.einsum(
        "bqhgk,bshk->bhgqs", qg.astype(dt), k.astype(dt),
        preferred_element_type=dt,
    ) * jnp.asarray(hd ** -0.5, dt)
    mask = k_pos[:, None, :] <= q_pos[:, :, None]  # causal
    win_ok = (k_pos[:, None, :] > q_pos[:, :, None] - window) | (window <= 0)
    mask &= win_ok
    if k_valid is not None:
        mask &= k_valid[:, None, :]
    logits = jnp.where(mask[:, None, None, :, :], logits, jnp.asarray(NEG_INF, dt))
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bhgqs,bshk->bqhgk", probs, v.astype(dt),
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _sdpa_chunked(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Sk, Hkv, hd)
    v: jnp.ndarray,
    q_pos: jnp.ndarray,  # (B, Sq)
    k_pos: jnp.ndarray,  # (B, Sk)
    k_valid: Optional[jnp.ndarray],
    window,
    q_chunk: int,
    score_dtype: str = "f32",
) -> jnp.ndarray:
    """Flash-style scan over query chunks with per-chunk remat.

    TPU adaptation of the paper's cuBLAS leaf for attention: the (Sq, Sk)
    score matrix never materializes — each scan step holds one
    (B, c, H, Sk) block, and ``jax.checkpoint`` recomputes it in backward.
    (On real TPUs the Pallas flash kernel replaces this; this is the
    portable XLA form with identical memory behaviour.)
    """
    B, Sq, H, hd = q.shape
    c = q_chunk
    nc = Sq // c

    def chunk(x):  # (B,Sq,...) -> (nc,B,c,...)
        return x.reshape((B, nc, c) + x.shape[2:]).swapaxes(0, 1)

    @jax.checkpoint
    def body(_, inp):
        qc, pc = inp  # (B,c,H,hd), (B,c)
        o = _sdpa(qc, k, v, pc, k_pos, k_valid, window, score_dtype)
        return (), o

    _, ys = jax.lax.scan(body, (), (chunk(q), chunk(q_pos)))
    return ys.swapaxes(0, 1).reshape(B, Sq, H, hd)


def _sdpa_auto(cfg: ArchConfig, q, k, v, q_pos, k_pos, k_valid, window):
    """Pick chunked vs direct attention by query length."""
    Sq = q.shape[1]
    if Sq > cfg.attn_q_chunk and Sq % cfg.attn_q_chunk == 0:
        return _sdpa_chunked(
            q, k, v, q_pos, k_pos, k_valid, window, cfg.attn_q_chunk,
            cfg.score_dtype,
        )
    return _sdpa(q, k, v, q_pos, k_pos, k_valid, window, cfg.score_dtype)


def attention_apply(
    cfg: ArchConfig,
    p,
    x: jnp.ndarray,  # (B, S, D)
    positions: jnp.ndarray,  # (B, S)
    window=0,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    cache_pos: Optional[jnp.ndarray] = None,  # (B,) write index for decode
    ctx=None,  # MoeCtx: activation-sharding anchors
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Returns (output, updated_cache)."""
    q, k, v = _qkv(cfg, p, x, positions, window)
    if ctx is not None and cache is None and cfg.anchor_attn:
        # anchor the Megatron layout: heads over TP, full seq (the
        # all-gather from the SP layout happens HERE, once, in bf16)
        q = ctx.constrain_heads(q)
        k = ctx.constrain_heads(k)
        v = ctx.constrain_heads(v)
    if cache is None:
        if cfg.use_pallas and isinstance(window, int):
            from ..kernels.flash_attention import flash_attention

            o = flash_attention(
                q.transpose(0, 2, 1, 3),
                k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3),
                causal=True,
                window=window,
            ).transpose(0, 2, 1, 3)
        else:
            o = _sdpa_auto(cfg, q, k, v, positions, positions, None, window)
        if ctx is not None and cfg.anchor_attn:
            o = ctx.constrain_heads(o)
        new_cache = None
    else:
        # decode: write new K/V at cache_pos, attend over the whole cache
        B = x.shape[0]
        Smax = cache["k"].shape[1]
        if jnp.ndim(cache_pos) == 0:
            # uniform position: O(1) in-place update instead of O(Smax) select
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1
            )
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1
            )
            cache_pos_b = jnp.broadcast_to(cache_pos, (B,))
        else:
            idx = cache_pos[:, None, None, None]  # (B,1,1,1)
            arange = jnp.arange(Smax)[None, :, None, None]
            sel = arange == idx
            ck = jnp.where(sel, k.astype(cache["k"].dtype), cache["k"])
            cv = jnp.where(sel, v.astype(cache["v"].dtype), cache["v"])
            cache_pos_b = cache_pos
        k_pos = jnp.broadcast_to(jnp.arange(Smax)[None, :], (B, Smax))
        # valid = written region (last written index = cache_pos + Sq - 1);
        # causality vs the query positions is enforced inside _sdpa.
        k_valid = k_pos <= cache_pos_b[:, None] + (x.shape[1] - 1)
        o = _sdpa_auto(cfg, q, ck, cv, positions, k_pos, k_valid, window)
        new_cache = {"k": ck, "v": cv}
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    return out, new_cache


def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int, n: int, dtype):
    """n stacked caches (scan over layers / hybrid groups)."""
    shape = (n, batch, max_seq, cfg.n_kv, cfg.hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def kv_cache_specs(cfg: ArchConfig, batch: int, max_seq: int, n: int, dtype):
    shape = (n, batch, max_seq, cfg.n_kv, cfg.hd)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }
