"""RWKV6 ("Finch") block: data-dependent per-channel decay, token shift.

The hallmark of RWKV6 is the LoRA-produced *data-dependent decay*
``w_t = exp(-exp(w0 + tanh(x_w A) B))`` per channel.  We implement the
WKV6 recurrence with a chunked formulation whose every exponent is <= 0
(chunk-relative log-decay differences), so fp32 is overflow-safe with no
clamping:

  intra:  A[i,j] = sum_k r_i[k] k_j[k] exp(l_{i-1}[k] - l_j[k])   (j < i)
          A[i,i] = sum_k r_i[k] u[k] k_i[k]                       (bonus u)
  state:  S <- exp(l_last) * S + sum_j (k_j exp(l_last - l_j)) (x) v_j
  inter:  y_i += (r_i exp(l_{i-1}[k])) . S_prev

Simplifications vs. the reference implementation (noted per DESIGN.md):
static token-shift mix vectors (RWKV5-style) for r/k/v/g; the decay w keeps
the full data-dependent LoRA path.  Decode state is O(1): (B,H,K,V) wkv
state + one-token shift states.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import PSpec, norm_apply, norm_template, rms_norm


def _dims(cfg: ArchConfig):
    D = cfg.d_model
    K = cfg.rwkv_head_size
    H = D // K
    return D, H, K


def rwkv_template(cfg: ArchConfig) -> Dict[str, PSpec]:
    D, H, K = _dims(cfg)
    F = cfg.d_ff
    lora = 64
    return {
        "ln1": norm_template(cfg),
        "ln2": norm_template(cfg),
        # time-mix
        "mu": PSpec((5, D), (None, "embed"), init="const", scale=0.5),
        "wr": PSpec((D, H, K), ("embed", "heads", "head_dim")),
        "wk": PSpec((D, H, K), ("embed", "heads", "head_dim")),
        "wv": PSpec((D, H, K), ("embed", "heads", "head_dim")),
        "wg": PSpec((D, H, K), ("embed", "heads", "head_dim")),
        "w0": PSpec((H, K), ("heads", "head_dim"), init="zeros"),
        "w_lora_a": PSpec((D, lora), ("embed", None)),
        "w_lora_b": PSpec((lora, H, K), (None, "heads", "head_dim"), scale=0.1),
        "u": PSpec((H, K), ("heads", "head_dim"), init="zeros"),
        "ln_x": PSpec((H, K), ("heads", "head_dim"), init="ones"),
        "wo": PSpec((H, K, D), ("heads", "head_dim", "embed")),
        # channel-mix
        "mu_cm": PSpec((2, D), (None, "embed"), init="const", scale=0.5),
        "wk_cm": PSpec((D, F), ("embed", "mlp")),
        "wv_cm": PSpec((F, D), ("mlp", "embed")),
        "wr_cm": PSpec((D, D), ("embed", None)),
    }


def _shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Token shift: x_{t-1} (zero / carried state at t=0)."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None, :], x[:, :-1]], axis=1)


def wkv6_chunked(
    r: jnp.ndarray,  # (B,S,H,K)
    k: jnp.ndarray,  # (B,S,H,K)
    v: jnp.ndarray,  # (B,S,H,K)  (V == K)
    log_w: jnp.ndarray,  # (B,S,H,K) fp32 <= 0
    u: jnp.ndarray,  # (H,K)
    chunk: int,
    s0: Optional[jnp.ndarray] = None,  # (B,H,K,V)
    mix_dtype=jnp.float32,  # bf16 halves the dominant (B,Q,Q,H,K) traffic
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential ``lax.scan`` over chunks: working set is ONE chunk's
    (B,Q,Q,H,K) pairwise-decay tensor (rematerialized in backward), never
    the full-sequence O(S*Q*H*K) blow-up.  Every exponent is <= 0 (so the
    decay weights are in [0,1] — safe to round to ``mix_dtype``); the state
    scan and all exponents stay fp32."""
    B, S, H, K = r.shape
    Q = min(chunk, S)
    while S % Q:  # largest divisor of S not exceeding the requested chunk
        Q -= 1
    nc = S // Q
    f32 = jnp.float32

    def chunks(x):  # (B,S,H,K) -> (nc,B,Q,H,K)
        return x.reshape(B, nc, Q, H, K).swapaxes(0, 1)

    tri_strict = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
    eye = jnp.eye(Q, dtype=f32)
    u32 = u.astype(f32)

    @jax.checkpoint
    def body(s, inp):
        rc, kc, vc, lw = inp  # (B,Q,H,K) fp32
        l = jnp.cumsum(lw, axis=1)  # inclusive log-decay
        l_exc = l - lw  # exclusive
        # intra: pair[i,j,k] = exp(l_exc[i,k] - l[j,k]), j < i (exponent <= 0)
        diff = l_exc[:, :, None, :, :] - l[:, None, :, :, :]  # (B,i,j,H,K)
        pair = jnp.where(tri_strict[None, :, :, None, None], jnp.exp(diff), 0.0)
        md = mix_dtype
        A = jnp.einsum(
            "bihk,bijhk,bjhk->bijh", rc.astype(md), pair.astype(md),
            kc.astype(md), preferred_element_type=f32,
        )
        A_diag = jnp.einsum("bihk,hk,bihk->bih", rc, u32, kc)
        A = A + A_diag[:, :, None, :] * eye[None, :, :, None]
        y = jnp.einsum(
            "bijh,bjhk->bihk", A.astype(md), vc.astype(md),
            preferred_element_type=f32,
        )
        # inter: contribution of the carried state (exponent <= 0)
        y = y + jnp.einsum("bqhk,bhkv->bqhv", rc * jnp.exp(l_exc), s)
        # state update (exponents <= 0)
        k_dec = kc * jnp.exp(l[:, -1:, :, :] - l)
        s = jnp.exp(l[:, -1])[..., None] * s + jnp.einsum(
            "bqhk,bqhv->bhkv", k_dec, vc
        )
        return s, y

    s_init = jnp.zeros((B, H, K, K), f32) if s0 is None else s0.astype(f32)
    xs = (chunks(r).astype(f32), chunks(k).astype(f32), chunks(v).astype(f32),
          chunks(log_w))
    s_final, ys = jax.lax.scan(body, s_init, xs)
    y = ys.swapaxes(0, 1).reshape(B, S, H, K)
    return y.astype(r.dtype), s_final


def rwkv_block_apply(
    cfg: ArchConfig,
    p,
    x: jnp.ndarray,  # (B,S,D)
    cache: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Full RWKV6 layer: time-mix + channel-mix (both with token shift)."""
    D, H, K = _dims(cfg)
    B, S, _ = x.shape
    new_cache = {} if cache is not None else None

    # ---- time mix (pre-norm, paper-standard x = x + TM(LN1 x)) -------------
    xa = norm_apply(cfg, p["ln1"], x)
    prev = cache["shift_tm"] if cache is not None else None
    xp = _shift(xa, prev)
    mu = p["mu"].astype(x.dtype)  # (5, D): r,k,v,w,g
    mix = lambda i: xa + mu[i] * (xp - xa)
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = jnp.einsum("bsd,dhk->bshk", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xv, p["wv"].astype(x.dtype))
    g = jnp.einsum("bsd,dhk->bshk", xg, p["wg"].astype(x.dtype))
    lora = jnp.einsum(
        "bsd,dl->bsl", jnp.tanh(xw.astype(jnp.float32)), p["w_lora_a"].astype(jnp.float32)
    )
    wexp = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsl,lhk->bshk", jnp.tanh(lora), p["w_lora_b"].astype(jnp.float32)
    )
    log_w = -jnp.exp(wexp)  # data-dependent decay, always <= 0

    s0 = cache["wkv"] if cache is not None else None
    # chunked in ALL modes: the recurrence carries state across chunks, so
    # prefill-with-cache must NOT fall back to one S-sized chunk (the
    # (B,S,S,H,K) pair tensor would be terabytes at 32k)
    chunk = cfg.rwkv_chunk if S > 1 else 1
    mix_dtype = jnp.bfloat16 if cfg.score_dtype == "bf16" else jnp.float32
    y, s_final = wkv6_chunked(r, k, v, log_w, p["u"], chunk, s0,
                              mix_dtype=mix_dtype)
    y = rms_norm(y, jnp.ones((), y.dtype)) * p["ln_x"].astype(y.dtype)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    tm_out = jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(y.dtype))
    x = x + tm_out
    if cache is not None:
        new_cache["wkv"] = s_final

    # ---- channel mix (pre-norm) ---------------------------------------------
    xb = norm_apply(cfg, p["ln2"], x)
    prev_cm = cache["shift_cm"] if cache is not None else None
    xp2 = _shift(xb, prev_cm)
    mu_cm = p["mu_cm"].astype(x.dtype)
    xk2 = xb + mu_cm[0] * (xp2 - xb)
    xr2 = xb + mu_cm[1] * (xp2 - xb)
    kk = jnp.einsum("bsd,df->bsf", xk2, p["wk_cm"].astype(x.dtype))
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    vv = jnp.einsum("bsf,fd->bsd", kk, p["wv_cm"].astype(x.dtype))
    rr = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr2, p["wr_cm"].astype(x.dtype)).astype(jnp.float32)
    ).astype(x.dtype)
    out = x + rr * vv

    if cache is not None:
        # shift states carry the *normed inputs* at the last position
        new_cache["shift_tm"] = xa[:, -1]
        new_cache["shift_cm"] = xb[:, -1]
    return out, new_cache


def rwkv_cache_shape(cfg: ArchConfig, batch: int) -> Dict[str, Tuple[int, ...]]:
    D, H, K = _dims(cfg)
    return {
        "wkv": (batch, H, K, K),
        "shift_tm": (batch, D),
        "shift_cm": (batch, D),
    }
