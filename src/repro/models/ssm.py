"""Mamba2 block (state-space dual / SSD) with chunked scan.

TPU adaptation: instead of a per-token recurrence (serial, VPU-bound) the
sequence is processed in chunks — intra-chunk work is a masked (Q x Q)
matmul (MXU) and only the small per-chunk state (B, H, N, P) is carried by
``lax.scan`` (DESIGN.md: rethinking a GPU scan kernel as MXU-friendly
blocking).  All decay exponents are <= 0 by construction, so fp32 ``exp``
never overflows.

Decode keeps O(1) state: the SSM state (B,H,N,P) plus a (ck-1)-deep
convolution tail per stream.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import PSpec, rms_norm


def mamba_template(cfg: ArchConfig) -> Dict[str, PSpec]:
    D = cfg.d_model
    H = cfg.ssm_heads
    P = (cfg.ssm_expand * D) // H  # head dim of the inner stream
    N = cfg.ssm_state
    ck = cfg.ssm_conv
    G = 1  # B/C groups
    return {
        "wz": PSpec((D, H, P), ("embed", "heads", "head_dim")),
        "wx": PSpec((D, H, P), ("embed", "heads", "head_dim")),
        "wb": PSpec((D, G, N), ("embed", None, None)),
        "wc": PSpec((D, G, N), ("embed", None, None)),
        "wdt": PSpec((D, H), ("embed", "heads")),
        "conv_x": PSpec((ck, H, P), (None, "heads", "head_dim"), init="normal"),
        "conv_b": PSpec((ck, G, N), (None, None, None)),
        "conv_c": PSpec((ck, G, N), (None, None, None)),
        "A_log": PSpec((H,), ("heads",), init="zeros"),
        "dt_bias": PSpec((H,), ("heads",), init="zeros"),
        "D_skip": PSpec((H,), ("heads",), init="ones"),
        "norm": PSpec((H, P), ("heads", "head_dim"), init="ones"),
        "wo": PSpec((H, P, D), ("heads", "head_dim", "embed")),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along axis 1. x: (B,S,...), w: (ck, ...)."""
    ck = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(ck):  # ck is tiny (4): unrolled shifts
        shift = ck - 1 - i
        xi = x if shift == 0 else jnp.pad(x, [(0, 0), (shift, 0)] + [(0, 0)] * (x.ndim - 2))[:, : x.shape[1]]
        out = out + xi * w[i].astype(x.dtype)
    return out


def ssd_chunked(
    xs: jnp.ndarray,  # (B,S,H,P)
    dt: jnp.ndarray,  # (B,S,H) fp32, positive
    A: jnp.ndarray,  # (H,) fp32, negative
    bs: jnp.ndarray,  # (B,S,G,N)
    cs: jnp.ndarray,  # (B,S,G,N)
    chunk: int,
    s0: Optional[jnp.ndarray] = None,  # (B,H,N,P) initial state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,H,P), final_state (B,H,N,P)).

    Sequential ``lax.scan`` over chunks: the working set is ONE chunk's
    (B,Q,Q,H) decay matrix (rematerialized in backward) — never the
    full-sequence O(S*Q*H) blow-up.  Exponents are <= 0 throughout.
    """
    B, S, H, P = xs.shape
    G, N = bs.shape[2], bs.shape[3]
    Q = min(chunk, S)
    while S % Q:  # largest divisor of S not exceeding the requested chunk
        Q -= 1
    nc = S // Q
    hg = H // G
    f32 = jnp.float32

    def chunks(x):  # (B,S,...) -> (nc,B,Q,...)
        return x.reshape((B, nc, Q) + x.shape[2:]).swapaxes(0, 1)

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    @jax.checkpoint
    def body(s, inp):
        xc, dtc, bc, cc = inp  # (B,Q,H,P) (B,Q,H) (B,Q,G,N) (B,Q,G,N)
        log_a = dtc * A  # (B,Q,H) <= 0
        l = jnp.cumsum(log_a, axis=1)  # inclusive
        # intra: M[i,j] = exp(l_i - l_j), i >= j (exponent <= 0)
        diff = l[:, :, None, :] - l[:, None, :, :]  # (B,Q,Q,H)
        M = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        CB = jnp.einsum("bqgn,bkgn->bqkg", cc, bc)  # (B,Q,Q,G)
        W = jnp.repeat(CB, hg, axis=-1) * M * dtc[:, None, :, :]
        y = jnp.einsum("bqkh,bkhp->bqhp", W, xc)
        # inter: carried state, weighted by decay from chunk start
        cs_h = jnp.repeat(cc, hg, axis=2)  # (B,Q,H,N)
        y = y + jnp.einsum("bqhn,bhnp->bqhp", cs_h * jnp.exp(l)[..., None], s)
        # state update
        decay_to_end = jnp.exp(l[:, -1:, :] - l)  # (B,Q,H) <= 1
        wj = (dtc * decay_to_end)[..., None]  # (B,Q,H,1)
        bs_h = jnp.repeat(bc, hg, axis=2)  # (B,Q,H,N)
        s = jnp.exp(l[:, -1])[:, :, None, None] * s + jnp.einsum(
            "bqhn,bqhp->bhnp", bs_h, xc * wj
        )
        return s, y

    s_init = jnp.zeros((B, H, N, P), f32) if s0 is None else s0.astype(f32)
    xs_in = (
        chunks(xs).astype(f32),
        chunks(dt).astype(f32),
        chunks(bs).astype(f32),
        chunks(cs).astype(f32),
    )
    s_final, ys = jax.lax.scan(body, s_init, xs_in)
    y = ys.swapaxes(0, 1).reshape(B, S, H, P)
    return y.astype(xs.dtype), s_final


def mamba_apply(
    cfg: ArchConfig,
    p,
    x: jnp.ndarray,  # (B,S,D)
    cache: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    B, S, D = x.shape
    H = cfg.ssm_heads
    P = (cfg.ssm_expand * D) // H
    ck = cfg.ssm_conv

    z = jnp.einsum("bsd,dhp->bshp", x, p["wz"].astype(x.dtype))
    xs = jnp.einsum("bsd,dhp->bshp", x, p["wx"].astype(x.dtype))
    bs = jnp.einsum("bsd,dgn->bsgn", x, p["wb"].astype(x.dtype))
    cs = jnp.einsum("bsd,dgn->bsgn", x, p["wc"].astype(x.dtype))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(x.dtype))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if cache is None:
        xs_c = _causal_conv(xs, p["conv_x"])
        bs_c = _causal_conv(bs, p["conv_b"])
        cs_c = _causal_conv(cs, p["conv_c"])
        new_cache = None
    else:
        # decode: prepend conv tails (B, ck-1, ...), keep last ck-1 raw inputs
        xs_full = jnp.concatenate([cache["conv_x"].astype(xs.dtype), xs], axis=1)
        bs_full = jnp.concatenate([cache["conv_b"].astype(bs.dtype), bs], axis=1)
        cs_full = jnp.concatenate([cache["conv_c"].astype(cs.dtype), cs], axis=1)
        xs_c = _causal_conv(xs_full, p["conv_x"])[:, ck - 1 :]
        bs_c = _causal_conv(bs_full, p["conv_b"])[:, ck - 1 :]
        cs_c = _causal_conv(cs_full, p["conv_c"])[:, ck - 1 :]
        new_cache = {
            "conv_x": xs_full[:, -(ck - 1) :],
            "conv_b": bs_full[:, -(ck - 1) :],
            "conv_c": cs_full[:, -(ck - 1) :],
        }
    act = lambda t: jax.nn.silu(t.astype(jnp.float32)).astype(t.dtype)
    xs_c, bs_c, cs_c = act(xs_c), act(bs_c), act(cs_c)

    if cache is None:
        y, _ = ssd_chunked(xs_c, dt, A, bs_c, cs_c, cfg.ssm_chunk)
    else:
        # chunked prefill too: one S-sized chunk would materialize the
        # (B,S,S,H) decay matrix (terabytes at 32k)
        y, s_final = ssd_chunked(
            xs_c, dt, A, bs_c, cs_c,
            chunk=cfg.ssm_chunk if S > 1 else 1, s0=cache["ssm"],
        )
        new_cache["ssm"] = s_final
    y = y + p["D_skip"].astype(y.dtype)[:, None] * xs_c
    y = rms_norm(
        y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
        jnp.ones((), y.dtype),  # scale applied below per (H,P)
    ) * p["norm"].astype(y.dtype)
    out = jnp.einsum("bshp,hpd->bsd", y, p["wo"].astype(y.dtype))
    return out, new_cache


def mamba_cache_shape(cfg: ArchConfig, batch: int) -> Dict[str, Tuple[int, ...]]:
    D = cfg.d_model
    H = cfg.ssm_heads
    P = (cfg.ssm_expand * D) // H
    N = cfg.ssm_state
    ck = cfg.ssm_conv
    G = 1
    return {
        "ssm": (batch, H, N, P),
        "conv_x": (batch, ck - 1, H, P),
        "conv_b": (batch, ck - 1, G, N),
        "conv_c": (batch, ck - 1, G, N),
    }
