"""Top-level model: embed/frontend -> group-scanned stack -> norm -> head.

``build_model(cfg)`` returns a ``Model`` namespace of *pure functions* so
the launch layer can jit/pjit them with explicit shardings:

    template()/init(rng)        parameter template / materialized params
    forward(params, batch,...)  hidden states (+ cache, moe aux)
    loss(params, batch)         scalar LM loss + metrics (chunked xent)
    prefill(params, batch, cache)   fill the KV cache for a prompt
    decode_step(params, cache, toks, pos)  one token with cache

Batch convention: {"tokens": (B,S) int32} for token-input archs, or
{"embeds": (B,S,D)} for stub-frontend archs ([audio]/[vlm]); training adds
{"labels": (B,S) int32}.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .frontend import uses_stub_frontend
from .layers import (
    PSpec,
    abstract_params,
    count_template,
    init_params,
    logical_tree,
    norm_apply,
    norm_template,
    sinusoidal_embed,
)
from .moe import MoeCtx
from .transformer import (
    cache_logical,
    cache_specs,
    group_layout,
    init_cache,
    n_groups,
    stack_apply,
    stack_template,
)


def model_template(cfg: ArchConfig) -> Dict[str, Any]:
    D, V = cfg.d_model, cfg.vocab
    t: Dict[str, Any] = {}
    if not uses_stub_frontend(cfg):
        t["embed"] = PSpec((V, D), ("vocab", "embed"), init="embed", scale=0.02)
    t["stack"] = stack_template(cfg)
    t["final_norm"] = norm_template(cfg)
    if uses_stub_frontend(cfg) or not cfg.tie_embeddings:
        t["lm_head"] = PSpec((D, V), ("embed", "vocab"))
    return t


def _head_weight(cfg: ArchConfig, params) -> jnp.ndarray:
    if "lm_head" in params:
        return params["lm_head"]  # (D, V)
    return params["embed"].T  # tied


def cast_for_forward(cfg: ArchConfig, params):
    """Cast >=2D float params to the compute dtype ONCE at step entry.

    The convert runs on the *sharded* leaves, so every downstream FSDP
    all-gather moves bf16 instead of fp32 master weights — half the
    gather bytes and HBM traffic (§Perf).  Router weights stay fp32
    (routing-logit precision).  Backward flows through the convert, so
    gradients accumulate into the fp32 masters unchanged.
    """
    if not cfg.cast_params:
        return params
    cd = cfg.compute_dtype

    def cast(path, p):
        keys = {getattr(k, "key", None) for k in path}
        if "router" in keys:
            return p
        if cfg.cast_in_scan and "groups" in keys:
            return p  # cast happens inside the scan body instead
        if (
            hasattr(p, "dtype")
            and jnp.issubdtype(p.dtype, jnp.floating)
            and p.ndim >= 2
            and p.dtype != jnp.dtype(cd)
        ):
            return p.astype(cd)
        return p

    return jax.tree_util.tree_map_with_path(cast, params)


def embed_batch(cfg: ArchConfig, params, batch, positions) -> jnp.ndarray:
    if "embeds" in batch:
        h = batch["embeds"].astype(cfg.compute_dtype)
    else:
        h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(
            cfg.compute_dtype
        )
    if cfg.embed_scale:
        h = h * jnp.asarray(jnp.sqrt(float(cfg.d_model)), h.dtype)
    if cfg.pos_type == "sinusoidal":
        h = h + sinusoidal_embed(positions, cfg.d_model).astype(h.dtype)
    return h


def forward(
    cfg: ArchConfig,
    params,
    batch: Dict[str, jnp.ndarray],
    positions: Optional[jnp.ndarray] = None,
    cache=None,
    cache_pos=None,
    moe_ctx: Optional[MoeCtx] = None,
):
    """Returns (hidden (B,S,D), new_cache, moe_aux)."""
    x0 = batch["embeds"] if "embeds" in batch else batch["tokens"]
    B, S = x0.shape[0], x0.shape[1]
    if positions is None:
        base = 0 if cache_pos is None else cache_pos
        positions = jnp.arange(S)[None, :] + jnp.reshape(base, (-1, 1))
        positions = jnp.broadcast_to(positions, (B, S))
    h = embed_batch(cfg, params, batch, positions)
    if moe_ctx is not None:
        h = moe_ctx.constrain_batch(h)
    h, new_cache, aux = stack_apply(
        cfg, params["stack"], h, positions, cache, cache_pos, moe_ctx
    )
    h = norm_apply(cfg, params["final_norm"], h)
    if moe_ctx is not None:
        h = moe_ctx.constrain_batch(h)
    return h, new_cache, aux


def lm_logits(cfg: ArchConfig, params, h: jnp.ndarray) -> jnp.ndarray:
    w = _head_weight(cfg, params).astype(cfg.compute_dtype)
    return jnp.einsum(
        "...d,dv->...v", h, w, preferred_element_type=jnp.float32
    )


def chunked_xent(
    cfg: ArchConfig, params, h: jnp.ndarray, labels: jnp.ndarray, moe_ctx=None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cross-entropy without materializing (B,S,V) logits.

    Scans sequence chunks; the chunk body is rematerialized so backward
    recomputes each chunk's logits instead of storing them (the (B,S,V)
    fp32 logits of a 256k-vocab model would otherwise dominate HBM).
    Returns (mean loss, token accuracy).
    """
    B, S, D = h.shape
    c = min(cfg.loss_chunk, S)
    if S % c != 0:
        c = S
    nc = S // c
    w = _head_weight(cfg, params).astype(cfg.compute_dtype)
    hc = h.reshape(B, nc, c, D).swapaxes(0, 1)  # (nc, B, c, D)
    yc = labels.reshape(B, nc, c).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_stats(hh, yy):
        logits = jnp.einsum(
            "bcd,dv->bcv", hh, w, preferred_element_type=jnp.float32
        )
        if moe_ctx is not None:
            logits = moe_ctx.constrain_logits(logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yy[..., None], axis=-1)[..., 0]
        acc = (jnp.argmax(logits, axis=-1) == yy).sum()
        return (lse - gold).sum(), acc

    def body(carry, xs):
        tot, acc = carry
        l, a = chunk_stats(*xs)
        return (tot + l, acc + a), None

    (tot, acc), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (hc, yc)
    )
    n = B * S
    return tot / n, acc.astype(jnp.float32) / n


def loss_fn(
    cfg: ArchConfig,
    params,
    batch: Dict[str, jnp.ndarray],
    moe_ctx: Optional[MoeCtx] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    params = cast_for_forward(cfg, params)
    h, _, aux = forward(cfg, params, batch, moe_ctx=moe_ctx)
    loss, acc = chunked_xent(cfg, params, h, batch["labels"], moe_ctx=moe_ctx)
    metrics = {"xent": loss, "accuracy": acc}
    if cfg.is_moe:
        n_moe = sum(1 for d in group_layout(cfg) if d.moe) * n_groups(cfg)
        aux = cfg.moe_aux_weight * aux / max(n_moe, 1)
        metrics["moe_aux"] = aux
        loss = loss + aux
    metrics["loss"] = loss
    return loss, metrics


def prefill(
    cfg: ArchConfig,
    params,
    batch: Dict[str, jnp.ndarray],
    cache,
    moe_ctx: Optional[MoeCtx] = None,
):
    """Run the prompt through the model filling ``cache`` from position 0.

    Returns (last-token logits (B, V), new_cache).
    """
    params = cast_for_forward(cfg, params)
    h, new_cache, _ = forward(
        cfg, params, batch, cache=cache, cache_pos=jnp.zeros((), jnp.int32),
        moe_ctx=moe_ctx,
    )
    return lm_logits(cfg, params, h[:, -1]), new_cache


def decode_step(
    cfg: ArchConfig,
    params,
    cache,
    batch: Dict[str, jnp.ndarray],  # tokens/embeds of shape (B, 1, ...)
    pos: jnp.ndarray,  # scalar int32 position (uniform across batch)
    moe_ctx: Optional[MoeCtx] = None,
):
    """One decode step. Returns (logits (B, V), new_cache)."""
    params = cast_for_forward(cfg, params)
    h, new_cache, _ = forward(
        cfg, params, batch, cache=cache, cache_pos=pos, moe_ctx=moe_ctx
    )
    return lm_logits(cfg, params, h[:, -1]), new_cache


# --------------------------------------------------------------------------
# parameter accounting (exact, from the template — feeds roofline MODEL_FLOPS)
# --------------------------------------------------------------------------
def param_counts(cfg: ArchConfig) -> Dict[str, int]:
    t = model_template(cfg)
    total = count_template(t)
    embed = 0
    if "embed" in t:
        embed += count_template(t["embed"])
    expert_total = 0
    expert_active = 0

    def visit(spec: PSpec):
        nonlocal expert_total, expert_active
        if "experts" in spec.logical:
            n = 1
            for d in spec.shape:
                n *= d
            expert_total += n
            expert_active += (n // cfg.n_experts) * cfg.top_k

    jax.tree.map(visit, t, is_leaf=lambda x: isinstance(x, PSpec))
    active = total - expert_total + expert_active
    return {
        "total": total,
        "active": active,
        "embed": embed,
        "active_nonembed": active - embed,
        "total_nonembed": total - embed,
    }


# --------------------------------------------------------------------------
# build
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    template: Any
    logical: Any

    def init(self, rng: jax.Array):
        return init_params(self.template, rng, self.cfg.param_dtype)

    def abstract(self):
        return abstract_params(self.template, self.cfg.param_dtype)

    def forward(self, params, batch, **kw):
        return forward(self.cfg, params, batch, **kw)

    def loss(self, params, batch, moe_ctx=None):
        return loss_fn(self.cfg, params, batch, moe_ctx=moe_ctx)

    def prefill(self, params, batch, cache, moe_ctx=None):
        return prefill(self.cfg, params, batch, cache, moe_ctx=moe_ctx)

    def decode_step(self, params, cache, batch, pos, moe_ctx=None):
        return decode_step(self.cfg, params, cache, batch, pos, moe_ctx=moe_ctx)

    def init_cache(self, batch: int, max_seq: int):
        return init_cache(self.cfg, batch, max_seq)

    def cache_specs(self, batch: int, max_seq: int):
        return cache_specs(self.cfg, batch, max_seq)

    def cache_logical(self):
        return cache_logical(self.cfg)

    def param_counts(self):
        return param_counts(self.cfg)


def build_model(cfg: ArchConfig) -> Model:
    t = model_template(cfg)
    return Model(cfg=cfg, template=t, logical=logical_tree(t))
