"""Modality frontends.

Per the assignment, ``[audio]`` / ``[vlm]`` architectures specify the
transformer BACKBONE only — the modality frontend is a STUB whose
``input_specs()`` provides *precomputed* frame/patch embeddings.  This
module defines that contract plus a deterministic synthetic embedder used
by tests/examples so end-to-end drivers have something real to feed.

  musicgen-large : EnCodec frame embeddings.  The real model sums four
                   codebook embeddings per 50 Hz frame; the stub delivers
                   the summed (B, S, d_model) frame embedding directly.
  pixtral-12b    : Pixtral-ViT patch embeddings interleaved with text
                   embeddings.  The stub delivers the fused (B, S, d_model)
                   sequence directly.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig


def uses_stub_frontend(cfg: ArchConfig) -> bool:
    return cfg.frontend in ("audio", "vision")


def embed_input_shape(cfg: ArchConfig, batch: int, seq: int) -> Tuple[int, int, int]:
    return (batch, seq, cfg.d_model)


def synth_embeddings(
    cfg: ArchConfig, rng: jax.Array, batch: int, seq: int
) -> jnp.ndarray:
    """Deterministic synthetic frame/patch embeddings (tests, examples)."""
    x = jax.random.normal(rng, (batch, seq, cfg.d_model), jnp.float32)
    return (x / jnp.sqrt(float(cfg.d_model))).astype(cfg.compute_dtype)


def synth_frames_from_audio(
    cfg: ArchConfig, audio: jnp.ndarray, frame: int = 320
) -> jnp.ndarray:
    """A stand-in 'EnCodec encoder': strided frame fold + fixed projection.

    audio: (B, T) waveform -> (B, T//frame, d_model).  Deterministic, cheap,
    and shaped like the real frontend so the serving example exercises the
    full path.
    """
    B, T = audio.shape
    S = T // frame
    x = audio[:, : S * frame].reshape(B, S, frame)
    k = jax.random.normal(jax.random.PRNGKey(0), (frame, cfg.d_model), jnp.float32)
    return (x @ (k / jnp.sqrt(frame))).astype(cfg.compute_dtype)


def synth_patches_from_image(
    cfg: ArchConfig, images: jnp.ndarray, patch: int = 16
) -> jnp.ndarray:
    """A stand-in 'ViT stem': patchify + fixed projection.

    images: (B, H, W, C) -> (B, (H//p)*(W//p), d_model).
    """
    B, H, W, C = images.shape
    ph, pw = H // patch, W // patch
    x = images[:, : ph * patch, : pw * patch]
    x = x.reshape(B, ph, patch, pw, patch, C).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(B, ph * pw, patch * patch * C)
    k = jax.random.normal(
        jax.random.PRNGKey(1), (patch * patch * C, cfg.d_model), jnp.float32
    )
    return (x @ (k / jnp.sqrt(patch * patch * C))).astype(cfg.compute_dtype)
