"""Shared model building blocks + the parameter-template system.

Every parameter is declared as a ``PSpec`` (shape, logical axes, init kind).
The template tree drives three things with one source of truth:
  - ``init_params``     — RNG initialization,
  - ``logical_tree``    — logical-axis tree for the sharding resolver,
  - ``param_counts``    — exact N for roofline MODEL_FLOPS.
Logical axis names are mapped to mesh axes by ``launch/sharding.py``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig


@dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"  # 'normal' | 'zeros' | 'ones' | 'embed'
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def stack(spec: PSpec, n: int, axis_name: Optional[str] = None) -> PSpec:
    """Add a leading stacked-layers dim (for lax.scan over layers)."""
    return PSpec(
        (n,) + spec.shape, (axis_name,) + spec.logical, spec.init, spec.scale
    )


def stack_tree(tree, n: int):
    return jax.tree.map(
        lambda s: stack(s, n), tree, is_leaf=lambda x: isinstance(x, PSpec)
    )


def init_params(template, rng: jax.Array, dtype) -> Any:
    leaves, treedef = jax.tree.flatten(
        template, is_leaf=lambda x: isinstance(x, PSpec)
    )
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for spec, r in zip(leaves, rngs):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        elif spec.init == "const":
            out.append(jnp.full(spec.shape, spec.scale, dtype))
        else:
            if spec.init == "embed":
                std = spec.scale
            else:
                fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
                std = spec.scale / (fan_in ** 0.5)
            out.append(jax.random.normal(r, spec.shape, dtype) * std)
    return treedef.unflatten(out)


def abstract_params(template, dtype) -> Any:
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        template,
        is_leaf=lambda x: isinstance(x, PSpec),
    )


def logical_tree(template) -> Any:
    return jax.tree.map(
        lambda s: s.logical, template, is_leaf=lambda x: isinstance(x, PSpec)
    )


def count_template(template) -> int:
    leaves = jax.tree.leaves(template, is_leaf=lambda x: isinstance(x, PSpec))
    total = 0
    for s in leaves:
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total


# --------------------------------------------------------------------------
# norms / rope
# --------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-6
) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_template(cfg: ArchConfig, dim: Optional[int] = None) -> Dict[str, PSpec]:
    """Pre-norm parameter template honouring ``cfg.norm_type``."""
    d = cfg.d_model if dim is None else dim
    t = {"scale": PSpec((d,), ("embed",), init="ones")}
    if cfg.norm_type == "layernorm":
        t["bias"] = PSpec((d,), ("embed",), init="zeros")
    return t


def norm_apply(cfg: ArchConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm_type == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def sinusoidal_embed(positions: jnp.ndarray, dim: int) -> jnp.ndarray:
    """Classic transformer sin/cos position embedding. positions (B,S) -> (B,S,dim)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def rope_embed(positions: jnp.ndarray, head_dim: int, theta: float):
    """cos/sin tables for ``positions`` (any shape) -> (+ (hd/2,)) trailing."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) -> broadcast over heads."""
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# MLP variants
# --------------------------------------------------------------------------
def mlp_template(cfg: ArchConfig) -> Dict[str, PSpec]:
    D, F = cfg.d_model, cfg.d_ff
    t = {"wo": PSpec((F, D), ("mlp", "embed"))}
    t["wi"] = PSpec((D, F), ("embed", "mlp"))
    if cfg.mlp_type in ("swiglu", "geglu"):
        t["wg"] = PSpec((D, F), ("embed", "mlp"))
    return t


def mlp_apply(cfg: ArchConfig, p, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    elif cfg.mlp_type == "geglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        h = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * h
    elif cfg.mlp_type == "relu2":  # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    elif cfg.mlp_type == "gelu":  # starcoder2/musicgen non-gated GELU
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(cfg.mlp_type)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
