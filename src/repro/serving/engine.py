"""Batched serving engine: continuous batching over a fixed slot pool.

A ``ServeEngine`` owns the params, a slot-pooled KV cache and two jitted
programs (prefill, decode) built on the same model functions the dry-run
compiles.  Requests queue up; each engine step

  1. admits queued requests into free slots — a B=1 prefill fills a fresh
     cache which is scattered into the slot's cache lane,
  2. runs ONE batched decode step for all active slots (per-slot
     positions: the attention cache path takes a ``cache_pos`` vector, so
     sequences of different lengths share one compiled program —
     continuous batching),
  3. samples (greedy / temperature / top-k), appends, retires finished
     slots and immediately refills them from the queue.

Prompts prefill at exact length (one compile per distinct prompt length —
fine at engine scale; length-bucketing with masked tails is the production
extension for attention families, but is unsafe for recurrent families
where padding corrupts the integrated state).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models.model import Model, build_model


@dataclass
class EngineConfig:
    slots: int = 4
    max_seq: int = 512
    temperature: float = 0.0  # 0 = greedy
    top_k: int = 0
    eos_token: int = -1  # -1 = never stops early
    seed: int = 0


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32 tokens ((S, D) float embeds for stub archs)
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    t_submit: float = field(default_factory=time.time)
    t_first: Optional[float] = None
    t_done: Optional[float] = None


def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, ecfg: Optional[EngineConfig] = None):
        self.cfg = cfg
        self.ecfg = ecfg or EngineConfig()
        self.model: Model = build_model(cfg)
        self.params = params
        B, S = self.ecfg.slots, self.ecfg.max_seq
        self.cache = self.model.init_cache(B, S)
        self.slot_req: List[Optional[Request]] = [None] * B
        self.slot_pos = np.zeros(B, dtype=np.int32)  # next write index
        self.slot_tok = np.zeros(B, dtype=np.int32)  # last sampled token
        self.requests: List[Request] = []
        self.queue: List[Request] = []
        self._rng = jax.random.PRNGKey(self.ecfg.seed)
        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn, static_argnames=("pad_len",))
        self._scatter = jax.jit(self._scatter_fn, static_argnames=("slot",))
        self.decode_steps = 0

    # -- jitted programs --------------------------------------------------------
    def _prefill_fn(self, params, prompt_tokens, pad_len):
        """prompt_tokens (1, pad_len) -> (last real logits handled by caller)."""
        cache = self.model.init_cache(1, self.ecfg.max_seq)
        batch = (
            {"embeds": prompt_tokens}
            if self.cfg.frontend
            else {"tokens": prompt_tokens}
        )
        logits, cache = self.model.prefill(params, batch, cache)
        return logits, cache

    def _scatter_fn(self, pool, one, slot):
        # every cache leaf has layout (G, B, ...): batch lane is axis 1
        return jax.tree.map(lambda p, o: p.at[:, slot].set(o[:, 0]), pool, one)

    def _decode_fn(self, params, cache, tokens, pos, rng):
        """tokens (B,) int32; pos (B,) int32 -> (next (B,), new_cache)."""
        if self.cfg.frontend:
            # stub-frontend: map token id to its deterministic embedding
            emb = jax.random.normal(
                jax.random.PRNGKey(7), (self.cfg.vocab, self.cfg.d_model)
            ) / jnp.sqrt(float(self.cfg.d_model))
            batch = {"embeds": emb[tokens][:, None].astype(self.cfg.compute_dtype)}
        else:
            batch = {"tokens": tokens[:, None]}
        logits, new_cache = self.model.decode_step(params, cache, batch, pos)
        e = self.ecfg
        if e.temperature <= 0.0:
            nxt = jnp.argmax(logits, axis=-1)
        else:
            l = logits / e.temperature
            if e.top_k > 0:
                kth = jax.lax.top_k(l, e.top_k)[0][:, -1:]
                l = jnp.where(l < kth, -jnp.inf, l)
            nxt = jax.random.categorical(rng, l, axis=-1)
        return nxt.astype(jnp.int32), new_cache

    # -- API ---------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.requests.append(req)
        self.queue.append(req)

    def _sample_host(self, logits: jax.Array) -> int:
        e = self.ecfg
        if e.temperature <= 0.0:
            return int(jax.device_get(jnp.argmax(logits, axis=-1))[0])
        self._rng, k = jax.random.split(self._rng)
        return int(jax.device_get(jax.random.categorical(k, logits / e.temperature))[0])

    def _admit(self) -> None:
        for slot in range(self.ecfg.slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            S = len(req.prompt)
            assert S + req.max_new_tokens <= self.ecfg.max_seq, "prompt too long"
            toks = np.asarray(req.prompt, dtype=np.int32)[None]
            logits, one_cache = self._prefill(self.params, jnp.asarray(toks), pad_len=S)
            self.cache = self._scatter(self.cache, one_cache, slot=slot)
            tok = self._sample_host(logits)
            self.slot_req[slot] = req
            self.slot_pos[slot] = S
            self.slot_tok[slot] = tok
            req.out_tokens.append(tok)
            req.t_first = time.time()

    def step(self) -> int:
        """One engine iteration; returns number of active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        self._rng, k = jax.random.split(self._rng)
        nxt, self.cache = self._decode(
            self.params,
            self.cache,
            jnp.asarray(self.slot_tok),
            jnp.asarray(self.slot_pos),
            k,
        )
        nxt = np.asarray(jax.device_get(nxt))
        self.decode_steps += 1
        for i in active:
            req = self.slot_req[i]
            self.slot_pos[i] += 1
            tok = int(nxt[i])
            self.slot_tok[i] = tok
            req.out_tokens.append(tok)
            if (
                len(req.out_tokens) >= req.max_new_tokens
                or tok == self.ecfg.eos_token
            ):
                req.done = True
                req.t_done = time.time()
                self.slot_req[i] = None
                self.slot_pos[i] = 0
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) and (
            steps < max_steps
        ):
            self.step()
            steps += 1
        return [r for r in self.requests if r.done]
