from .engine import EngineConfig, Request, ServeEngine

__all__ = ["EngineConfig", "Request", "ServeEngine"]
