"""Logical-axis -> mesh-axis sharding resolver (divisibility-safe).

Every parameter/cache leaf carries *logical* axis names (PSpec.logical /
cache_logical).  A ``Rules`` table maps each logical name to an ordered
tuple of candidate mesh axes; the resolver walks a leaf's dims in order and
assigns each candidate axis iff (a) it exists in the mesh, (b) it is not
already used by an earlier dim of the same leaf, and (c) the dim is
divisible by the axis size.  Anything else falls back to replication —
placement NEVER fails, it only degrades (e.g. kv_heads=8 on a 16-way model
axis stays replicated while q heads shard).

Standard parallelism expressed through the tables:
  TP    heads/mlp/experts/vocab -> "model"
  FSDP  embed (d_model) dim of matrices -> "data" (+"pod" for >=100B)
  DP    batch -> ("pod", "data")
  SP    cache seq -> leftover axes (long-context: ("pod","data","model"))
  EP    experts -> "model" (the MoE shard_map path reads the same table)
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig

Axes = Tuple[str, ...]


@dataclass(frozen=True)
class Rules:
    table: Dict[Optional[str], Axes]
    # leaves with fewer dims than this stay replicated (norm vectors etc.)
    min_ndim: int = 2

    def lookup(self, name: Optional[str]) -> Axes:
        return self.table.get(name, ())


def train_rules(cfg: ArchConfig, big_model_fsdp_pod: bool = True) -> Rules:
    fsdp: Axes = ()
    if cfg.fsdp:
        # >=100B params need the pod axis in the FSDP group to fit HBM
        big = param_bytes_estimate(cfg) > 100e9 * 4
        fsdp = ("pod", "data") if (big and big_model_fsdp_pod) else ("data",)
    return Rules(
        table={
            "vocab": ("model",),
            "heads": ("model",),
            "kv_heads": ("model",),
            "mlp": ("model",),
            "experts": ("model",),
            "embed": fsdp,
            "batch": ("pod", "data"),
            "seq": (),
            "head_dim": (),
            "layers": (),
            "state": (),
            None: (),
        }
    )


def serve_rules(cfg: ArchConfig) -> Rules:
    """Decode/prefill: same weight layout; cache seq takes leftover axes."""
    base = train_rules(cfg)
    t = dict(base.table)
    t["batch"] = ("pod", "data")
    t["seq"] = ("pod", "data", "model")  # long-context cache sharding
    return Rules(table=t)


def param_bytes_estimate(cfg: ArchConfig) -> int:
    from ..models.model import param_counts

    return param_counts(cfg)["total"] * jax.dtypes.canonicalize_dtype(
        cfg.param_dtype
    ).itemsize


# --------------------------------------------------------------------------
# resolution
# --------------------------------------------------------------------------
def resolve_pspec(
    logical: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    mesh: Mesh,
    rules: Rules,
) -> P:
    if len(shape) < rules.min_ndim:
        return P()
    used = set()
    spec = []
    for dim, name in zip(shape, logical):
        chosen = []
        rem = dim
        for ax in rules.lookup(name):
            if ax in mesh.axis_names and ax not in used:
                sz = mesh.shape[ax]
                if rem % sz == 0 and rem >= sz:
                    chosen.append(ax)
                    used.add(ax)
                    rem //= sz
        spec.append(tuple(chosen) if len(chosen) > 1 else (chosen[0] if chosen else None))
    return P(*spec)


def _shape_of(leaf) -> Tuple[int, ...]:
    return tuple(leaf.shape)


def tree_pspecs(logical_tree: Any, shaped_tree: Any, mesh: Mesh, rules: Rules):
    """Map (logical, shaped) trees -> PartitionSpec tree."""
    return jax.tree.map(
        lambda lg, leaf: resolve_pspec(tuple(lg), _shape_of(leaf), mesh, rules),
        logical_tree,
        shaped_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


def tree_shardings(logical_tree: Any, shaped_tree: Any, mesh: Mesh, rules: Rules):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_pspecs(logical_tree, shaped_tree, mesh, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_pspec(mesh: Mesh, rules: Rules, ndim: int) -> P:
    """(B, S, ...) activations: batch dim over the DP axes."""
    axes = tuple(a for a in rules.lookup("batch") if a in mesh.axis_names)
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return P(lead, *([None] * (ndim - 1)))


def batch_sharding(mesh: Mesh, rules: Rules, batch_size: int, ndim: int):
    axes = tuple(a for a in rules.lookup("batch") if a in mesh.axis_names)
    sz = 1
    for a in axes:
        sz *= mesh.shape[a]
    if sz and batch_size % sz != 0:
        # drop axes from the right until divisible (e.g. batch=1 long-context)
        while axes and batch_size % _prod(mesh, axes) != 0:
            axes = axes[:-1]
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return NamedSharding(mesh, P(lead, *([None] * (ndim - 1))))


def _prod(mesh: Mesh, axes: Axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def data_parallel_degree(mesh: Mesh, rules: Rules, batch_size: int) -> int:
    axes = tuple(a for a in rules.lookup("batch") if a in mesh.axis_names)
    while axes and batch_size % _prod(mesh, axes) != 0:
        axes = axes[:-1]
    return _prod(mesh, axes)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
