"""Three-term roofline from the compiled dry-run artifact (assignment
§ROOFLINE ANALYSIS).

    compute term    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory term     = HLO_bytes / (chips * HBM_BW)
    collective term = collective_bytes_per_chip / ICI_BW

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes (already per-
partition under SPMD); collective bytes parsed from the post-SPMD HLO text
(shapes there are per-device).  Ring-collective convention: an all-gather
moves ~result_bytes per chip, an all-reduce ~2x operand bytes, a
reduce-scatter ~operand bytes, all-to-all/permute ~operand bytes; the
(n-1)/n factor is folded to 1.

MODEL_FLOPS (useful compute) comes from the exact parameter template:
6*N_active*tokens for training, 2*N_active*tokens for inference, plus the
sequence-mixing term per family (causal-aware).  The ratio
MODEL_FLOPS/HLO_FLOPs exposes remat/recompute/full-causal waste.
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..configs.base import ArchConfig, ShapeConfig

# ---- TPU v5e hardware constants (assignment-provided) ---------------------
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link (conservative single-link figure)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# result-type chunks like  bf16[8,128,2048]{2,1,0}  or  f32[] .
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(?P<res>[^=]*?)\s*(?P<op>"
    + "|".join(_COLLECTIVES)
    + r")(?P<start>-start)?\s*\("
)


def _bytes_of_result(res: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(res):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-kind {count, bytes} from post-SPMD HLO (per-device shapes)."""
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES
    }
    for m in _LINE_RE.finditer(hlo_text):
        op = m.group("op")
        b = _bytes_of_result(m.group("res"))
        if op == "all-reduce":
            b *= 2  # ring: reduce-scatter + all-gather phases
        out[op]["count"] += 1
        out[op]["bytes"] += b
    return out


def collective_bytes(coll: Dict[str, Dict[str, float]]) -> float:
    return sum(v["bytes"] for v in coll.values())


# --------------------------------------------------------------------------
# analytic useful-FLOPs model (exact N from the template)
# --------------------------------------------------------------------------
def seq_mix_flops(cfg: ArchConfig, batch: int, seq: int, kind: str) -> float:
    """Sequence-mixing FLOPs beyond the 6N/2N weight term (causal-aware)."""
    B, S = batch, seq

    def attn(n_layers: int, cache_len: Optional[int] = None) -> float:
        H, hd = cfg.n_heads, cfg.hd
        if kind == "decode":
            L = cache_len if cache_len is not None else S
            return 4.0 * B * L * H * hd * n_layers  # q.K + p.V, one token
        # train/prefill: causal = half the full square
        f = 2.0 * B * S * S * H * hd * n_layers
        return f * (3.0 if kind == "train" else 1.0)  # bwd ~ 2x fwd

    if cfg.family == "rwkv":
        D = cfg.d_model
        H = D // cfg.rwkv_head_size
        K = cfg.rwkv_head_size
        Q = cfg.rwkv_chunk
        T = B * (1 if kind == "decode" else S)
        f = 2.0 * T * H * K * (2 * K + 2 * Q) * cfg.n_layers
        return f * (3.0 if kind == "train" else 1.0)
    if cfg.family == "hybrid":
        D = cfg.d_model
        H, P, N, Q = cfg.ssm_heads, (cfg.ssm_expand * cfg.d_model) // cfg.ssm_heads, cfg.ssm_state, cfg.ssm_chunk
        T = B * (1 if kind == "decode" else S)
        ssd = 2.0 * T * H * (2 * N * P + Q * (N + P)) * cfg.n_layers
        ssd *= 3.0 if kind == "train" else 1.0
        n_shared = cfg.n_layers // max(cfg.hybrid_attn_every, 1)
        return ssd + attn(n_shared, cache_len=S)
    if cfg.local_per_global > 0:
        g = cfg.local_per_global + 1
        n_glob = cfg.n_layers // g
        n_loc = cfg.n_layers - n_glob
        W = cfg.local_window
        H, hd = cfg.n_heads, cfg.hd
        if kind == "decode":
            loc = 4.0 * B * min(W, S) * H * hd * n_loc
        else:
            loc = 4.0 * B * S * min(W, S) * H * hd * n_loc * (
                3.0 if kind == "train" else 1.0
            )
        return attn(n_glob, cache_len=S) + loc
    return attn(cfg.n_layers, cache_len=S)


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    from ..models.model import param_counts

    c = param_counts(cfg)
    N = c["active_nonembed"]
    if shape.kind == "train":
        T = shape.global_batch * shape.seq_len
        return 6.0 * N * T + seq_mix_flops(cfg, shape.global_batch, shape.seq_len, "train")
    if shape.kind == "prefill":
        T = shape.global_batch * shape.seq_len
        return 2.0 * N * T + seq_mix_flops(cfg, shape.global_batch, shape.seq_len, "prefill")
    # decode: one token per sequence against a cache of seq_len
    T = shape.global_batch
    return 2.0 * N * T + seq_mix_flops(cfg, shape.global_batch, shape.seq_len, "decode")


# --------------------------------------------------------------------------
# report
# --------------------------------------------------------------------------
@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # per chip (cost_analysis is per-partition)
    hlo_bytes: float
    coll_bytes: float
    collectives: Dict[str, Dict[str, float]]
    model_flops_total: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0  # MODEL_FLOPS / (chips * HLO_FLOPs)
    mfu_bound: float = 0.0  # MODEL_FLOPS / (chips * PEAK * max term)
    memory_per_chip: Optional[float] = None
    notes: str = ""
    # raw XLA cost_analysis (loop bodies counted once — reference only)
    xla_cost_flops: float = 0.0
    xla_cost_bytes: float = 0.0

    def finalize(self) -> "Roofline":
        self.compute_s = self.hlo_flops / PEAK_FLOPS
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.coll_bytes / ICI_BW
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.bottleneck = max(terms, key=terms.get)
        denom = self.chips * self.hlo_flops
        self.useful_ratio = self.model_flops_total / denom if denom else 0.0
        t = max(self.compute_s, self.memory_s, self.collective_s)
        self.mfu_bound = (
            self.model_flops_total / (self.chips * PEAK_FLOPS * t) if t else 0.0
        )
        return self

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)


def analyze(
    cfg: ArchConfig,
    shape: ShapeConfig,
    mesh_name: str,
    chips: int,
    cost: Dict[str, Any],
    hlo_text: str,
    memory_stats: Optional[Dict[str, float]] = None,
    notes: str = "",
) -> Roofline:
    """Three-term roofline from the compiled HLO (loop-aware; see hlo_cost)."""
    from .hlo_cost import analyze_hlo

    hc = analyze_hlo(hlo_text)
    if hc.notes:
        notes = (notes + "; " + hc.notes).strip("; ")
    r = Roofline(
        arch=cfg.name,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=hc.flops,
        hlo_bytes=hc.traffic,
        coll_bytes=hc.coll_bytes,
        collectives=hc.coll_dict(),
        model_flops_total=model_flops(cfg, shape),
        memory_per_chip=(memory_stats or {}).get("total"),
        notes=notes,
    )
    r.xla_cost_flops = float(cost.get("flops", 0.0))
    r.xla_cost_bytes = float(cost.get("bytes accessed", 0.0))
    return r.finalize()
