"""Step builders: jit-able train/prefill/decode steps with full shardings.

Each builder returns a ``StepPlan``: the pure function, ShapeDtypeStruct
argument trees (dry-run: no allocation) and the matching NamedSharding
trees for ``jax.jit(fn, in_shardings=..., out_shardings=...)``.  The same
plan drives the real trainer/server (with materialized arrays) and the
multi-pod dry-run (with abstract inputs) — one source of truth.

The UTP connection (paper §2.1): a step IS the root task of a task tree —
``TrainStepOp.split() -> [microbatch fwd/bwd]* -> grad-reduce -> optimizer
update``.  On TPU the dispatcher's optimal plan is maximal fusion, so the
tree lowers to the single jit program built here; the ``train/step_ops.py``
module exposes the same step through the explicit UTP task interface.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import optim
from ..configs.base import ArchConfig, ShapeConfig
from ..models.model import Model, build_model
from ..models.moe import MoeCtx
from ..models.transformer import cache_logical, cache_specs
from . import sharding as sh


@dataclass
class StepPlan:
    name: str
    fn: Callable
    args: Tuple[Any, ...]  # ShapeDtypeStruct trees (positional)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    static_meta: Optional[Dict[str, Any]] = None

    def jitted(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        return self.jitted().lower(*self.args)


# --------------------------------------------------------------------------
# batch specs
# --------------------------------------------------------------------------
def batch_specs(
    cfg: ArchConfig,
    batch: int,
    seq: int,
    mesh: Mesh,
    rules: sh.Rules,
    with_labels: bool,
):
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    shards: Dict[str, NamedSharding] = {}
    if cfg.frontend:
        specs["embeds"] = jax.ShapeDtypeStruct(
            (batch, seq, cfg.d_model), cfg.compute_dtype
        )
        shards["embeds"] = sh.batch_sharding(mesh, rules, batch, 3)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        shards["tokens"] = sh.batch_sharding(mesh, rules, batch, 2)
    if with_labels:
        specs["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        shards["labels"] = sh.batch_sharding(mesh, rules, batch, 2)
    return specs, shards


def _group_param_constraint(cfg: ArchConfig, mesh: Mesh, rules: sh.Rules):
    """Pin a scanned group's param slices to their stored sharding.

    The slice drops the leading 'layers' dim from the stacked templates, so
    resolve each leaf's spec from its remaining logical axes.  Anchoring
    the forward slices makes Shardy produce already-sharded weight-grad
    cotangents (reduce-scatter per group instead of fp32 all-reduce)."""
    from ..models.model import model_template
    from ..models.layers import PSpec, logical_tree
    from ..models.transformer import group_template

    t = group_template(cfg)
    logical = logical_tree(t)
    shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
        t, is_leaf=lambda x: isinstance(x, PSpec),
    )
    specs = sh.tree_pspecs(logical, shapes, mesh, rules)

    def constrain(p_g):
        return jax.tree.map(
            lambda x, spec: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec)
            ),
            p_g,
            specs,
            is_leaf=lambda x: isinstance(x, P),
        )

    return constrain


def moe_ctx_for(cfg: ArchConfig, mesh: Mesh, rules: sh.Rules) -> Optional[MoeCtx]:
    """Parallel context — needed by every arch (activation anchoring), and
    by MoE archs additionally for the shard_map EP dispatch."""
    if mesh is None:
        return None
    return MoeCtx(
        mesh=mesh,
        batch_axes=tuple(a for a in rules.lookup("batch") if a in mesh.axis_names),
        model_axis="model" if "model" in mesh.axis_names else None,
        fsdp_axes=tuple(a for a in rules.lookup("embed") if a in mesh.axis_names),
        seq_axis=(
            "model"
            if cfg.seq_parallel and "model" in mesh.axis_names
            else None
        ),
        group_param_constraint=(
            _group_param_constraint(cfg, mesh, rules) if cfg.anchor_params else None
        ),
    )


# --------------------------------------------------------------------------
# train step
# --------------------------------------------------------------------------
def make_train_step(
    cfg: ArchConfig,
    mesh: Mesh,
    shape: ShapeConfig,
    opt_cfg: Optional[optim.AdamWConfig] = None,
    rules: Optional[sh.Rules] = None,
) -> StepPlan:
    model = build_model(cfg)
    rules = rules or sh.train_rules(cfg)
    opt_cfg = opt_cfg or optim.AdamWConfig(state_dtype=cfg.optim_state_dtype)
    mctx = moe_ctx_for(cfg, mesh, rules)
    m = cfg.microbatches

    # p_shard is needed by loss_of's anchored cast; resolve it up front
    p_specs_early = model.abstract()
    p_shard_early = sh.tree_shardings(model.logical, p_specs_early, mesh, rules)

    def loss_of(params, batch):
        from ..models.model import cast_for_forward

        if cfg.cast_params and cfg.anchor_cast:
            # cast to compute dtype AND pin the bf16 copy to the stored
            # sharding, so FSDP all-gathers move bf16 (the partitioner
            # otherwise may commute to gather-f32-then-convert)
            casted = cast_for_forward(cfg, params)
            params = jax.tree.map(
                lambda p, s: jax.lax.with_sharding_constraint(p, s),
                casted, p_shard_early,
            )
        return model.loss(params, batch, moe_ctx=mctx)

    def train_step(params, opt_state, batch):
        if m > 1:
            def micro(carry, mb):
                g_acc, loss_acc = carry
                (loss, metrics), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params, mb
                )
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, loss_acc + loss), metrics

            mb = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch
            )
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), metrics_seq = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32)), mb
            )
            grads = jax.tree.map(lambda g: g / m, grads)
            metrics = jax.tree.map(lambda x: x.mean(), metrics_seq)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, batch
            )
        new_params, new_opt, om = optim.update(grads, opt_state, params, opt_cfg)
        return new_params, new_opt, {**metrics, **om}

    # specs + shardings
    p_specs = model.abstract()
    p_shard = sh.tree_shardings(model.logical, p_specs, mesh, rules)
    o_specs = {
        "m": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, opt_cfg.state_dtype), p_specs
        ),
        "v": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, opt_cfg.state_dtype), p_specs
        ),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }
    o_shard = {
        "m": p_shard,
        "v": p_shard,
        "count": sh.replicated(mesh),
    }
    b_specs, b_shard = batch_specs(
        cfg, shape.global_batch, shape.seq_len, mesh, rules, with_labels=True
    )
    metrics_shard = None  # let jit infer (all replicated scalars)
    return StepPlan(
        name="train_step",
        fn=train_step,
        args=(p_specs, o_specs, b_specs),
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, metrics_shard),
        donate_argnums=(0, 1),
        static_meta={"kind": "train"},
    )


# --------------------------------------------------------------------------
# serve steps
# --------------------------------------------------------------------------
def _cache_trees(cfg: ArchConfig, batch: int, max_seq: int, mesh, rules):
    c_specs = cache_specs(cfg, batch, max_seq)
    c_logical = cache_logical(cfg)
    c_shard = sh.tree_shardings(c_logical, c_specs, mesh, rules)
    return c_specs, c_shard


def _serve_param_specs(model: Model, cfg: ArchConfig):
    """Serving stores weights in the compute dtype (bf16) — no fp32 masters
    at inference.  Matches ``cast_for_forward``'s rule so the in-step cast
    is a no-op: >=2D float leaves in compute dtype, the rest unchanged."""
    import numpy as np

    cd = cfg.compute_dtype

    def spec(s: jax.ShapeDtypeStruct):
        if np.issubdtype(s.dtype, np.floating) and len(s.shape) >= 2:
            return jax.ShapeDtypeStruct(s.shape, cd)
        return s

    return jax.tree.map(spec, model.abstract())


def make_prefill_step(
    cfg: ArchConfig,
    mesh: Mesh,
    shape: ShapeConfig,
    rules: Optional[sh.Rules] = None,
) -> StepPlan:
    model = build_model(cfg)
    rules = rules or sh.serve_rules(cfg)
    mctx = moe_ctx_for(cfg, mesh, rules)

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache, moe_ctx=mctx)

    p_specs = _serve_param_specs(model, cfg)
    p_shard = sh.tree_shardings(model.logical, p_specs, mesh, rules)
    b_specs, b_shard = batch_specs(
        cfg, shape.global_batch, shape.seq_len, mesh, rules, with_labels=False
    )
    c_specs, c_shard = _cache_trees(
        cfg, shape.global_batch, shape.seq_len, mesh, rules
    )
    logits_shard = sh.batch_sharding(mesh, rules, shape.global_batch, 2)
    return StepPlan(
        name="prefill_step",
        fn=prefill_step,
        args=(p_specs, b_specs, c_specs),
        in_shardings=(p_shard, b_shard, c_shard),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(2,),
        static_meta={"kind": "prefill"},
    )


def make_decode_step(
    cfg: ArchConfig,
    mesh: Mesh,
    shape: ShapeConfig,
    rules: Optional[sh.Rules] = None,
) -> StepPlan:
    """One new token against a KV cache of ``shape.seq_len``."""
    model = build_model(cfg)
    rules = rules or sh.serve_rules(cfg)
    mctx = moe_ctx_for(cfg, mesh, rules)

    def decode_step(params, cache, batch, pos):
        return model.decode_step(params, cache, batch, pos, moe_ctx=mctx)

    p_specs = _serve_param_specs(model, cfg)
    p_shard = sh.tree_shardings(model.logical, p_specs, mesh, rules)
    b_specs, b_shard = batch_specs(
        cfg, shape.global_batch, 1, mesh, rules, with_labels=False
    )
    c_specs, c_shard = _cache_trees(
        cfg, shape.global_batch, shape.seq_len, mesh, rules
    )
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    logits_shard = sh.batch_sharding(mesh, rules, shape.global_batch, 2)
    return StepPlan(
        name="decode_step",
        fn=decode_step,
        args=(p_specs, c_specs, b_specs, pos_spec),
        in_shardings=(p_shard, c_shard, b_shard, sh.replicated(mesh)),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(1,),
        static_meta={"kind": "decode"},
    )


def make_step(cfg: ArchConfig, mesh: Mesh, shape: ShapeConfig, **kw) -> StepPlan:
    if shape.kind == "train":
        return make_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, shape, **kw)
    return make_decode_step(cfg, mesh, shape, **kw)
