"""Loop-aware cost analysis over compiled HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE — a
layer-scanned LM under-reports FLOPs/bytes/collectives by ~n_layers x.
This module re-derives the three roofline inputs from ``compiled.as_text()``
with call-graph multipliers:

  * computations are parsed into blocks with a per-block symbol table
    (op name -> shape); ``while`` ops multiply their body by the trip count
    (``known_trip_count`` backend config when present, else the max integer
    constant in the condition computation — the ``lax.scan`` ``i < N``
    pattern);
  * FLOPs = 2 * prod(result dims) * prod(lhs contracted dims), summed over
    every ``dot`` (the MXU ops; elementwise flops are bandwidth-bound
    noise);
  * HBM traffic = operand+result bytes of every top-level op (fusion
    internals excluded — a fusion's boundary IS its HBM traffic, the
    HloCostAnalysis convention);
  * collective bytes = result bytes per collective op (all-reduce x2 for
    the ring reduce+broadcast phases), multiplied up the call graph.

Shapes in post-SPMD HLO are per-partition, so every figure is per-chip.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w\.\-]+)")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
_SKIP_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "after-all", "iota", "partition-id", "replica-id", "while",
}
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_ATTR_COMP = re.compile(r"(condition|body|to_apply|calls)=\s*%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_OPNAME_RE = re.compile(r"^\(?[\sa-z0-9_\[\],\{\}/]*?\)?\s*([a-z][a-z0-9\-]*)\(")
_DOT_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shapes_of(typestr: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    op: str
    shapes: List[Tuple[str, List[int]]]  # result shapes
    operands: List[str]
    line: str
    is_root: bool = False


@dataclass
class Block:
    name: str
    is_entry: bool = False
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, List[Tuple[str, List[int]]]] = field(default_factory=dict)
    max_int_const: int = 1
    root: Optional[Op] = None


def _parse_blocks(text: str) -> Dict[str, Block]:
    blocks: Dict[str, Block] = {}
    cur: Optional[Block] = None
    comment = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment.sub("", raw).rstrip()
        if not line:
            continue
        if not line.startswith(" "):
            # computation headers sit at column 0: `[ENTRY ]%name (...) -> ...{`
            m = _HEADER_RE.match(line)
            if m and ("(" in line):
                cur = Block(name=m.group(2), is_entry=bool(m.group(1)))
                blocks[cur.name] = cur
            continue
        if cur is None:
            continue
        md = _DEF_RE.match(line)
        if not md:
            continue
        name, rhs = md.group(1), md.group(2)
        rhs_main = rhs.split(", metadata=")[0]
        mo = _OPNAME_RE.match(rhs_main)
        op = mo.group(1) if mo else ""
        # result type = text before the op name token
        res_str = rhs_main if not mo else rhs_main[: mo.start(1)]
        res_shapes = _shapes_of(res_str)
        # operands: names inside the first (...) after the op name
        operands: List[str] = []
        if mo:
            after = rhs_main[mo.end(1):]
            depth = 0
            arg = []
            for ch in after:
                if ch == "(":
                    depth += 1
                    if depth == 1:
                        continue
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                if depth >= 1:
                    arg.append(ch)
            operands = _OPND_RE.findall("".join(arg))
        o = Op(name=name, op=op, shapes=res_shapes, operands=operands,
               line=rhs_main + rhs[len(rhs_main):][:512],
               is_root=line.lstrip().startswith("ROOT"))
        cur.ops.append(o)
        if o.is_root:
            cur.root = o
        cur.symbols[name] = res_shapes
        if op == "constant":
            for m in _CONST_INT.finditer(rhs_main):
                cur.max_int_const = max(cur.max_int_const, int(m.group(1)))
    return blocks


@dataclass
class HloCost:
    flops: float = 0.0
    traffic: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)
    n_while: int = 0
    notes: str = ""
    loops: List[Tuple[str, float, float]] = field(default_factory=list)  # (body, trip, mult)

    @property
    def coll_bytes(self) -> float:
        return sum(v["bytes"] for v in self.collectives.values())

    def coll_dict(self) -> Dict[str, Dict[str, float]]:
        return {k: dict(v) for k, v in self.collectives.items() if v["count"]}


def analyze_hlo(text: str) -> HloCost:
    return _walk(_parse_blocks(text))


_PARAM_IDX = re.compile(r"parameter\((\d+)\)")


def _fusion_traffic(o: Op, b: Block, blocks: Dict[str, Block]) -> float:
    """Boundary traffic of a fusion.

    Two aliasing patterns matter for honesty:
      * root dynamic-update-slice: the fusion updates a loop carry in
        place — traffic is the update slice, not the whole buffer;
      * an operand whose ONLY use inside the body is dynamic-slice
        (lax.scan slicing the stacked params each iteration) — traffic is
        the slice, not the stacked array.
    """
    attrs = dict(_ATTR_COMP.findall(o.line))
    cb = blocks.get(attrs.get("calls", ""))
    if cb is None:
        tb = _bytes_of(o.shapes)
        for name in o.operands:
            tb += _bytes_of(b.symbols.get(name, []))
        return float(tb)

    # map parameter index -> parameter op name + its uses
    param_name: Dict[int, str] = {}
    uses: Dict[str, List[Op]] = {}
    for op2 in cb.ops:
        if op2.op == "parameter":
            m = _PARAM_IDX.search(op2.line)
            if m:
                param_name[int(m.group(1))] = op2.name
        for nm in op2.operands:
            uses.setdefault(nm, []).append(op2)

    total = 0.0
    # result side
    root = cb.root
    if root is not None and root.op == "dynamic-update-slice":
        upd = root.operands[1] if len(root.operands) > 1 else ""
        total += 2.0 * _bytes_of(cb.symbols.get(upd, []))
    else:
        total += _bytes_of(o.shapes)
    # operand side
    for i, name in enumerate(o.operands):
        full = _bytes_of(b.symbols.get(name, []))
        pname = param_name.get(i)
        pu = uses.get(pname, []) if pname else []
        if pu and all(u.op == "dynamic-slice" for u in pu):
            total += sum(_bytes_of(u.shapes) for u in pu)
        elif root is not None and root.op == "dynamic-update-slice" and i == 0:
            pass  # aliased carry operand already counted via the slice
        else:
            total += full
    return total


def _block_cost(b: Block, fusion_body: bool, blocks: Dict[str, Block]):
    """Returns (flops, traffic, coll, calls) for one pass of this block."""
    flops = 0.0
    traffic = 0.0
    coll: Dict[str, List[float]] = {}
    calls: List[Tuple[str, float]] = []
    for o in b.ops:
        if o.op == "dot":
            if o.shapes:
                n = 1
                for d in o.shapes[0][1]:
                    n *= d
                contract = 1
                mc = _DOT_CONTRACT.search(o.line)
                lhs = b.symbols.get(o.operands[0] if o.operands else "", [])
                if mc and lhs:
                    dims = lhs[0][1]
                    for i in [int(x) for x in mc.group(1).split(",") if x]:
                        if i < len(dims):
                            contract *= dims[i]
                flops += 2.0 * n * contract
        if o.op == "while":
            attrs = dict(_ATTR_COMP.findall(o.line))
            mt = _TRIP_RE.search(o.line)
            trip = int(mt.group(1)) if mt else -1
            calls.append(
                ("__while__:%s:%s" % (attrs.get("body", ""), attrs.get("condition", "")),
                 trip)
            )
            continue
        if o.op == "fusion":
            attrs = dict(_ATTR_COMP.findall(o.line))
            if "calls" in attrs:
                calls.append(("__fusion__:" + attrs["calls"], 1))
            if not fusion_body:
                traffic += _fusion_traffic(o, b, blocks)
            continue
        elif o.op in ("call", "custom-call", "map"):
            attrs = dict(_ATTR_COMP.findall(o.line))
            if "to_apply" in attrs:
                calls.append((attrs["to_apply"], 1))
        elif o.op == "conditional":
            mb = _BRANCHES.search(o.line)
            if mb:
                for name in mb.group(1).split(","):
                    calls.append((name.strip().lstrip("%"), 1))
        elif o.op in ("reduce", "reduce-window", "scatter", "sort",
                      "select-and-scatter"):
            attrs = dict(_ATTR_COMP.findall(o.line))
            if "to_apply" in attrs:
                calls.append(("__applied__:" + attrs["to_apply"], 1))
        is_coll = False
        for cname in _COLLECTIVES:
            if o.op == cname or o.op == cname + "-start":
                res_bytes = _bytes_of(o.shapes)
                bts = float(res_bytes) * (2.0 if cname == "all-reduce" else 1.0)
                c = coll.setdefault(cname, [0, 0.0])
                c[0] += 1
                c[1] += bts
                is_coll = True
                break
        if fusion_body:
            continue  # traffic counted at the fusion boundary
        if o.op in _SKIP_TRAFFIC and not is_coll:
            continue
        if o.op == "dynamic-update-slice":
            # in-place on the loop carry: real traffic = the update slice
            upd = o.operands[1] if len(o.operands) > 1 else ""
            traffic += 2 * _bytes_of(b.symbols.get(upd, []))
            continue
        if o.op == "dynamic-slice":
            traffic += 2 * _bytes_of(o.shapes)
            continue
        tb = _bytes_of(o.shapes)
        for name in o.operands:
            tb += _bytes_of(b.symbols.get(name, []))
        traffic += tb
    return flops, traffic, coll, calls


def _walk(blocks: Dict[str, Block]) -> HloCost:
    out = HloCost()
    entry = next((b for b in blocks.values() if b.is_entry), None)
    if entry is None:
        out.notes = "no ENTRY computation found"
        return out

    fusion_bodies = set()
    # pre-scan for fusion body names
    for b in blocks.values():
        for o in b.ops:
            if o.op == "fusion":
                attrs = dict(_ATTR_COMP.findall(o.line))
                if "calls" in attrs:
                    fusion_bodies.add(attrs["calls"])

    stack = set()

    def visit(name: str, mult: float) -> None:
        b = blocks.get(name)
        if b is None or name in stack:
            return
        stack.add(name)
        flops, traffic, coll, calls = _block_cost(b, name in fusion_bodies, blocks)
        out.flops += flops * mult
        out.traffic += traffic * mult
        for k, (cnt, bts) in coll.items():
            c = out.collectives.setdefault(k, {"count": 0, "bytes": 0.0})
            c["count"] += cnt * mult
            c["bytes"] += bts * mult
        for callee, trip in calls:
            if callee.startswith("__while__:"):
                _, body, cond = callee.split(":")
                t = trip
                if t == -1:
                    t = blocks[cond].max_int_const if cond in blocks else 1
                out.n_while += 1
                out.loops.append((body, float(t), mult))
                visit(body, mult * max(t, 1))
            elif callee.startswith("__fusion__:"):
                visit(callee.split(":", 1)[1], mult)
            elif callee.startswith("__applied__:"):
                pass
            else:
                visit(callee, mult)
        stack.discard(name)

    visit(entry.name, 1.0)
    return out
