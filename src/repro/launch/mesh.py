"""Production mesh construction (assignment §MULTI-POD DRY-RUN).

``make_production_mesh`` is a FUNCTION (never a module constant) so that
importing this module touches no jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import and only then calls it.

Single pod  : (16, 16)      axes ("data", "model")   = 256 chips (v5e pod)
Multi pod   : (2, 16, 16)   axes ("pod", "data", "model") = 512 chips;
              the "pod" axis is the DCN/ICI-cross-pod data-parallel axis.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(
    model: Optional[int] = None, data: Optional[int] = None
) -> Mesh:
    """Mesh over whatever devices exist (tests, examples, benchmarks)."""
    n = jax.device_count()
    if model is None:
        model = 1
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)
