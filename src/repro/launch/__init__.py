"""Launch layer: production mesh, sharding resolver, step builders, dry-run."""
