import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run (assignment §MULTI-POD DRY-RUN).

For every supported (architecture x input-shape) cell, lower + compile the
step program for the production mesh — (16,16)=256 chips single-pod and
(2,16,16)=512 chips multi-pod — with ShapeDtypeStruct inputs (no
allocation), then extract:

    compiled.memory_analysis()   -> fits-in-HBM proof
    compiled.cost_analysis()     -> FLOPs / bytes for §Roofline
    compiled.as_text()           -> collective bytes (parsed)

Results land in benchmarks/results/<mesh>/<arch>__<shape>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs import ARCHS, SHAPES, cell_supported, get_arch, get_shape
from . import roofline as rl
from .mesh import make_production_mesh
from .steps import make_step

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results"


def memory_stats(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None, "memory_analysis unavailable"
    if ma is None:
        return None, "memory_analysis None"
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out or None, ""


def _parse_overrides(pairs):
    """['score_dtype=bf16', 'microbatches=8'] -> dict with typed values."""
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("true", "True"):
            v = True
        if v in ("false", "False"):
            v = False
        out[k] = v
    return out


def run_cell(arch: str, shape: str, mesh_name: str, save_hlo: bool = False,
             rules_variant: str = "default", tag: str = "",
             overrides=None):
    import dataclasses

    cfg = get_arch(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    sh = get_shape(shape)
    if not cell_supported(cfg, sh):
        print(f"SKIP {arch} x {shape}: needs sub-quadratic attention")
        return None
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = mesh.size
    t0 = time.time()
    plan = make_step(cfg, mesh, sh)
    lowered = plan.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = dict(compiled.cost_analysis() or {})
    mem, mem_note = memory_stats(compiled)
    hlo = compiled.as_text()
    r = rl.analyze(
        cfg, sh, mesh_name, chips, cost, hlo,
        memory_stats=mem, notes=mem_note,
    )
    rec = json.loads(r.to_json())
    rec.update(
        step=plan.name,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        hlo_bytes_text=len(hlo),
        memory=mem,
        rules_variant=rules_variant,
        overrides={k: str(v) for k, v in (overrides or {}).items()},
        tag=tag,
    )
    outdir = RESULTS / mesh_name
    outdir.mkdir(parents=True, exist_ok=True)
    stem = f"{arch}__{shape}" + (f"__{tag}" if tag else "")
    (outdir / f"{stem}.json").write_text(json.dumps(rec, indent=1))
    if save_hlo:
        (outdir / f"{stem}.hlo.txt").write_text(hlo)
    print(
        f"OK {mesh_name} {arch} x {shape}: compile={t_compile:.0f}s "
        f"compute={r.compute_s*1e3:.2f}ms memory={r.memory_s*1e3:.2f}ms "
        f"coll={r.collective_s*1e3:.2f}ms bottleneck={r.bottleneck} "
        f"useful={r.useful_ratio:.2f} mfu_bound={r.mfu_bound:.3f}"
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument(
        "--override", action="append", default=[],
        help="cfg field override, e.g. --override score_dtype=bf16",
    )
    args = ap.parse_args()
    overrides = _parse_overrides(args.override)

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failed = []
    for mesh_name in meshes:
        for a, s in cells:
            try:
                run_cell(a, s, mesh_name, save_hlo=args.save_hlo, tag=args.tag,
                         overrides=overrides)
            except Exception as e:  # noqa: BLE001 — report, keep sweeping
                failed.append((mesh_name, a, s, repr(e)))
                print(f"FAIL {mesh_name} {a} x {s}: {e}")
                traceback.print_exc()
    if failed:
        raise SystemExit(f"{len(failed)} cells failed: {failed}")


if __name__ == "__main__":
    main()
