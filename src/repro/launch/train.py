"""Production training entry point.

On a real TPU fleet each host runs:

    python -m repro.launch.train --arch qwen3-32b --shape train_4k \
        --multi-pod --steps 10000 --ckpt-dir gs://...

and `jax.distributed.initialize()` wires the hosts into the 256/512-chip
mesh from launch/mesh.py.  On this CPU harness the same entry runs the
reduced config on the local device mesh — the code path (StepPlan ->
Trainer -> checkpoints) is identical to what the dry-run compiles.
"""

from __future__ import annotations

import argparse

import jax

from .. import optim
from ..configs import get_arch, get_shape
from ..configs.base import ShapeConfig
from ..train import Trainer, TrainerConfig
from .mesh import make_local_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the (16,16)/(2,16,16) mesh (needs the chips)")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config + small shape (CPU harness)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() first")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_arch(args.arch)
    shape = get_shape(args.shape)
    if args.reduced or not args.production_mesh:
        cfg = cfg.reduced()
        shape = ShapeConfig("reduced_train", seq_len=128, global_batch=8,
                            kind="train")
        mesh = make_local_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    trainer = Trainer(
        cfg, shape, mesh,
        TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir),
        opt_cfg=optim.AdamWConfig(
            lr=optim.warmup_cosine(3e-4, warmup=min(100, args.steps // 10 + 1),
                                   total=args.steps),
            state_dtype=cfg.optim_state_dtype,
        ),
    )
    out = trainer.train()
    print(f"finished at step {out['step']}; stragglers={out['stragglers']} "
          f"failures={out['failures']}")


if __name__ == "__main__":
    main()
