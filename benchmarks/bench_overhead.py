"""Paper §3 parity claim: UTP adds no material overhead.

Measures (a) pure dispatcher cost — submit+split+version+schedule per task
with execution stubbed out — and (b) end-to-end wave-batched execution vs
a hand-written blocked-cholesky jnp loop (no task layer at all), plus the
executor launch/compile counters that witness whole-schedule compilation
(one compiled WaveProgram per repeated schedule; DESIGN.md §2/§5) and the
fused-group counters that witness the dependency-exact scheduling pass
(``lu_groups_before`` / ``lu_groups_after_fusion`` on the multi-root LU
drain; single-root LU sits at its chain lower bound and must record
groups == groups_prefusion), plus the composed ``lu_solve`` drain
(DESIGN.md §4: one WaveProgram for factor+solve; here fusion MUST strictly
reduce the group count, and the fused drain is timed against the same
pipeline as three barrier-separated drains).

Also measures the static-verification cost pair (DESIGN.md §11): cold
drains (memo cleared) with/without ``verify``, and hot memo replays where
the verifier is skipped by construction — CI gates that verify-off drains
record zero verification counters and verify-on replays stay pure replay.

Emits ``BENCH_overhead.json`` (machine-readable; tracked PR-over-PR).
``--smoke`` runs a fast, small-size variant for CI's compile-counter
regression gate and writes ``BENCH_overhead.smoke.json`` instead.
"""

from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Dispatcher, GData, GTask, dd_matrix, spd_matrix
from repro.core.executors import clear_compile_cache
from repro.core.executors.base import Executor
from repro.linalg import run_cholesky, run_lu, run_lu_many, run_lu_solve
from repro.linalg.cholesky import utp_cholesky
from repro.linalg.lu import utp_getrf, utp_lu_solve, utp_solve
from repro.linalg.ops import POTRF
from repro.kernels import ref as kref

from .common import row, timeit_pair

JSON_PATH = "BENCH_overhead.json"
SMOKE_JSON_PATH = "BENCH_overhead.smoke.json"


class NullExecutor(Executor):
    name = "null"

    def execute_wave(self, wave):
        for t in wave:
            self._finished(t)
        return len(wave)


def dispatcher_only_cost(n_blocks: int) -> float:
    d = Dispatcher(graph="g2")
    d.executor = NullExecutor(on_task_finished=d._on_finished)
    a = GData((64 * n_blocks, 64 * n_blocks), partitions=((n_blocks, n_blocks),))
    t0 = time.perf_counter()
    d.submit_task(GTask(POTRF, None, [a.root_view()]))
    n = d.run()
    dt = time.perf_counter() - t0
    return dt / max(n, 1)


def hand_written_blocked(a: jnp.ndarray, p: int) -> jnp.ndarray:
    """Reference: blocked cholesky with zero task-layer involvement."""
    n = a.shape[0] // p
    A = [[a[i * n:(i + 1) * n, j * n:(j + 1) * n] for j in range(p)] for i in range(p)]
    for i in range(p):
        for j in range(i):
            A[i][i] = kref.syrk(A[i][j], A[i][i])
            for k in range(i + 1, p):
                A[k][i] = kref.gemm(A[k][j], A[i][j], A[k][i])
        A[i][i] = kref.potrf(A[i][i])
        for j in range(i + 1, p):
            A[j][i] = kref.trsm(A[i][i], A[j][i])
    rows = [jnp.concatenate(r, axis=1) for r in A]
    return jnp.tril(jnp.concatenate(rows, axis=0))


def hand_written_blocked_lu(a: jnp.ndarray, p: int) -> jnp.ndarray:
    """Reference: blocked right-looking LU with zero task-layer involvement."""
    n = a.shape[0] // p
    A = [[a[i * n:(i + 1) * n, j * n:(j + 1) * n] for j in range(p)] for i in range(p)]
    for k in range(p):
        A[k][k] = kref.getrf(A[k][k])
        for j in range(k + 1, p):
            A[k][j] = kref.trsml(A[k][k], A[k][j])
        for i in range(k + 1, p):
            A[i][k] = kref.trsmu(A[k][k], A[i][k])
        for i in range(k + 1, p):
            for j in range(k + 1, p):
                A[i][j] = kref.gemmnn(A[i][k], A[k][j], A[i][j])
    rows = [jnp.concatenate(r, axis=1) for r in A]
    return jnp.concatenate(rows, axis=0)


def drain_stats(
    mats, p: int, graph: str = "g2", submit=utp_cholesky,
    verify: bool = False,
) -> dict:
    """launches/compiles/fused-group counters for a first and a
    structurally repeated drain; ``mats`` may hold several root matrices
    (the multi-root drain case), and an entry may itself be a tuple of
    matrices submitted to one root (composed workloads: ``utp_lu_solve``
    takes A and B).  ``stack_roots=False`` pins the PR-3 segment-fusion
    path: every counter gate below asserts THAT path's invariants (the
    stacked path is measured separately by bench_serving, DESIGN.md §7)."""
    if not isinstance(mats, (list, tuple)):
        mats = [mats]
    clear_compile_cache()
    out = {}
    for which in ("first_drain", "repeat_drain"):
        d = Dispatcher(graph=graph, stack_roots=False, verify=verify)
        for a in mats:
            group = a if isinstance(a, tuple) else (a,)
            datas = [
                GData(m.shape, partitions=((p, p),), dtype=m.dtype, value=m)
                for m in group
            ]
            submit(d, *datas)
        n = d.run()
        out[which] = {
            "leaf_tasks": n,
            "launches": int(d.executor.stats.get("launches", 0)),
            "compiles": int(d.executor.stats.get("compiles", 0)),
            "groups": int(d.executor.stats.get("groups", 0)),
            "groups_prefusion": int(
                d.executor.stats.get("groups_prefusion", 0)
            ),
            # static-verification counters (DESIGN.md §11): must be zero
            # with verify off (no added work disabled) and zero on memo
            # replays (replay pays zero) — both CI-gated
            "verified_scopes": int(d.stats.get("verified_scopes", 0)),
            "verified_plans": int(
                d.executor.stats.get("verified_plans", 0)
            ),
        }
    return out


def measure(smoke: bool = False) -> dict:
    """Run the full overhead measurement; writes the per-bench JSON
    artifact and returns the raw report dict (the harness scenario's
    ``evaluate`` hook reuses this directly; DESIGN.md §13)."""
    report = {"bench": "overhead", "backend": jax.default_backend(),
              "mode": "smoke" if smoke else "full"}
    n, p = (256, 8) if smoke else (512, 8)
    warmup, iters = (1, 3) if smoke else (2, 11)
    for nb in ((4, 8) if smoke else (4, 8, 16)):
        per_task = dispatcher_only_cost(nb)
        row(f"utp_dispatch_only_p{nb}", per_task, "per_task_overhead")
        report[f"dispatch_only_us_per_task_p{nb}"] = per_task * 1e6

    a = spd_matrix(n)
    hand = jax.jit(lambda x: hand_written_blocked(x, p))
    t_hand, t_utp = timeit_pair(
        lambda: hand(a),
        lambda: run_cholesky(a, graph="g2", partitions=((p, p),)),
        warmup=warmup, iters=iters)
    row(f"blocked_handwritten_n{n}_p{p}", t_hand, f"{(n**3/3)/t_hand/1e9:.2f}GF/s")
    ratio = t_utp / t_hand
    row(f"blocked_utp_g2_n{n}_p{p}", t_utp,
        f"overhead={100*(ratio-1):+.1f}%")
    report.update(
        n=n, p=p,
        handwritten_us=t_hand * 1e6,
        utp_g2_us=t_utp * 1e6,
        utp_over_handwritten_ratio=ratio,
        stats=drain_stats(a, p),
    )

    # LU through the same dispatcher/executors (operation-algebra parity)
    a_lu = dd_matrix(n)
    hand_lu = jax.jit(lambda x: hand_written_blocked_lu(x, p))
    t_hand_lu, t_utp_lu = timeit_pair(
        lambda: hand_lu(a_lu),
        lambda: run_lu(a_lu, graph="g2", partitions=((p, p),)),
        warmup=warmup, iters=iters)
    row(f"blocked_lu_handwritten_n{n}_p{p}", t_hand_lu,
        f"{(2*n**3/3)/t_hand_lu/1e9:.2f}GF/s")
    ratio_lu = t_utp_lu / t_hand_lu
    row(f"blocked_lu_utp_g2_n{n}_p{p}", t_utp_lu,
        f"overhead={100*(ratio_lu-1):+.1f}%")
    report.update(
        lu_handwritten_us=t_hand_lu * 1e6,
        lu_utp_g2_us=t_utp_lu * 1e6,
        lu_utp_over_handwritten_ratio=ratio_lu,
        lu_stats=drain_stats(a_lu, p, submit=utp_getrf),
    )

    # Multi-root LU drain (DESIGN.md §2): two independent factorizations in
    # one drain; the dependency-exact pass fuses their same-signature
    # groups across roots into shared launches.  This is the LU case where
    # fusion MUST strictly reduce the group count (single-root LU is at
    # its chain lower bound and stays at groups == groups_prefusion).
    b_lu = dd_matrix(n, seed=7)
    mstats = drain_stats([a_lu, b_lu], p, submit=utp_getrf)
    first = mstats["first_drain"]
    row("lu_multiroot_fusion", 0.0,
        f"groups {first['groups_prefusion']}->{first['groups']}")
    t_pair_sep, t_pair_fused = timeit_pair(
        lambda: (run_lu(a_lu, partitions=((p, p),)),
                 run_lu(b_lu, partitions=((p, p),))),
        lambda: run_lu_many([a_lu, b_lu], partitions=((p, p),)),
        warmup=warmup, iters=iters)
    row("lu_pair_two_drains", t_pair_sep)
    row("lu_pair_fused_drain", t_pair_fused,
        f"speedup={t_pair_sep/t_pair_fused:.2f}x")
    report.update(
        lu_groups_before=first["groups_prefusion"],
        lu_groups_after_fusion=first["groups"],
        lu_multiroot_stats=mstats,
        lu_pair_two_drains_us=t_pair_sep * 1e6,
        lu_pair_fused_drain_us=t_pair_fused * 1e6,
    )

    # End-to-end lu_solve (DESIGN.md §4): the composed factor+solve drain
    # vs the same pipeline as three barrier-separated drains (factor,
    # forward solve, backward solve).  The composed drain is the
    # single-root case where fusion MUST strictly reduce the group count
    # (solve groups merge into independent same-signature factor groups).
    b_rhs = jnp.asarray(
        np.random.default_rng(0).standard_normal((n, n)).astype(np.float32)
    )

    def lu_solve_three_drains():
        A = GData(a_lu.shape, partitions=((p, p),), dtype=a_lu.dtype, value=a_lu)
        B = GData(b_rhs.shape, partitions=((p, p),), dtype=b_rhs.dtype, value=b_rhs)
        d1 = Dispatcher(graph="g2")
        utp_getrf(d1, A)
        d1.run()
        d2 = Dispatcher(graph="g2")
        utp_solve(d2, A, B, lower=True)
        d2.run()
        d3 = Dispatcher(graph="g2")
        utp_solve(d3, A, B, lower=False, side="left")
        d3.run()
        return B.value

    t_three, t_fused_solve = timeit_pair(
        lu_solve_three_drains,
        lambda: run_lu_solve(a_lu, b_rhs, partitions=((p, p),)),
        warmup=warmup, iters=iters)
    row("lu_solve_three_drains", t_three)
    row("lu_solve_fused_drain", t_fused_solve,
        f"speedup={t_three/t_fused_solve:.2f}x")
    sstats = drain_stats([(a_lu, b_rhs)], p, submit=utp_lu_solve)
    sfirst = sstats["first_drain"]
    row("lu_solve_fusion", 0.0,
        f"groups {sfirst['groups_prefusion']}->{sfirst['groups']}")
    report.update(
        lu_solve_stats=sstats,
        lu_solve_groups_before=sfirst["groups_prefusion"],
        lu_solve_groups_after_fusion=sfirst["groups"],
        lu_solve_three_drains_us=t_three * 1e6,
        lu_solve_fused_drain_us=t_fused_solve * 1e6,
    )
    # Static-verification cost (DESIGN.md §11): verify-on vs verify-off,
    # cold (drain memo cleared each call — the full hazard + plan proofs
    # run against cached compiled programs) and hot (memo replay — the
    # verifier is skipped entirely by construction).  The counter shapes
    # are gated in CI; the timings document what REPRO_VERIFY=1 costs.
    from repro.analysis import clear_verified_cache
    from repro.core.executors.jit_wave import _DRAIN_MEMO

    def lu_drain(verify: bool, fresh: bool = False):
        if fresh:
            _DRAIN_MEMO.clear()
            clear_verified_cache()
        d = Dispatcher(graph="g2", stack_roots=False, verify=verify)
        A = GData(
            a_lu.shape, partitions=((p, p),), dtype=a_lu.dtype, value=a_lu
        )
        utp_getrf(d, A)
        d.run()
        return A.value

    t_cold_off, t_cold_on = timeit_pair(
        lambda: lu_drain(False, fresh=True),
        lambda: lu_drain(True, fresh=True),
        warmup=warmup, iters=iters)
    row("lu_drain_cold_verify_off", t_cold_off)
    row("lu_drain_cold_verify_on", t_cold_on,
        f"verify_cost={100*(t_cold_on/t_cold_off-1):+.1f}%")
    t_hot_off, t_hot_on = timeit_pair(
        lambda: lu_drain(False), lambda: lu_drain(True),
        warmup=warmup, iters=iters)
    row("lu_drain_hot_verify_off", t_hot_off)
    row("lu_drain_hot_verify_on", t_hot_on,
        f"replay_cost={100*(t_hot_on/t_hot_off-1):+.1f}%")
    report.update(
        verify_stats=drain_stats(a_lu, p, submit=utp_getrf, verify=True),
        verify_cold_off_us=t_cold_off * 1e6,
        verify_cold_on_us=t_cold_on * 1e6,
        verify_cold_ratio=t_cold_on / t_cold_off,
        verify_hot_off_us=t_hot_off * 1e6,
        verify_hot_on_us=t_hot_on * 1e6,
        verify_hot_ratio=t_hot_on / t_hot_off,
    )

    path = SMOKE_JSON_PATH if smoke else JSON_PATH
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path} (ratio={ratio:.3f}x)")
    return report


def main(smoke: bool = False, quick: bool = None) -> None:
    """Standalone entry (``quick`` kept for benchmarks.run compat)."""
    measure(smoke=smoke if quick is None else quick)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
