"""Paper §3 parity claim: UTP adds no material overhead.

Measures (a) pure dispatcher cost — submit+split+version+schedule per task
with execution stubbed out — and (b) end-to-end wave-batched execution vs
a hand-written blocked-cholesky jnp loop (no task layer at all), plus the
executor launch/compile counters that witness whole-schedule compilation
(one compiled WaveProgram per repeated schedule; DESIGN.md §2/§5).

Emits ``BENCH_overhead.json`` (machine-readable; tracked PR-over-PR).
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core import Dispatcher, GData, GTask, dd_matrix, spd_matrix
from repro.core.executors import clear_compile_cache
from repro.core.executors.base import Executor
from repro.linalg import run_cholesky, run_lu
from repro.linalg.cholesky import utp_cholesky
from repro.linalg.lu import utp_getrf
from repro.linalg.ops import POTRF
from repro.kernels import ref as kref

from .common import row, timeit

JSON_PATH = "BENCH_overhead.json"


class NullExecutor(Executor):
    name = "null"

    def execute_wave(self, wave):
        for t in wave:
            self._finished(t)
        return len(wave)


def dispatcher_only_cost(n_blocks: int) -> float:
    d = Dispatcher(graph="g2")
    d.executor = NullExecutor(on_task_finished=d._on_finished)
    a = GData((64 * n_blocks, 64 * n_blocks), partitions=((n_blocks, n_blocks),))
    t0 = time.perf_counter()
    d.submit_task(GTask(POTRF, None, [a.root_view()]))
    n = d.run()
    dt = time.perf_counter() - t0
    return dt / max(n, 1)


def hand_written_blocked(a: jnp.ndarray, p: int) -> jnp.ndarray:
    """Reference: blocked cholesky with zero task-layer involvement."""
    n = a.shape[0] // p
    A = [[a[i * n:(i + 1) * n, j * n:(j + 1) * n] for j in range(p)] for i in range(p)]
    for i in range(p):
        for j in range(i):
            A[i][i] = kref.syrk(A[i][j], A[i][i])
            for k in range(i + 1, p):
                A[k][i] = kref.gemm(A[k][j], A[i][j], A[k][i])
        A[i][i] = kref.potrf(A[i][i])
        for j in range(i + 1, p):
            A[j][i] = kref.trsm(A[i][i], A[j][i])
    rows = [jnp.concatenate(r, axis=1) for r in A]
    return jnp.tril(jnp.concatenate(rows, axis=0))


def hand_written_blocked_lu(a: jnp.ndarray, p: int) -> jnp.ndarray:
    """Reference: blocked right-looking LU with zero task-layer involvement."""
    n = a.shape[0] // p
    A = [[a[i * n:(i + 1) * n, j * n:(j + 1) * n] for j in range(p)] for i in range(p)]
    for k in range(p):
        A[k][k] = kref.getrf(A[k][k])
        for j in range(k + 1, p):
            A[k][j] = kref.trsml(A[k][k], A[k][j])
        for i in range(k + 1, p):
            A[i][k] = kref.trsmu(A[k][k], A[i][k])
        for i in range(k + 1, p):
            for j in range(k + 1, p):
                A[i][j] = kref.gemmnn(A[i][k], A[k][j], A[i][j])
    rows = [jnp.concatenate(r, axis=1) for r in A]
    return jnp.concatenate(rows, axis=0)


def drain_stats(a: jnp.ndarray, p: int, graph: str = "g2", submit=utp_cholesky) -> dict:
    """launches/compiles for a first and a structurally repeated drain."""
    clear_compile_cache()
    out = {}
    for which in ("first_drain", "repeat_drain"):
        d = Dispatcher(graph=graph)
        A = GData(a.shape, partitions=((p, p),), dtype=a.dtype, value=a)
        submit(d, A)
        n = d.run()
        out[which] = {
            "leaf_tasks": n,
            "launches": int(d.executor.stats.get("launches", 0)),
            "compiles": int(d.executor.stats.get("compiles", 0)),
        }
    return out


def main(quick: bool = True) -> None:
    report = {"bench": "overhead", "backend": jax.default_backend()}
    for nb in (4, 8, 16):
        per_task = dispatcher_only_cost(nb)
        row(f"utp_dispatch_only_p{nb}", per_task, "per_task_overhead")
        report[f"dispatch_only_us_per_task_p{nb}"] = per_task * 1e6

    n, p = 512, 8
    a = spd_matrix(n)
    hand = jax.jit(lambda x: hand_written_blocked(x, p))
    t_hand = timeit(hand, a, warmup=2, iters=7)
    row(f"blocked_handwritten_n{n}_p{p}", t_hand, f"{(n**3/3)/t_hand/1e9:.2f}GF/s")
    t_utp = timeit(lambda: run_cholesky(a, graph="g2", partitions=((p, p),)),
                   warmup=2, iters=7)
    ratio = t_utp / t_hand
    row(f"blocked_utp_g2_n{n}_p{p}", t_utp,
        f"overhead={100*(ratio-1):+.1f}%")
    report.update(
        n=n, p=p,
        handwritten_us=t_hand * 1e6,
        utp_g2_us=t_utp * 1e6,
        utp_over_handwritten_ratio=ratio,
        stats=drain_stats(a, p),
    )

    # LU through the same dispatcher/executors (operation-algebra parity)
    a_lu = dd_matrix(n)
    hand_lu = jax.jit(lambda x: hand_written_blocked_lu(x, p))
    t_hand_lu = timeit(hand_lu, a_lu, warmup=2, iters=7)
    row(f"blocked_lu_handwritten_n{n}_p{p}", t_hand_lu,
        f"{(2*n**3/3)/t_hand_lu/1e9:.2f}GF/s")
    t_utp_lu = timeit(lambda: run_lu(a_lu, graph="g2", partitions=((p, p),)),
                      warmup=2, iters=7)
    ratio_lu = t_utp_lu / t_hand_lu
    row(f"blocked_lu_utp_g2_n{n}_p{p}", t_utp_lu,
        f"overhead={100*(ratio_lu-1):+.1f}%")
    report.update(
        lu_handwritten_us=t_hand_lu * 1e6,
        lu_utp_g2_us=t_utp_lu * 1e6,
        lu_utp_over_handwritten_ratio=ratio_lu,
        lu_stats=drain_stats(a_lu, p, submit=utp_getrf),
    )
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {JSON_PATH} (ratio={ratio:.3f}x)")


if __name__ == "__main__":
    main()
