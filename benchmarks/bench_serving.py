"""Batched-serving throughput: stacked vs sequential vs segment-fused.

The DESIGN.md §7 acceptance benchmark, TaPS-style (throughput over a
request sweep, not single-drain latency): N small LU requests served

  (a) sequentially        — N independent drains (``run_lu`` per matrix),
  (b) segment-fused       — ONE multi-root drain, per-root gather segments
                            (PR-3 ``run_lu_many``),
  (c) stacked             — ONE batched program over a pow2-padded batch
                            axis (``run_lu_batched``, this PR).

All ratios use interleaved A/B timing (``timeit_pair``, DESIGN.md §9) with
the stacked side re-timed inside each pair, so both comparisons survive
machine-load drift.  Also measured: the compiled-program count over an
N=1..max sweep (must stay O(log N): one program per pow2 bucket plus the
N=1 unstacked drain) and the ``BatchServer`` steady state (repeat ticks
must be 0 compiles / 1 launch per signature bucket).

Async overlap A/B (DESIGN.md §12): the ``overlap`` section serves the same
multi-bucket request stream through two servers that differ ONLY in the
``overlap`` flag — off is the pre-PR fence-per-bucket behaviour — with the
two sides interleaved inside every timing iteration (``timeit_pair``).
``check_finite=True`` on both sides makes each tick a true fence (the
validation probes depend on every result grid), so the measured ratio is
completed-work throughput, not dispatch depth.  Per-tick ``host_idle_us``
and ``overlap_ratio`` counters land in the JSON alongside the ratio.

Emits ``BENCH_serving.json`` (``--smoke``: smaller sizes, writes
``BENCH_serving.smoke.json`` for CI's serving gate).  Longitudinal
tracking moved to the evaluation harness (DESIGN.md §13): running this
bench through ``python -m benchmarks.harness`` appends one unified
record per run to ``BENCH_trend.jsonl`` and diffs it against the
recorded baseline (``BENCH_serving.trend.jsonl`` is the frozen pre-§13
trend history).
``--overload`` adds a fault-and-overload scenario (DESIGN.md §10): a burst
past ``max_pending`` plus an injected poisoned request, recording p50/p99
latency and the shed/retried/failed counters — CI's serving gate checks
this section alongside the unchanged 0-compile/1-launch repeat-tick
contract.
"""

from __future__ import annotations

import json
import math
import sys

import jax
import numpy as np

from repro.core import Dispatcher, GData, dd_matrix
from repro.core.executors import clear_compile_cache
from repro.core.executors.jit_wave import drain_memo_stats
from repro.linalg import run_lu, run_lu_batched, run_lu_many
from repro.linalg.lu import utp_getrf
from repro.serve import BatchServer
from repro.testing import faults

from .common import row, timeit, timeit_pair

JSON_PATH = "BENCH_serving.json"
SMOKE_JSON_PATH = "BENCH_serving.smoke.json"


def _mats(N: int, n: int, seed0: int = 0):
    return [dd_matrix(n, seed=seed0 + s) for s in range(N)]


def _overload_section(smoke: bool) -> dict:
    """Overload + fault scenario: burst past ``max_pending`` (sheds with
    RejectedError), then a deterministically poisoned request (bisect
    isolates it; its retries exhaust into DrainError) — every healthy
    request still resolves, and the section records the latency
    percentiles and shed/retried/failed counters for CI's serving gate."""
    clear_compile_cache()
    n, max_pending = (32, 12) if smoke else (64, 24)
    srv = BatchServer(
        graph="g2",
        max_batch=8,
        max_pending=max_pending,
        overload_policy="reject",
        max_retries=1,
        retry_backoff=1,
    )
    burst = max_pending + 8  # 8 requests past the bound are shed
    futs = [
        srv.lu(dd_matrix(n, seed=s), partitions=((2, 2),))
        for s in range(burst)
    ]
    srv.tick()
    poison = [
        srv.lu(dd_matrix(n, seed=100 + s), partitions=((2, 2),))
        for s in range(8)
    ]
    target = poison[3].rid
    with faults.inject(
        "serve.drain",
        RuntimeError("injected: lane poisoned"),
        when=lambda ctx: target in ctx["rids"],
        times=None,
    ):
        srv.tick()  # bisects; poisoned request consumes its retry
        while srv.pending():
            srv.tick()  # backoff ticks, then the retry exhausts
    healthy = sum(
        1 for f in futs + poison if f.done and f.exception() is None
    )
    section = {
        "submitted": burst + 8,
        "max_pending": max_pending,
        "policy": "reject",
        "resolved": healthy,
        "shed": srv.stats["shed"],
        "retried": srv.stats["retried"],
        "failed": srv.stats["failed"],
        "bisected": srv.stats["bisected"],
        "latency": srv.latency_percentiles(),
    }
    row(
        "serve_overload",
        0.0,
        f"{healthy}/{burst + 8} resolved shed={section['shed']} "
        f"retried={section['retried']} failed={section['failed']} "
        f"p50={section['latency']['p50_ms']:.1f}ms "
        f"p99={section['latency']['p99_ms']:.1f}ms",
    )
    return section


def _overlap_ab_section(smoke: bool) -> dict:
    """Interleaved A/B of overlap on vs. off (DESIGN.md §12).

    One tick serves one request in each of K signature buckets — the shape
    where fence-per-bucket hurts most: overlap-off pays (host + device +
    fence) serially per bucket, overlap-on launches all K programs
    back-to-back and fences once.  ``check_finite=True`` on BOTH sides so
    every measured tick ends fully validated (identical semantics, only
    the fencing strategy differs)."""
    clear_compile_cache()
    sizes = tuple(range(24, 56, 8)) if smoke else tuple(range(24, 152, 8))
    per = 1
    pools = {
        n: _mats(per, n, seed0=n) for n in sizes
    }
    requests = per * len(sizes)

    def make_round(srv: BatchServer):
        def fn():
            for n in sizes:
                for m in pools[n]:
                    srv.lu(m, partitions=((4, 4),))
            return srv.tick()

        return fn

    srv_on = BatchServer(graph="g2", check_finite=True, overlap=True)
    srv_off = BatchServer(graph="g2", check_finite=True, overlap=False)
    fn_on, fn_off = make_round(srv_on), make_round(srv_off)
    fn_on()  # capture tick: compiles + memo capture, shared by both sides
    fn_off()
    warmup, iters = (1, 3) if smoke else (2, 13)
    t_off, t_on = timeit_pair(fn_off, fn_on, warmup=warmup, iters=iters)
    rep_on, rep_off = fn_on(), fn_off()
    ratio = t_off / t_on
    row(
        "serve_overlap_ab",
        t_on,
        f"{requests/t_on:.1f}req/s off={t_off*1e6:.0f}us "
        f"off/on={ratio:.2f}x idle_on={rep_on.host_idle_us:.0f}us "
        f"idle_off={rep_off.host_idle_us:.0f}us",
    )
    return {
        "requests": requests,
        "buckets": len(sizes),
        "sizes": list(sizes),
        "on_us": t_on * 1e6,
        "off_us": t_off * 1e6,
        "off_over_on": ratio,
        "on_req_per_s": requests / t_on,
        "host_idle_us_on": rep_on.host_idle_us,
        "host_idle_us_off": rep_off.host_idle_us,
        "overlap_ratio_on": rep_on.overlap_ratio,
        "overlap_ratio_off": rep_off.overlap_ratio,
    }


def measure(smoke: bool = False, overload: bool = False) -> dict:
    """Run the full serving measurement; writes the per-bench JSON
    artifact and returns the raw report dict (the harness scenario's
    ``evaluate`` hook reuses this directly; DESIGN.md §13)."""
    n, p = (64, 4) if smoke else (128, 4)
    sweep_max = 16 if smoke else 64
    batch_sizes = (1, 4, 16) if smoke else (1, 4, 16, 64)
    warmup, iters = (1, 3) if smoke else (2, 9)
    report = {
        "bench": "serving",
        "backend": jax.default_backend(),
        "mode": "smoke" if smoke else "full",
        "n": n,
        "p": p,
        "by_batch": {},
    }

    for N in batch_sizes:
        mats = _mats(N, n)
        clear_compile_cache()
        # pre-capture both paths so the timed region measures the serving
        # steady state (replays), not first-drain Python expansion
        run_lu_batched(mats, partitions=((p, p),))
        for m in mats:
            run_lu(m, partitions=((p, p),))
        run_lu_many(mats, partitions=((p, p),))

        t_seq, t_stacked = timeit_pair(
            lambda: [run_lu(m, partitions=((p, p),)) for m in mats],
            lambda: run_lu_batched(mats, partitions=((p, p),)),
            warmup=warmup,
            iters=iters,
        )
        t_seg, t_stacked2 = timeit_pair(
            lambda: run_lu_many(mats, partitions=((p, p),)),
            lambda: run_lu_batched(mats, partitions=((p, p),)),
            warmup=warmup,
            iters=iters,
        )
        row(f"serve_lu_N{N}_sequential", t_seq, f"{N/t_seq:.1f}req/s")
        row(f"serve_lu_N{N}_segment_fused", t_seg, f"{N/t_seg:.1f}req/s")
        row(
            f"serve_lu_N{N}_stacked",
            t_stacked,
            f"{N/t_stacked:.1f}req/s "
            f"seq/stacked={t_seq/t_stacked:.2f}x "
            f"seg/stacked={t_seg/t_stacked2:.2f}x",
        )
        report["by_batch"][str(N)] = {
            "sequential_us": t_seq * 1e6,
            "segment_fused_us": t_seg * 1e6,
            "stacked_us": t_stacked * 1e6,
            "stacked_us_vs_segment": t_stacked2 * 1e6,
            "stacked_req_per_s": N / t_stacked,
            "seq_over_stacked": t_seq / t_stacked,
            "seg_over_stacked": t_seg / t_stacked2,
        }

    # compile-count sweep: any N in 1..sweep_max must hit one of the
    # O(log N) bucket programs (pow2 buckets + the N=1 unstacked drain)
    clear_compile_cache()
    sweep_compiles = 0
    for N in range(1, sweep_max + 1):
        d = Dispatcher(graph="g2")
        for m in _mats(N, n, seed0=N):
            A = GData(m.shape, partitions=((p, p),), dtype=m.dtype, value=m)
            utp_getrf(d, A)
        d.run()
        sweep_compiles += int(d.executor.stats.get("compiles", 0))
    budget = int(math.log2(sweep_max)) + 1
    row(
        "serve_compile_sweep",
        0.0,
        f"{sweep_compiles} compiles over N=1..{sweep_max} (budget {budget})",
    )
    report.update(
        sweep_max=sweep_max,
        sweep_compiles=sweep_compiles,
        sweep_compile_budget=budget,
        drain_memo=drain_memo_stats(),
    )

    # BatchServer steady state: repeat ticks replay per signature bucket
    clear_compile_cache()
    srv = BatchServer(graph="g2")
    rng = np.random.default_rng(0)
    tick_n = 16 if not smoke else 8

    def queue_and_tick(seed0: int):
        for s in range(tick_n):
            srv.lu_solve(
                dd_matrix(n, seed=seed0 + s),
                rng.standard_normal(n).astype(np.float32),
            )
        return srv.tick()

    queue_and_tick(0)  # capture tick
    reports = [queue_and_tick(100 * (i + 1)) for i in range(3)]
    repeat_compiles = sum(r.compiles for r in reports)
    repeat_launches = [r.launches for r in reports]
    # pipeline contract (DESIGN.md §12): without check_finite a repeat tick
    # never fences, so its host idle must be exactly zero
    repeat_host_idle = sum(r.host_idle_us for r in reports)
    t_tick = timeit(lambda: queue_and_tick(rng.integers(1 << 20)),
                    warmup=1, iters=(3 if smoke else 7))
    latency = srv.latency_percentiles()
    row(
        "serve_tick_lu_solve",
        t_tick,
        f"{tick_n/t_tick:.1f}req/s repeat_compiles={repeat_compiles} "
        f"p50={latency['p50_ms']:.1f}ms p99={latency['p99_ms']:.1f}ms",
    )
    report.update(
        tick_requests=tick_n,
        tick_us=t_tick * 1e6,
        tick_req_per_s=tick_n / t_tick,
        repeat_tick_compiles=repeat_compiles,
        repeat_tick_launches=repeat_launches,
        repeat_tick_host_idle_us=repeat_host_idle,
        latency=latency,
        server_stats=dict(srv.stats),
    )

    report["overlap"] = _overlap_ab_section(smoke)

    if overload:
        report["overload"] = _overload_section(smoke)

    path = SMOKE_JSON_PATH if smoke else JSON_PATH
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}")
    return report


def main(smoke: bool = False, overload: bool = False) -> None:
    measure(smoke=smoke, overload=overload)


if __name__ == "__main__":
    main(
        smoke="--smoke" in sys.argv[1:],
        overload="--overload" in sys.argv[1:],
    )
