"""Benchmark utilities: timing, CSV rows, flop math."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (blocks on jax outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def timeit_pair(
    fn_a: Callable, fn_b: Callable, warmup: int = 1, iters: int = 3
) -> Tuple[float, float]:
    """Interleaved A/B timing: (median_a, median_b) wall seconds per call.

    The two sides alternate within every iteration, so their *ratio* is
    robust to machine-load drift across the run — phase-separated timing
    (timeit twice) can easily skew a ratio 2-3x on a shared box (§9)."""
    for _ in range(warmup):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    ta: List[float] = []
    tb: List[float] = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        tb.append(time.perf_counter() - t0)
    ta.sort()
    tb.sort()
    return ta[len(ta) // 2], tb[len(tb) // 2]


def row(name: str, seconds: float, derived: str = "") -> None:
    ROWS.append((name, seconds * 1e6, derived))
    print(f"{name},{seconds*1e6:.1f},{derived}")


def chol_flops(n: int) -> float:
    return n**3 / 3.0
