"""Benchmark utilities: timing, CSV rows, flop math."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (blocks on jax outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def row(name: str, seconds: float, derived: str = "") -> None:
    ROWS.append((name, seconds * 1e6, derived))
    print(f"{name},{seconds*1e6:.1f},{derived}")


def chol_flops(n: int) -> float:
    return n**3 / 3.0
