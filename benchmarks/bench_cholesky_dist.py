"""Paper Fig. 3(b) analog: distributed Cholesky, UTP vs direct.

Runs in a SUBPROCESS with ``--xla_force_host_platform_device_count=4`` so
the DuctTeip-analog shard executor places level-1 blocks over a real
4-device mesh (the paper's C7-C9 configs, scaled to this harness).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import row

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, time
import jax, jax.numpy as jnp
from repro.core import spd_matrix
from repro.linalg import run_cholesky

mesh = jax.make_mesh((4, 1), ("data", "model"))
out = {}
n = 512
a = spd_matrix(n)

def t(fn):
    fn(); t0 = time.perf_counter(); r = fn(); jax.block_until_ready(r)
    return time.perf_counter() - t0

out["direct"] = t(lambda: jnp.linalg.cholesky(a))
out["g3flat_4dev"] = t(lambda: run_cholesky(a, graph="g3flat", partitions=((8, 8),), mesh=mesh))
out["g3_4dev"] = t(lambda: run_cholesky(a, graph="g3", partitions=((4, 4), (2, 2)), mesh=mesh))
out["g4_4dev"] = t(lambda: run_cholesky(a, graph="g4", partitions=((4, 4), (2, 2)), mesh=mesh))
err = float(jnp.abs(run_cholesky(a, graph="g3", partitions=((4,4),(2,2)), mesh=mesh)
                    - jnp.linalg.cholesky(a)).max())
out["g3_max_err"] = err
print("RESULT " + json.dumps(out))
"""


def main(quick: bool = True) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True, env=env,
        timeout=900,
    )
    line = next(
        (l for l in proc.stdout.splitlines() if l.startswith("RESULT ")), None
    )
    if line is None:
        print(proc.stdout[-2000:])
        print(proc.stderr[-2000:])
        raise RuntimeError("distributed cholesky child failed")
    out = json.loads(line[len("RESULT "):])
    n = 512
    for k in ("direct", "g3flat_4dev", "g3_4dev", "g4_4dev"):
        row(f"cholesky_dist_{k}_n{n}", out[k], f"{(n**3/3)/out[k]/1e9:.2f}GF/s")
    row("cholesky_dist_g3_max_err", out["g3_max_err"] * 1e-6, "abs_err")


if __name__ == "__main__":
    main()
