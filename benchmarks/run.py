"""Benchmark suite entry: harness scenarios + the remaining ad-hoc benches.

    PYTHONPATH=src python -m benchmarks.run [--full]

The four gated cases (overhead, serving, cholesky, lm) run through the
evaluation harness (DESIGN.md §13) — each appends one unified record to
``BENCH_trend.jsonl`` — which is also what finally wires ``bench_serving``
into this suite entry (it previously had no route here at all).  The
exploratory benches without gates (hierarchy, distributed cholesky,
roofline) still run as plain modules.  For the gated path with baseline
diffing use ``python -m benchmarks.harness check`` directly.

CSV rows: name,us_per_call,derived.
"""

from __future__ import annotations

import argparse
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger sizes")
    args = ap.parse_args()
    mode = "full" if args.full else "smoke"
    quick = not args.full

    from benchmarks.harness import REGISTRY, append_trend
    from benchmarks.harness import scenarios  # noqa: F401 — registers

    from . import bench_cholesky_dist, bench_hierarchy, bench_roofline

    print("name,us_per_call,derived")
    for name in sorted(REGISTRY):
        try:
            append_trend(REGISTRY[name].run(mode))
        except Exception as e:  # noqa: BLE001 — keep the suite going
            print(f"harness:{name},BENCH_FAILED,{e!r}")
            traceback.print_exc()
    for mod in (bench_hierarchy, bench_cholesky_dist, bench_roofline):
        try:
            mod.main(quick=quick)
        except Exception as e:  # noqa: BLE001 — keep the suite going
            print(f"{mod.__name__},BENCH_FAILED,{e!r}")
            traceback.print_exc()


if __name__ == "__main__":
    main()
