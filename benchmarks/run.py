"""Benchmark harness entry: one bench per paper table/figure + LM side.

    PYTHONPATH=src python -m benchmarks.run [--full]

CSV rows: name,us_per_call,derived.  ``bench_overhead`` additionally writes
``BENCH_overhead.json`` (machine-readable overhead-parity record, committed
so the perf trajectory is tracked PR-over-PR; DESIGN.md §5).
"""

from __future__ import annotations

import argparse
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger sizes")
    args = ap.parse_args()
    quick = not args.full

    from . import (
        bench_cholesky,
        bench_cholesky_dist,
        bench_hierarchy,
        bench_lm,
        bench_overhead,
        bench_roofline,
    )

    print("name,us_per_call,derived")
    for mod in (
        bench_cholesky,
        bench_overhead,
        bench_hierarchy,
        bench_cholesky_dist,
        bench_lm,
        bench_roofline,
    ):
        try:
            mod.main(quick=quick)
        except Exception as e:  # noqa: BLE001 — keep the suite going
            print(f"{mod.__name__},BENCH_FAILED,{e!r}")
            traceback.print_exc()


if __name__ == "__main__":
    main()
