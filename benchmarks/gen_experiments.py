"""Regenerate EXPERIMENTS.md from benchmarks/results/*.json.

    PYTHONPATH=src python -m benchmarks.gen_experiments
"""

from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "benchmarks" / "results"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "musicgen-large", "rwkv6-3b", "qwen3-32b", "nemotron-4-340b",
    "starcoder2-7b", "gemma3-12b", "zamba2-2.7b", "granite-moe-1b-a400m",
    "llama4-maverick-400b-a17b", "pixtral-12b",
]


def load(mesh: str, tag: str = ""):
    out = {}
    d = RESULTS / mesh
    if not d.exists():
        return out
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("tag", "") != tag:
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def hbm(r):
    v = (r.get("memory") or {}).get("total")
    return f"{v/1e9:.1f}" if v else "n/a"


def roofline_table(rows):
    hdr = (
        "| arch | shape | step | compute ms | memory ms | coll ms | "
        "bottleneck | useful | MFU bound | HBM/chip GB |\n"
        "|---|---|---|---:|---:|---:|---|---:|---:|---:|\n"
    )
    lines = []
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = rows.get((a, s))
            if r is None:
                continue
            lines.append(
                f"| {a} | {s} | {r['step'].replace('_step','')} "
                f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
                f"| {r['collective_s']*1e3:.1f} | {r['bottleneck']} "
                f"| {r['useful_ratio']:.2f} | {r['mfu_bound']:.3f} "
                f"| {hbm(r)} |"
            )
    return hdr + "\n".join(lines)


def delta_table(base, opt):
    hdr = (
        "| arch | shape | MFU base | MFU opt | Δ | HBM base | HBM opt |\n"
        "|---|---|---:|---:|---:|---:|---:|\n"
    )
    lines = []
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            b, o = base.get((a, s)), opt.get((a, s))
            if b is None or o is None:
                continue
            d = (o["mfu_bound"] / b["mfu_bound"] - 1) * 100 if b["mfu_bound"] > 1e-4 else float("nan")
            ds = f"{d:+.0f}%" if d == d else "—"
            lines.append(
                f"| {a} | {s} | {b['mfu_bound']:.3f} | {o['mfu_bound']:.3f} "
                f"| {ds} | {hbm(b)} | {hbm(o)} |"
            )
    return hdr + "\n".join(lines)


def collect_stats(rows):
    n = len(rows)
    fits = sum(
        1 for r in rows.values()
        if (r.get("memory") or {}).get("total", 1e18) <= 16e9
    )
    return n, fits


HEADER = """\
# EXPERIMENTS — TaskUniVerse-JAX

Environment: jax {jaxver} on CPU (single core); TPU v5e is the TARGET
(197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link ICI per the assignment).
Production meshes: single pod (16,16)=256 chips axes ("data","model");
multi-pod (2,16,16)=512 chips axes ("pod","data","model").

Methodology notes (see DESIGN.md §9):
* Every figure below derives from the COMPILED dry-run artifact
  (`lower().compile()`): `memory_analysis()` for HBM capacity, and a
  loop-aware re-analysis of `compiled.as_text()` for per-chip FLOPs, HBM
  traffic and collective bytes (XLA's `cost_analysis()` counts scan bodies
  once — ~64x under-report on these programs; our parser is validated to
  the FLOP on known scans in tests/test_launch.py).
* terms: compute = FLOPs/peak; memory = traffic/HBM_bw; collective =
  bytes/link_bw (ring convention: all-reduce 2x result, all-gather result,
  reduce-scatter/all-to-all operand; (n-1)/n folded to 1).
* `useful` = MODEL_FLOPS / (chips x HLO_FLOPs) where MODEL_FLOPS =
  6·N_active·tokens (train) or 2·N_active·tokens (inference) + the
  causal-aware sequence-mixing term per family (exact N from the parameter
  template; matches published sizes in tests/test_models.py).
* `MFU bound` = MODEL_FLOPS / (chips x PEAK x max(term)) — the roofline
  score. For decode cells the max term is HBM bandwidth by nature, so the
  MFU bound is ~0 by construction; there `useful` (~1.0 = no wasted
  compute) and the memory term itself are the quality signals.
* CPU-backend caveat: XLA:CPU fuses elementwise chains less aggressively
  than XLA:TPU, so the memory term is an upper bound; relative deltas
  between variants are the optimization signal.
"""


def main():
    import jax

    base_pod = load("pod", "")
    opt_pod = load("pod", "opt")
    base_mp = load("multipod", "")
    opt_mp = load("multipod", "opt")

    n_pod, fit_pod = collect_stats(opt_pod)
    doc = [HEADER.format(jaxver=jax.__version__)]

    doc.append("""
## §Dry-run — multi-pod compile proof

Every supported (architecture x input-shape) cell lowers AND compiles for
both production meshes with `ShapeDtypeStruct` inputs (no allocation):

* single-pod (16,16), 256 chips: **33/33 OK** (baseline) and **33/33 OK**
  (optimized defaults)
* multi-pod (2,16,16), 512 chips: **33/33 OK** — the "pod" axis shards
  (data-parallel across pods; FSDP extends onto it for >=100B models)
* 7 documented `long_500k` skips (pure full-attention archs:
  musicgen-large, qwen3-32b, nemotron-4-340b, starcoder2-7b,
  granite-moe-1b-a400m, llama4-maverick-400b-a17b, pixtral-12b) — see
  DESIGN.md §5. long_500k RUNS for rwkv6-3b, zamba2-2.7b, gemma3-12b.

Command: `python -m repro.launch.dryrun --all --mesh both`
(logs in /tmp/dryrun_{pod,multipod}.log; per-cell JSON in
benchmarks/results/<mesh>/).

HBM capacity (optimized defaults, v5e budget 16 GB/chip): """
f"{fit_pod}/{n_pod} pod cells fit outright."
"""
Known over-budget cells and their production resolution:
* nemotron-4-340b train (87 GB/chip single-pod): a 340B fp32-master run
  does not fit one 256-chip v5e pod by arithmetic (params+moments alone
  ~10.6 GB/chip before activations); the multi-pod mesh extends FSDP over
  ("pod","data") and remains the deployment target. Microbatching was
  measured and REFUTED as a fix (§Perf: grad reductions scale ~m x).
* llama4-maverick train (49 GB/chip): same class — 400B totals want the
  512-chip mesh or v5p-class HBM.
* decode_32k cells sit at 16-46 GB/chip driven by the batch-128 KV cache +
  double-buffered donation; production serving shards batch 128 across
  more replicas or quantizes the cache (int8 KV is the next knob).
""")

    doc.append("## §Roofline — baseline, single pod (16,16), per chip\n\n"
               + roofline_table(base_pod))
    doc.append("\n## §Roofline — optimized defaults, single pod, per chip\n\n"
               + roofline_table(opt_pod))
    doc.append("\n### Baseline -> optimized deltas (pod)\n\n"
               + delta_table(base_pod, opt_pod))
    doc.append("\n## §Roofline — multi-pod (2,16,16) baseline\n\n"
               + roofline_table(base_mp))
    if opt_mp:
        doc.append("\n### Multi-pod optimized (hillclimbed cells)\n\n"
                   + roofline_table(opt_mp))

    doc.append(PERF_LOG)
    doc.append(PAPER_VALIDATION)
    (ROOT / "EXPERIMENTS.md").write_text("\n".join(doc))
    print(f"wrote EXPERIMENTS.md ({len(base_pod)} baseline pod cells, "
          f"{len(opt_pod)} optimized)")


PERF_LOG = """
## §Perf — hillclimbing log (hypothesis -> change -> before -> after -> verdict)

Three cells selected per the assignment: **worst roofline fraction**
(rwkv6-3b train_4k, MFU bound 0.007), **most collective-bound**
(gemma3-12b train_4k), **most representative** (qwen3-32b train_4k — the
dense-FSDP+TP flagship the paper's "one program, any mesh" claim rides on).
All numbers: per-chip seconds on the (16,16) pod from the compiled HLO.
Reproduce any row: `python -m repro.launch.dryrun --arch X --shape train_4k
--mesh pod --override k=v ... --tag mytag`.

### Pre-baseline framework fix (applies to every cell)

While validating the first compiles, the qwen3 baseline showed activations
materialized as `f32[256,4096,320]` — the partitioner had all-gathered the
BATCH and sharded d_model to chase the FSDP weight sharding. One
`with_sharding_constraint` anchoring the residual stream to the DP layout
per group (models/moe.py `constrain_batch`) cut the memory term 371 s ->
39.4 s and compute 17.9 s -> 5.9 s. Lesson: **anchor activation layouts at
scan boundaries; never let weight shardings propagate into activations.**
All baselines below already include this fix.

### Cell A — qwen3-32b x train_4k (baseline: C 5.89 / M 39.38 / X 29.12 s, MFU bound 0.108, HBM 133 GB/chip)

| iter | hypothesis | change | dominant term before -> after | verdict |
|---|---|---|---|---|
| A1 | FSDP all-gathers move fp32 masters; casting params to bf16 at step entry halves gather bytes | `cast_params` entry cast | M 39.38 -> 39.43 | **refuted** — XLA already hoists the per-use converts before the gathers (all-gather was 19.8 GB, already bf16) |
| A2 | fp32 attention scores dominate HBM traffic (predict M -30%) | `score_dtype=bf16` | M 39.38 -> 39.08 | **refuted** (-0.8%) — the chunked+rematerialized scores are a minor stream; full-seq norms/elementwise dominate |
| A3 | Megatron-SP: norms/elementwise on S/16 shards, TP all-reduce -> RS+AG, per-group saved activations sharded | `seq_parallel=True` | M 39.38 -> 24.70, X 29.12 -> 25.86, HBM 133 -> 17.9 GB | **confirmed** — MFU bound 0.108 -> 0.165 (+53%) |
| A4 | A2 on top of A3 (seq AGs now carry score-adjacent tensors) | A3 + `score_dtype=bf16` | X 25.86 -> 25.86 | **refuted** — the remaining f32 collectives are weight-grad tuples + attention bwd cotangents, not scores |
| A5a | per-group fp32 weight-grad all-reduces (2x ~244 GB tuples) stem from unanchored backward carry; pinning forward param slices fixes it | `anchor_params=True` | X 25.86 -> 25.86 | **refuted** — constraint is a no-op (slices already sharded); Shardy still materializes full-size grad partials. Root cause: with seq-sharded attention, dy has FULL heads, so dW partials are full-size. Future: head-TP bwd or per-group reduce-scatter rewrite |
| A5b | forcing Megatron head-TP q/k/v/o layouts shrinks attention resharding | `anchor_attn=True` | X 25.86 -> 34.68 | **refuted (regression)** — Shardy's preferred seq-sharded attention beats forced head-TP when kv_heads (8) < TP degree (16) |
| A6 | remat `dots` removes bwd recompute (predict C -25%) | `remat=dots` | C 5.77 -> 4.83 but M 24.40 -> 33.09, HBM 92 GB | **mixed -> rejected** — compute win real (-16%) but capacity explodes at B_loc=16 |
| A7 | m=2 grad accumulation halves live activations to FIT 16 GB | `microbatches=2` | HBM 17.9 -> 12.0 GB; X 25.86 -> 41.17 | **confirmed for fit** (kept as the deployment variant; MFU 0.103) — grad reductions scale with m |
| A8 | moving the bf16 cast inside the scan makes grad reductions bf16 (predict X -35%) | `cast_in_scan=True` | X 25.86 -> 25.86 | **refuted** — XLA canonicalizes the converts back out of the loop |

Stop rule hit (A4, A5a, A8 < 5% on the dominant term). **Final: MFU bound
0.108 -> 0.165 (+53%), memory -38%, HBM/chip 133 -> 17.9 GB (12.0 GB fit
variant at MFU 0.103).** Remaining bottleneck: fp32 weight-grad reductions
(~490 GB/chip/step) — the identified future lever is a per-group
reduce-scatter custom-vjp.

### Cell B — gemma3-12b x train_4k (baseline: C 2.36 / M 17.08 / X 18.64 s, MFU bound 0.075, HBM 55 GB/chip)

| iter | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| B1 | SP + bf16 scores transfer from cell A | `seq_parallel + score_dtype=bf16` | M 17.08 -> 8.98 (-47%), HBM 55 -> 19.5 GB, X 18.64 -> 20.58 (+10%) | **mixed** — capacity/memory win, small collective regression; net MFU 0.075 -> 0.068 |
| B2 | gemma's 16 heads x hd 256 vs 16-way TP: anchoring head-TP q/k/v keeps the f32 qk-norm cotangents from resharding | B1 + `anchor_attn=True` | X 20.58 -> 18.95 (-8%) but C 2.24 -> 2.71 (+21%) | **neutral** — MFU 0.074 ≈ baseline |
| B3 | fp32 weight gathers (45+23 GB) are gather-then-convert; pinning the bf16 copies forces convert-then-gather | `anchor_cast=True` | X 20.58 -> 20.58 | **refuted** — Shardy's gather placement unchanged |

Stop rule hit. **Finding: gemma3's collective term is structural on a
16-way TP axis** — 16 q-heads/8 kv-heads leave one head per chip, and
qk-norm's fp32 upcasts ride every reshard (206 GB AG+AR pairs). The
optimized default (B1) is kept for the 2.8x HBM-capacity win (55 -> 19.8
GB: the baseline did not fit). Recorded future lever: head-DIM sharding
(hd=256 splits 16 ways cleanly) or an 8-way TP sub-mesh for this family.

### Cell C — rwkv6-3b x train_4k (baseline: C 2.92 / M 53.35 / X 2.95 s, MFU bound 0.007, useful 0.13)

| iter | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| C1 | the (B,Q,Q,H,K) pairwise-decay tensor's HBM traffic scales ~Q; Q=64 -> 16 cuts the memory term ~4x at negligible compute cost | `rwkv_chunk=16` | M 53.35 -> 27.50 (-48%) | **confirmed** (MFU 0.007 -> 0.013) |
| C2 | H=40 heads don't divide the 16-way TP axis, so the whole recurrence is REPLICATED 16x across the model axis; SP shards it by sequence instead | + `seq_parallel=True` | C 2.90 -> 0.52 (-82%!), M 27.50 -> 18.13, useful 0.13 -> 0.70 | **confirmed** — the single biggest insight: attention-free archs get TP for free only via sequence sharding |
| C3 | bf16 intra-chunk einsums halve the pair-tensor traffic | `score_dtype=bf16` (wkv mix dtype) | M 18.13 -> 18.12 | **refuted** — the fp32 exp/diff construction still materializes at the fusion boundary |
| C4 | consistency check: Q back to 32 should re-inflate | `rwkv_chunk=32` | M 18.13 -> 24.74 | confirmed (validates the Q-traffic model) |
| C5 | Q=8 continues the trend | `rwkv_chunk=8` | M 18.13 -> 17.51 (-3.4%) | **< 5%** — fixed per-chunk streams now dominate |

Stop rule hit (C3, C5). **Final: MFU bound 0.007 -> 0.021 (3x), useful
0.13 -> 0.71, compute -82%, memory -67%.** `rwkv_chunk=16` and
`seq_parallel` became the config defaults. Recorded future lever: the
sub-chunk dot-product decomposition (reference-point trick keeps both
exponent factors <= 0) to move the intra-chunk work onto the MXU entirely
in bf16 — the Pallas-kernel version of this layer.

### Prefill chunking bug (found by the optimized sweep, fixed)

rwkv6/zamba2 `prefill_32k` originally reused the decode path's
"single chunk" mode: one S-sized chunk materializes the (B,S,S,H)-class
decay tensor — 22 TB/chip for rwkv6. Chunked-with-carried-state prefill
(the training path + s0) fixed it: rwkv6 prefill HBM 22 TB -> 3.7 GB
(MFU bound 0.001 -> 0.036), zamba2 55 -> 3.2 GB (0.013 -> 0.040). Lesson:
recurrent-state prefill must reuse the chunked scan, never the
decode fallback.

### Beyond-paper optimizations (framework-wide, all validated by the tables above)

1. **Activation-layout anchoring** (`constrain_batch`) — the pre-baseline
   9.4x memory fix; now structural.
2. **Megatron sequence parallelism** as a one-flag config default.
3. **Flash-style chunked attention with per-chunk remat** (pure XLA) +
   the Pallas flash kernel (kernels/flash_attention.py, validated vs the
   oracle in interpret mode) as the TPU realization.
4. **Chunked cross-entropy** with rematerialized (B,c,V) logits — a 256k
   vocab never materializes (B,S,V).
5. **Scan-chunked RWKV6/Mamba2 recurrences** with fp32-safe exponents and
   O(chunk) working sets (terabytes -> GB at 32k).
6. **Expert-parallel MoE via shard_map** — tokens stay on their data
   shard; the combine is one TP-axis psum; FSDP'd expert weights
   all-gather bf16 inside the body (bwd = reduce-scatter).
7. **bf16 serving weights** (no fp32 masters at inference) — serve plans
   take compute-dtype params directly.
8. **Wave batching with power-of-two bucket padding** in the UTP executors
   (compile-once, run-many; idempotent duplicate scatter).
9. **Global compiled-group cache** keyed on structural signatures — the
   dispatcher-parity numbers in §Paper-validation depend on it.

### Scorecard (roofline fraction = MFU bound on the compiled step)

| cell | baseline | optimized | change |
|---|---:|---:|---:|
| qwen3-32b train_4k | 0.108 | **0.165** | +53% |
| gemma3-12b train_4k | 0.075 | 0.074 (B2) / 0.068 (default) | ~0 (HBM 55->19.8 GB) |
| rwkv6-3b train_4k | 0.007 | **0.021** | +200% |
| starcoder2-7b train_4k (defaults transfer) | 0.012 | **0.144** | +1100% |
| llama4-maverick train_4k (defaults transfer) | 0.012 | **0.080** | +560% |
| nemotron-4-340b prefill_32k (defaults transfer) | 0.170 | **0.209** | +23% |
| rwkv6-3b prefill_32k (bug fix) | 0.000 | **0.036** | ~36x |

Honest bound discussion: the best train cell (nemotron 0.221-0.240) is
compute-dense; most others are bandwidth/collective-bound on this CPU-fused
HLO and would improve further under XLA:TPU fusion + the Pallas flash
kernel replacing the portable attention (its BlockSpec working set streams
q/k/v/o exactly once per KV revisit — the memory-term model then drops the
score-tensor stream entirely).
"""

PAPER_VALIDATION = """
## §Paper-validation — the paper's own claims, re-validated

(CSV from `python -m benchmarks.run`; CPU wall-clock, median of 3.)

1. **Portability (Fig. 2/3 claim):** ONE application program
   (`utp_cholesky`) runs under G1 (eager leaves), G2 (wave-batched jit),
   G2' (Pallas tile kernels), G3/G4 (hierarchical, sharded over a device
   mesh) with identical results (tests/test_cholesky.py, max_err ~1e-7 vs
   `jnp.linalg.cholesky`; examples/quickstart.py prints the four plans).
2. **Low overhead (paper §3 parity):** dispatcher-only cost is ~16-30 us
   per task (bench `utp_dispatch_only_*`); the end-to-end LM task-tree
   step under the fused executor costs ~27 ms vs ~20 ms for the
   hand-written jit step (`lm_train_step_utp_fused_m2`, ~+30% — all of it
   Python-side task bookkeeping per step, amortizable by submitting once
   per N steps; the compiled XLA program is identical). The wave executors
   compile-once/run-many via a process-global structural cache — without
   it the same bench was 300x slower, which is itself a §Perf lesson.
3. **Hierarchy extends reach (Fig. 3a C5 vs C6):** two-level partitioning
   runs 20 leaf tasks/12 wave launches where the flat 16x16 grid needs
   816 tasks/60 launches at equal accuracy (bench `hierarchy_*`) — the
   compile-size/schedule-size scaling the paper attributes to
   DuctTeip-over-SuperGlue.
4. **Distributed execution (Fig. 3b):** the same program on a real
   4-device host mesh under G3/G4 (bench `cholesky_dist_*`,
   examples/distributed_cholesky.py — the result stays sharded across
   devices; XLA collectives replace MPI messages).
5. **End-to-end training** (`examples/train_lm.py`): synthetic-bigram loss
   falls 6.07 -> 5.30 in 40 CPU steps on the reduced qwen3 config with
   async checkpoints + injected-failure recovery exercised in
   tests/test_train.py; `--preset 100m --steps 300` is the
   deliverable-scale configuration for real silicon.

## Reproduction commands

```bash
export PYTHONPATH=src
python -m pytest tests/                      # 118 tests
python -m benchmarks.run                     # paper-table benches + roofline CSV
python -m repro.launch.dryrun --all --mesh both        # 66 compiles
python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k \\
    --mesh pod --override seq_parallel=True --tag mine  # any §Perf row
python -m benchmarks.gen_experiments         # regenerate this file
```
"""


if __name__ == "__main__":
    main()
