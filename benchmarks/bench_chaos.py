"""Chaos scenario: self-healing serving under a multi-site fault schedule.

The DESIGN.md §14 acceptance benchmark, TaPS-style (failure-under-load as
a first-class evaluation axis): a ``BatchServer`` is driven through a
deterministic, seeded fault schedule that exercises all three self-healing
mechanisms in one run —

  * a persistently poisoned signature bucket (every drain raises) that
    must trip its circuit breaker OPEN, half-open after the cooldown, and
    re-close on the probe once the fault clears (the breaker ROUND TRIP
    witness),
  * a fence stall (injected ``drain.stall`` delay longer than the
    watchdog budget) that must surface as typed ``DrainStalledError``
    without blocking the tick past the budget,
  * a device OOM (injected ``launch.oom``) on a stacked chunk that must
    split, resolve both halves the same tick, and degrade then recover
    the bucket's batch cap,

plus seeded random transient drain failures sprinkled across the schedule
(retry + bisect load).  The invariants gated by CI: 100% of submitted
futures end resolved or typed-failed (``lost_futures == 0``), no tick
wedges past its budget (``wedged_ticks == 0``), at least one breaker
round trip / watchdog fire / OOM event was witnessed, and the post-fault
steady state is back to the §7 replay contract (0 compiles, 1 launch per
bucket, ``health() == HEALTHY``).

Emits ``BENCH_chaos.json`` (``--smoke``: ``BENCH_chaos.smoke.json``).
Running through ``python -m benchmarks.harness`` appends the unified
record — including the new ``TickReport`` self-healing counters — to
``BENCH_trend.jsonl``.
"""

from __future__ import annotations

import json
import sys
import time

import jax
import numpy as np

from repro.core import dd_matrix, spd_matrix
from repro.core.executors import clear_compile_cache
from repro.errors import ResourceExhausted, ServeError
from repro.serve import BatchServer
from repro.testing import faults

from .common import row

JSON_PATH = "BENCH_chaos.json"
SMOKE_JSON_PATH = "BENCH_chaos.smoke.json"

_N, _P = 32, 2
_WATCHDOG_S = 0.3
_STALL_S = 1.0


def _submit(srv: BatchServer, kind: str, seed: int):
    if kind == "lu":
        return srv.lu(dd_matrix(_N, seed=seed), partitions=((_P, _P),))
    return srv.cholesky(spd_matrix(_N, seed=seed), partitions=((_P, _P),))


def measure(smoke: bool = False) -> dict:
    """Run the chaos schedule; writes the per-bench JSON artifact and
    returns the raw report dict (the harness ChaosScenario's ``evaluate``
    hook reuses this directly; DESIGN.md §13/§14)."""
    clear_compile_cache()
    rng = np.random.default_rng(0)
    srv = BatchServer(
        graph="g2",
        max_batch=4,
        max_retries=1,
        watchdog_s=_WATCHDOG_S,
        breaker_threshold=2,
        breaker_cooldown=2,
        degrade_recovery=2,
        retry_jitter_seed=7,
    )
    # a tick that blocks longer than budget + every injected delay + slack
    # has wedged: nothing in the schedule can legitimately take this long
    wedge_budget_s = _WATCHDOG_S + _STALL_S + 30.0
    all_futs = []
    seed = 0
    ticks = 0
    wedged = 0

    def tick() -> None:
        nonlocal ticks, wedged
        t0 = time.perf_counter()
        srv.tick()
        if time.perf_counter() - t0 > wedge_budget_s:
            wedged += 1
        ticks += 1

    def submit(kind: str):
        nonlocal seed
        all_futs.append(_submit(srv, kind, seed))
        seed += 1

    # phase 0 — warmup: capture both buckets' programs healthy
    for _ in range(2):
        submit("lu")
        submit("chol")
    tick()

    # phase 1 — poisoned chol bucket: every drain raises until the breaker
    # trips (threshold 2), then the fault clears and the cooldown + probe
    # must complete the round trip
    with faults.inject(
        "serve.drain",
        lambda: RuntimeError("chaos: poisoned bucket"),
        when=lambda ctx: ctx["op"] == "potrf",
        times=None,
    ):
        for _ in range(3):
            submit("chol")
            submit("lu")  # healthy bystander bucket: must keep resolving
            tick()
    for _ in range(4):  # cooldown ticks + half-open probe + re-close
        submit("chol")
        tick()

    # phase 2 — fence stall: the watchdog must fail the chunk typed
    # within budget instead of blocking the tick on the hung fence
    submit("lu")
    submit("lu")
    with faults.inject("drain.stall", delay_s=_STALL_S):
        tick()

    # phase 3 — device OOM on a full stacked chunk: split halves resolve
    # the same tick, the bucket's cap degrades then recovers
    for _ in range(4):
        submit("lu")
    with faults.inject(
        "launch.oom", lambda: ResourceExhausted("RESOURCE_EXHAUSTED: chaos")
    ):
        tick()

    # phase 4 — seeded random transient faults (retry + bisect load)
    chaos_ticks = 2 if smoke else 5
    for _ in range(chaos_ticks):
        for _ in range(int(rng.integers(1, 4))):
            submit("lu")
        n_raises = int(rng.integers(0, 3))
        if n_raises:
            with faults.inject(
                "serve.drain",
                lambda: RuntimeError("chaos: transient"),
                times=n_raises,
            ):
                tick()
        else:
            tick()

    # phase 5 — recovery: healthy traffic until queue empty, breakers
    # closed, degradation recovered
    for i in range(12):
        submit("lu")
        submit("chol")
        tick()
        if (
            srv.pending() == 0
            and srv.health() == "HEALTHY"
            and all(f.done for f in all_futs)
        ):
            break

    # phase 6 — steady state: the §7 replay contract must hold again
    def steady_tick():
        for _ in range(2):
            submit("lu")
        for _ in range(2):
            submit("chol")
        tick()

    clear_steady = []
    for _ in range(3):
        before = dict(srv.stats)
        steady_tick()
        clear_steady.append(
            {
                "compiles": srv.stats["compiles"] - before["compiles"],
                "launches": srv.stats["launches"] - before["launches"],
                "failed": srv.stats["failed"] - before["failed"],
                "drains": srv.stats["drains"] - before["drains"],
            }
        )
    steady = clear_steady[-1]  # first steady tick may still recompile
    steady_ok = int(
        steady["compiles"] == 0
        and steady["failed"] == 0
        and steady["launches"] == steady["drains"] == 2  # one per bucket
    )

    resolved = typed_failed = lost = untyped = 0
    for f in all_futs:
        if not f.done:
            lost += 1
        elif f.exception() is None:
            resolved += 1
        elif isinstance(f.exception(), ServeError):
            typed_failed += 1
        else:
            untyped += 1

    report = {
        "bench": "chaos",
        "backend": jax.default_backend(),
        "mode": "smoke" if smoke else "full",
        "submitted": len(all_futs),
        "resolved": resolved,
        "typed_failed": typed_failed,
        "untyped_failed": untyped,
        "lost_futures": lost,
        "ticks": ticks,
        "wedged_ticks": wedged,
        "wedge_budget_s": wedge_budget_s,
        "breaker_trips": srv.stats["breaker_trips"],
        "breaker_closes": srv.stats["breaker_closes"],
        "breaker_round_trips": srv.breaker_round_trips(),
        "breaker_fast_fails": srv.stats["breaker_fast_fails"],
        "watchdog_fires": srv.stats["watchdog_fires"],
        "oom_events": srv.stats["oom_events"],
        "final_health": srv.health(),
        "final_health_healthy": int(srv.health() == "HEALTHY"),
        "steady_state": steady,
        "steady_state_ok": steady_ok,
        "server_stats": dict(srv.stats),
    }
    row(
        "serve_chaos",
        0.0,
        f"{resolved}/{len(all_futs)} resolved typed_failed={typed_failed} "
        f"lost={lost} wedged={wedged} "
        f"breaker_rt={report['breaker_round_trips']} "
        f"watchdog={report['watchdog_fires']} oom={report['oom_events']} "
        f"health={report['final_health']}",
    )

    path = SMOKE_JSON_PATH if smoke else JSON_PATH
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}")
    return report


def main(smoke: bool = False) -> None:
    measure(smoke=smoke)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
