"""Paper Fig. 3(a) C5-vs-C6 analog: hierarchical partitioning extends
reachable problem sizes.

The flat single-level schedule's compiled-launch count grows O(p^3) with
the block grid; two-level (DuctTeip-over-SuperGlue) keeps the top level
coarse and reuses the SAME small second-level programs — measured here as
distinct jit compilations + wave launches per matrix size (the
compile-size/working-set scaling argument from DESIGN.md §2).
"""

from __future__ import annotations

import jax

from repro.core import Dispatcher, GData, GTask, spd_matrix
from repro.linalg.cholesky import utp_cholesky

from .common import row, timeit


def run_with_stats(a, graph, partitions, mesh=None):
    d = Dispatcher(graph=graph, mesh=mesh)
    A = GData(a.shape, partitions=partitions, dtype=a.dtype, value=a)
    utp_cholesky(d, A)
    n = d.run()
    return n, dict(d.executor.stats), d.stats


def main(quick: bool = True) -> None:
    n = 512
    a = spd_matrix(n)
    flat_tasks, flat_stats, _ = run_with_stats(a, "g2", ((16, 16),))
    hier_tasks, hier_stats, _ = run_with_stats(a, "g2", ((4, 4), (4, 4)))
    row("hierarchy_flat_p16_leaf_tasks", flat_tasks * 1e-6, "tasks")
    row("hierarchy_flat_p16_compiles", flat_stats.get("compiles", 0) * 1e-6,
        "distinct_jit_programs")
    row("hierarchy_flat_p16_launches", flat_stats.get("launches", 0) * 1e-6,
        "wave_launches")
    row("hierarchy_2level_4x4_leaf_tasks", hier_tasks * 1e-6, "tasks")
    row("hierarchy_2level_4x4_compiles", hier_stats.get("compiles", 0) * 1e-6,
        "distinct_jit_programs")
    row("hierarchy_2level_4x4_launches", hier_stats.get("launches", 0) * 1e-6,
        "wave_launches")


if __name__ == "__main__":
    main()
