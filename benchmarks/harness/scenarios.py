"""The registered scenarios: the four ported benches (DESIGN.md §13).

Each scenario is a thin declarative wrapper over the existing bench
module's ``measure`` code — the measurement stays where it always lived;
the scenario maps the raw report into the unified ``Result`` record and
declares the gates.  Derived 0/1 "witness" counters turn cross-key
conditions (e.g. "fusion strictly reduced the group count") into exact
invariant gates, so the whole former hand-rolled CI gate script is now
data the baseline differ evaluates.
"""

from __future__ import annotations

from typing import Any, Dict

import jax

from .record import Result
from .scenario import Gate, Scenario, register

_REPEAT_CASES = ("stats", "lu_stats", "lu_multiroot_stats", "lu_solve_stats")


class OverheadScenario(Scenario):
    """Dispatcher/compile-counter parity (bench_overhead; DESIGN.md §5)."""

    name = "overhead"
    workload = "linalg"
    gates = tuple(
        [
            Gate(f"{case}_repeat_compiles", "invariant", "==", 0)
            for case in _REPEAT_CASES
        ]
        + [
            Gate(f"{case}_repeat_launches", "invariant", "==", 1)
            for case in _REPEAT_CASES
        ]
        + [
            # dependency-exact scheduling witnesses (DESIGN.md §2/§4)
            Gate("multiroot_fusion_reduced", "invariant", "==", 1),
            Gate("single_root_lu_at_lower_bound", "invariant", "==", 1),
            Gate("lu_solve_one_program", "invariant", "==", 1),
            Gate("lu_solve_fusion_reduced", "invariant", "==", 1),
            # static-verification cost contract (DESIGN.md §11)
            Gate("verify_off_zero_work", "invariant", "==", 1),
            Gate("verify_on_first_drain_proved", "invariant", "==", 1),
            Gate("verify_on_replay_pure", "invariant", "==", 1),
            # parity ratios: interleaved A/B, but genuinely load-sensitive
            # (task layer vs one jitted call), so band-gated vs baseline
            Gate(
                "utp_over_handwritten_ratio", "walltime",
                higher_is_better=False, band=0.5,
            ),
            Gate(
                "lu_utp_over_handwritten_ratio", "walltime",
                higher_is_better=False, band=0.5,
            ),
        ]
    )

    def config(self, mode: str) -> Dict[str, Any]:
        cfg = super().config(mode)
        cfg["smoke"] = mode == "smoke"
        return cfg

    def evaluate(self, cfg, gen) -> Dict[str, Any]:
        from benchmarks import bench_overhead

        return bench_overhead.measure(smoke=cfg["smoke"])

    def report(self, cfg, raw) -> Result:
        counters: Dict[str, int] = {}
        for case in _REPEAT_CASES:
            rep = raw[case]["repeat_drain"]
            counters[f"{case}_repeat_compiles"] = rep["compiles"]
            counters[f"{case}_repeat_launches"] = rep["launches"]
        counters["lu_groups_before"] = raw["lu_groups_before"]
        counters["lu_groups_after_fusion"] = raw["lu_groups_after_fusion"]
        counters["multiroot_fusion_reduced"] = int(
            raw["lu_groups_after_fusion"] < raw["lu_groups_before"]
        )
        lu = raw["lu_stats"]["first_drain"]
        counters["single_root_lu_at_lower_bound"] = int(
            lu["groups"] == lu["groups_prefusion"]
        )
        ls = raw["lu_solve_stats"]["first_drain"]
        counters["lu_solve_one_program"] = int(
            ls["launches"] == 1 and ls["compiles"] == 1
        )
        counters["lu_solve_fusion_reduced"] = int(
            ls["groups"] < ls["groups_prefusion"]
        )
        counters["verify_off_zero_work"] = int(
            all(
                raw[case][which]["verified_scopes"] == 0
                and raw[case][which]["verified_plans"] == 0
                for case in _REPEAT_CASES
                for which in ("first_drain", "repeat_drain")
            )
        )
        vf = raw["verify_stats"]["first_drain"]
        vr = raw["verify_stats"]["repeat_drain"]
        counters["verify_on_first_drain_proved"] = int(
            vf["verified_scopes"] >= 1 and vf["verified_plans"] >= 1
        )
        counters["verify_on_replay_pure"] = int(
            vr["compiles"] == 0
            and vr["launches"] == 1
            and vr["verified_scopes"] == 0
            and vr["verified_plans"] == 0
        )
        metrics = {
            k: raw[k]
            for k in (
                "utp_over_handwritten_ratio",
                "lu_utp_over_handwritten_ratio",
                "handwritten_us",
                "utp_g2_us",
                "lu_handwritten_us",
                "lu_utp_g2_us",
                "lu_pair_two_drains_us",
                "lu_pair_fused_drain_us",
                "lu_solve_three_drains_us",
                "lu_solve_fused_drain_us",
                "verify_cold_ratio",
                "verify_hot_ratio",
            )
        }
        for k, v in raw.items():
            if k.startswith("dispatch_only_us_per_task"):
                metrics[k] = v
        return Result(
            scenario=self.name,
            workload=self.workload,
            mode=cfg["mode"],
            backend=raw["backend"],
            graphs=["g2"],
            metrics=metrics,
            counters=counters,
        )


class ServingScenario(Scenario):
    """Batched-serving stacking/overlap/overload (bench_serving;
    DESIGN.md §7/§10/§12)."""

    name = "serving"
    workload = "serving"
    gates = (
        # replay contract: a structurally repeated tick is pure replay
        Gate("repeat_tick_compiles", "invariant", "==", 0),
        Gate("repeat_tick_launches_ok", "invariant", "==", 1),
        Gate("repeat_tick_host_idle_us", "invariant", "==", 0),
        # O(log N) stacked-program sweep (DESIGN.md §7)
        Gate("sweep_within_budget", "invariant", "==", 1),
        # latency percentiles recorded and well-formed (DESIGN.md §10)
        Gate("latency_ok", "invariant", "==", 1),
        # overload scenario: shedding + retry + poisoned-request isolation
        Gate("overload_shed", "invariant", ">=", 1),
        Gate("overload_retried", "invariant", ">=", 1),
        Gate("overload_failed", "invariant", ">=", 1),
        Gate("overload_accounting_ok", "invariant", "==", 1),
        # interleaved A/B ratios: fixed thresholds (DESIGN.md §9)
        Gate("n16_seq_over_stacked", "ratio", ">=", 1.0),
        Gate("overlap_off_over_on", "ratio", ">=", 0.9),
        # serving throughput vs recorded baseline (wide band: single-tick
        # CPU-smoke timing swings ~20% run-to-run)
        Gate("tick_req_per_s", "walltime", higher_is_better=True, band=0.5),
    )

    def config(self, mode: str) -> Dict[str, Any]:
        cfg = super().config(mode)
        cfg["smoke"] = mode == "smoke"
        cfg["overload"] = True
        return cfg

    def evaluate(self, cfg, gen) -> Dict[str, Any]:
        from benchmarks import bench_serving

        return bench_serving.measure(
            smoke=cfg["smoke"], overload=cfg["overload"]
        )

    def report(self, cfg, raw) -> Result:
        lat = raw.get("latency", {})
        ov = raw.get("overload") or {}
        olat = ov.get("latency", {})
        counters = {
            "repeat_tick_compiles": raw["repeat_tick_compiles"],
            "repeat_tick_launches_ok": int(
                all(l == 1 for l in raw["repeat_tick_launches"])
            ),
            "repeat_tick_host_idle_us": int(raw["repeat_tick_host_idle_us"]),
            "sweep_compiles": raw["sweep_compiles"],
            "sweep_compile_budget": raw["sweep_compile_budget"],
            "sweep_within_budget": int(
                raw["sweep_compiles"] <= raw["sweep_compile_budget"]
            ),
            "latency_ok": int(
                lat.get("samples", 0) > 0
                and lat.get("p99_ms", 0) >= lat.get("p50_ms", 0) > 0
            ),
            "overload_shed": ov.get("shed", 0),
            "overload_retried": ov.get("retried", 0),
            "overload_failed": ov.get("failed", 0),
            "overload_accounting_ok": int(
                bool(ov)
                and ov["resolved"]
                == ov["submitted"] - ov["shed"] - ov["failed"]
                and olat.get("samples", 0) > 0
                and olat.get("p99_ms", 0) >= olat.get("p50_ms", 0) > 0
            ),
        }
        n16 = raw["by_batch"].get("16", {})
        overlap = raw.get("overlap", {})
        metrics = {
            "tick_req_per_s": raw["tick_req_per_s"],
            "tick_us": raw["tick_us"],
            "n16_seq_over_stacked": n16.get("seq_over_stacked", 0.0),
            "n16_seg_over_stacked": n16.get("seg_over_stacked", 0.0),
            "n16_stacked_req_per_s": n16.get("stacked_req_per_s", 0.0),
            "overlap_off_over_on": overlap.get("off_over_on", 0.0),
            "overlap_on_req_per_s": overlap.get("on_req_per_s", 0.0),
            "latency_p50_ms": lat.get("p50_ms", 0.0),
            "latency_p99_ms": lat.get("p99_ms", 0.0),
        }
        return Result(
            scenario=self.name,
            workload=self.workload,
            mode=cfg["mode"],
            backend=raw["backend"],
            graphs=["g2"],
            metrics=metrics,
            counters=counters,
        )


class ChaosScenario(Scenario):
    """Self-healing serving under a multi-site fault schedule
    (bench_chaos; DESIGN.md §14).

    All gates are invariants — no recorded-baseline entry needed: the
    contract is exact (every future accounted for, every mechanism
    witnessed, steady state restored), not a timing band."""

    name = "chaos"
    workload = "serving"
    gates = (
        # no lost futures: 100% of submits end resolved or typed-failed
        Gate("lost_futures", "invariant", "==", 0),
        Gate("untyped_failed", "invariant", "==", 0),
        Gate("accounting_ok", "invariant", "==", 1),
        # no tick blocked past the watchdog budget (+ injected delays)
        Gate("wedged_ticks", "invariant", "==", 0),
        # every self-healing mechanism witnessed at least once
        Gate("breaker_round_trips", "invariant", ">=", 1),
        Gate("watchdog_fires", "invariant", ">=", 1),
        Gate("oom_events", "invariant", ">=", 1),
        # post-fault recovery: breakers closed, caps restored, and the
        # steady-state tick back to the §7 replay contract
        Gate("final_health_healthy", "invariant", "==", 1),
        Gate("steady_state_ok", "invariant", "==", 1),
    )

    def config(self, mode: str) -> Dict[str, Any]:
        cfg = super().config(mode)
        cfg["smoke"] = mode == "smoke"
        return cfg

    def evaluate(self, cfg, gen) -> Dict[str, Any]:
        from benchmarks import bench_chaos

        return bench_chaos.measure(smoke=cfg["smoke"])

    def report(self, cfg, raw) -> Result:
        counters = {
            k: int(raw[k])
            for k in (
                "submitted",
                "resolved",
                "typed_failed",
                "untyped_failed",
                "lost_futures",
                "ticks",
                "wedged_ticks",
                "breaker_trips",
                "breaker_closes",
                "breaker_round_trips",
                "breaker_fast_fails",
                "watchdog_fires",
                "oom_events",
                "final_health_healthy",
                "steady_state_ok",
            )
        }
        counters["accounting_ok"] = int(
            raw["resolved"] + raw["typed_failed"] + raw["untyped_failed"]
            == raw["submitted"]
            and raw["lost_futures"] == 0
        )
        counters["steady_compiles"] = int(raw["steady_state"]["compiles"])
        counters["steady_launches"] = int(raw["steady_state"]["launches"])
        return Result(
            scenario=self.name,
            workload=self.workload,
            mode=cfg["mode"],
            backend=raw["backend"],
            graphs=["g2"],
            metrics={"wedge_budget_s": raw["wedge_budget_s"]},
            counters=counters,
        )


class CholeskyScenario(Scenario):
    """Task-flow config sweep C1-C6 analog (bench_cholesky; paper Fig. 3a).

    The paper's parity claim, continuously measured: throughput through
    every graph tracks the direct factorization.  Gated on the largest
    measured size via the mode-independent ``*_max`` aliases."""

    name = "cholesky"
    workload = "linalg"
    gates = (
        Gate("direct_gf_max", "walltime", higher_is_better=True, band=0.5),
        Gate("g2_gf_max", "walltime", higher_is_better=True, band=0.5),
        Gate(
            "g2_over_direct_time_ratio", "walltime",
            higher_is_better=False, band=0.5,
        ),
    )

    def config(self, mode: str) -> Dict[str, Any]:
        cfg = super().config(mode)
        cfg["quick"] = mode == "smoke"
        return cfg

    def evaluate(self, cfg, gen) -> Dict[str, Any]:
        from benchmarks import bench_cholesky

        return bench_cholesky.measure(quick=cfg["quick"])

    def report(self, cfg, raw) -> Result:
        from benchmarks.bench_cholesky import GRAPHS

        metrics = {
            "direct_gf_max": raw["direct_gf_max"],
            "g2_over_direct_time_ratio": raw["g2_over_direct_time_ratio"],
        }
        for g in GRAPHS:
            metrics[f"{g}_gf_max"] = raw[f"{g}_gf_max"]
        for key, entry in raw["by_config"].items():
            metrics[f"{key}_us"] = entry["s"] * 1e6
        return Result(
            scenario=self.name,
            workload=self.workload,
            mode=cfg["mode"],
            backend=raw["backend"],
            graphs=list(GRAPHS),
            metrics=metrics,
            counters={"n_max": raw["n_max"], "p_max": raw["p_max"]},
        )


class LmScenario(Scenario):
    """LM-side parity: train-step + serve-engine throughput (bench_lm)."""

    name = "lm"
    workload = "lm"
    gates = (
        Gate("train_tok_per_s", "walltime", higher_is_better=True, band=0.5),
        Gate(
            "serve_us_per_token", "walltime",
            higher_is_better=False, band=0.5,
        ),
    )

    def config(self, mode: str) -> Dict[str, Any]:
        cfg = super().config(mode)
        cfg["quick"] = mode == "smoke"
        return cfg

    def evaluate(self, cfg, gen) -> Dict[str, Any]:
        from benchmarks import bench_lm

        return bench_lm.measure(quick=cfg["quick"])

    def report(self, cfg, raw) -> Result:
        metrics = {
            k: raw[k]
            for k in (
                "train_step_direct_us",
                "train_tok_per_s",
                "train_step_utp_fused_us",
                "utp_over_direct_ratio",
                "serve_us_per_token",
                "serve_tok_per_s",
            )
        }
        return Result(
            scenario=self.name,
            workload=self.workload,
            mode=cfg["mode"],
            backend=raw["backend"],
            graphs=["fused"],
            metrics=metrics,
            counters={"serve_tokens": raw["serve_tokens"]},
        )


register(OverheadScenario())
register(ServingScenario())
register(ChaosScenario())
register(CholeskyScenario())
register(LmScenario())

__all__ = [
    "ChaosScenario",
    "CholeskyScenario",
    "LmScenario",
    "OverheadScenario",
    "ServingScenario",
]
