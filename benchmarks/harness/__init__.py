"""TaPS-style evaluation harness (DESIGN.md §13).

A declarative scenario registry over the repo's benchmarks: every
scenario runs config -> generate -> evaluate -> report and produces ONE
unified ``Result`` record (backend, workload, graphs, mode, metrics,
counters) appended to the longitudinal trend file ``BENCH_trend.jsonl``.
A committed ``BENCH_baseline.json`` holds per-scenario reference metrics
with tolerance bands; ``python -m benchmarks.harness check`` diffs a run
(fresh or recorded) against it and exits nonzero on regression:

    python -m benchmarks.harness list                 # registered scenarios
    python -m benchmarks.harness run   --mode smoke   # run + append trend
    python -m benchmarks.harness check --mode smoke   # run + gate (CI)
    python -m benchmarks.harness rebaseline --mode smoke

Gating policy (the machine-checked perf contract):
  * invariant gates — exact comparisons on counters (e.g.
    ``repeat_tick_compiles == 0``); no baseline involved,
  * ratio gates — fixed thresholds on dimensionless ratios (e.g.
    ``n16_seq_over_stacked >= 1.0``); interleaved A/B ratios are robust
    to machine drift so they gate exactly,
  * walltime gates — compared against the recorded baseline within a
    configurable tolerance band (default ±25%, ``--band``), because CI
    boxes vary; improvements beyond the band pass and are reported.
"""

from .baseline import (
    BASELINE_PATH,
    BaselineError,
    Finding,
    MissingBaselineError,
    MissingScenarioError,
    check_result,
    load_baseline,
    save_baseline,
    summarize,
)
from .record import (
    SCHEMA_VERSION,
    TREND_PATH,
    Result,
    append_trend,
    read_trend,
    validate_line,
)
from .scenario import REGISTRY, Gate, Scenario, register

__all__ = [
    "BASELINE_PATH",
    "BaselineError",
    "Finding",
    "Gate",
    "MissingBaselineError",
    "MissingScenarioError",
    "REGISTRY",
    "Result",
    "SCHEMA_VERSION",
    "Scenario",
    "TREND_PATH",
    "append_trend",
    "check_result",
    "load_baseline",
    "read_trend",
    "register",
    "save_baseline",
    "summarize",
    "validate_line",
]
