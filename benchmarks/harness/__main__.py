"""CLI for the evaluation harness (DESIGN.md §13).

    python -m benchmarks.harness list
    python -m benchmarks.harness run        [--mode smoke|full] [--scenario S]*
    python -m benchmarks.harness check      [--mode ...] [--scenario S]*
                                            [--baseline PATH] [--record PATH]
                                            [--band F] [--report PATH]
                                            [--no-trend]
    python -m benchmarks.harness rebaseline [--mode ...] [--scenario S]*
                                            [--baseline PATH] [--band F]

``check`` runs the selected scenarios (or loads pre-recorded trend lines
via ``--record``, which is how CI's synthetic-regression negative test
feeds a tampered record back through the differ), appends unified records
to ``BENCH_trend.jsonl``, evaluates every declared gate against the
committed ``BENCH_baseline.json``, writes the findings artifact
(``BENCH_report.json``) and exits nonzero on any failing gate.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from .baseline import (
    BASELINE_PATH,
    DEFAULT_BAND,
    MissingBaselineError,
    check_result,
    load_baseline,
    save_baseline,
    summarize,
)
from .record import Result, append_trend, read_trend
from .scenario import MODES, REGISTRY

REPORT_PATH = "BENCH_report.json"


def _select(names: List[str]) -> Dict[str, object]:
    # import registers the built-in scenarios
    from . import scenarios  # noqa: F401

    if not names:
        return dict(REGISTRY)
    unknown = [n for n in names if n not in REGISTRY]
    if unknown:
        raise SystemExit(
            f"unknown scenario(s) {unknown}; have {sorted(REGISTRY)}"
        )
    return {n: REGISTRY[n] for n in names}


def _run_scenarios(selected, mode: str, trend: bool) -> List[Result]:
    results = []
    for name, sc in sorted(selected.items()):
        print(f"## harness run: {name} [{mode}]")
        r = sc.run(mode)
        if trend:
            append_trend(r)
        results.append(r)
    return results


def _load_record(path: str, selected, mode: str) -> List[Result]:
    """Results for ``check --record``: the latest trend line per selected
    scenario at the requested mode."""
    latest: Dict[str, Result] = {}
    for r in read_trend(path):
        if r.scenario in selected and r.mode == mode:
            latest[r.scenario] = r
    missing = sorted(set(selected) - set(latest))
    if missing:
        raise SystemExit(
            f"{path}: no {mode!r} record for scenario(s) {missing}"
        )
    return [latest[n] for n in sorted(latest)]


def cmd_list(args) -> int:
    selected = _select(args.scenario)
    for name, sc in sorted(selected.items()):
        kinds = {}
        for g in sc.gates:
            kinds[g.kind] = kinds.get(g.kind, 0) + 1
        gates = ", ".join(f"{v} {k}" for k, v in sorted(kinds.items()))
        print(f"{name:10s} workload={sc.workload:8s} gates: {gates or 'none'}")
    return 0


def cmd_run(args) -> int:
    selected = _select(args.scenario)
    results = _run_scenarios(selected, args.mode, trend=not args.no_trend)
    for r in results:
        print(
            f"# recorded {r.scenario} [{r.mode}]: "
            f"{len(r.metrics)} metrics, {len(r.counters)} counters"
        )
    return 0


def cmd_check(args) -> int:
    selected = _select(args.scenario)
    if args.record:
        results = _load_record(args.record, selected, args.mode)
    else:
        results = _run_scenarios(selected, args.mode, trend=not args.no_trend)

    try:
        baseline = load_baseline(args.baseline)
    except MissingBaselineError as e:
        print(f"harness check: {e}", file=sys.stderr)
        return 2

    findings = []
    for r in results:
        findings.extend(
            check_result(r, baseline, selected[r.scenario].gates,
                         default_band=args.band)
        )
    ok, text = summarize(findings)
    print(text)
    report = {
        "mode": args.mode,
        "ok": ok,
        "scenarios": sorted(r.scenario for r in results),
        "findings": [f.to_dict() for f in findings],
    }
    with open(args.report, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.report}")
    return 0 if ok else 1


def cmd_rebaseline(args) -> int:
    selected = _select(args.scenario)
    results = _run_scenarios(selected, args.mode, trend=True)
    save_baseline(results, path=args.baseline, band_default=args.band)
    print(
        f"# rebaselined {sorted(selected)} [{args.mode}] -> {args.baseline}"
    )
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.harness")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p, band_default=None):
        p.add_argument("--mode", choices=MODES, default="smoke")
        p.add_argument(
            "--scenario", action="append", default=[],
            help="restrict to this scenario (repeatable)",
        )
        p.add_argument("--baseline", default=BASELINE_PATH)
        p.add_argument("--band", type=float, default=band_default)

    p = sub.add_parser("list", help="list registered scenarios")
    p.add_argument("--scenario", action="append", default=[])
    p.set_defaults(fn=cmd_list)

    p = sub.add_parser("run", help="run scenarios, append trend records")
    common(p)
    p.add_argument("--no-trend", action="store_true")
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser(
        "check", help="run (or load --record) and diff against baseline"
    )
    common(p)
    p.add_argument(
        "--record", default=None,
        help="diff pre-recorded trend lines from this file instead of running",
    )
    p.add_argument("--report", default=REPORT_PATH)
    p.add_argument("--no-trend", action="store_true")
    p.set_defaults(fn=cmd_check)

    p = sub.add_parser("rebaseline", help="re-record the baseline (reviewed)")
    common(p, band_default=DEFAULT_BAND)
    p.set_defaults(fn=cmd_rebaseline)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
