"""Scenario contract + registry (DESIGN.md §13).

A ``Scenario`` is one benchmark case expressed as the TaPS-style hook
pipeline ``config -> generate -> evaluate -> report``:

  * ``config(mode)``   — the declarative knobs for "smoke" or "full",
  * ``generate(cfg)``  — build inputs / prepare state (may be a no-op
    when the wrapped bench generates its own inputs),
  * ``evaluate(cfg, gen)`` — run the measurement, return the raw report
    dict (the ported benches reuse their existing ``measure`` code here),
  * ``report(cfg, raw)``   — map the raw report into the unified
    ``Result`` record (metrics + counters) that feeds the trend file.

``gates`` declares the scenario's machine-checked contract; the baseline
differ (``harness.baseline``) evaluates them against a run.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

from .record import Result

MODES = ("smoke", "full")


@dataclass(frozen=True)
class Gate:
    """One gated quantity of a scenario's result.

    kind:
      * ``invariant`` — exact comparison of a counter against ``value``
        (``op`` one of ==, <=, >=).  Baseline-independent.
      * ``ratio``     — fixed-threshold comparison of a metric against
        ``value`` (dimensionless interleaved-A/B ratios: robust to
        machine drift, so they gate exactly too).
      * ``walltime``  — band comparison of a metric against the recorded
        baseline value: fails only beyond ``band`` (or the check's
        default band) in the bad direction given by
        ``higher_is_better``; beyond-band improvements pass, reported.
    """

    metric: str
    kind: str  # "invariant" | "ratio" | "walltime"
    op: str = "=="  # invariant/ratio comparison operator
    value: Optional[float] = None  # invariant/ratio reference
    band: Optional[float] = None  # walltime band override (fraction)
    higher_is_better: bool = True  # walltime regression direction

    def __post_init__(self):
        if self.kind not in ("invariant", "ratio", "walltime"):
            raise ValueError(f"unknown gate kind: {self.kind}")
        if self.kind in ("invariant", "ratio"):
            if self.value is None:
                raise ValueError(f"{self.kind} gate {self.metric} needs value")
            if self.op not in ("==", "<=", ">="):
                raise ValueError(f"unknown gate op: {self.op}")

    def source(self) -> str:
        return "counters" if self.kind == "invariant" else "metrics"


class Scenario:
    """Base scenario: subclass and override the four hooks."""

    name: str = ""
    workload: str = ""
    gates: Tuple[Gate, ...] = ()

    def config(self, mode: str) -> Dict[str, Any]:
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r} (want one of {MODES})")
        return {"mode": mode}

    def generate(self, cfg: Dict[str, Any]) -> Any:
        return None

    def evaluate(self, cfg: Dict[str, Any], gen: Any) -> Dict[str, Any]:
        raise NotImplementedError

    def report(self, cfg: Dict[str, Any], raw: Dict[str, Any]) -> Result:
        raise NotImplementedError

    def run(self, mode: str) -> Result:
        """The full pipeline; what ``harness run/check/rebaseline`` call."""
        cfg = self.config(mode)
        raw = self.evaluate(cfg, self.generate(cfg))
        result = self.report(cfg, raw)
        missing = [
            g.metric
            for g in self.gates
            if g.kind != "walltime"
            and g.metric not in getattr(result, g.source())
        ]
        if missing:
            raise ValueError(
                f"scenario {self.name}: report() dropped gated keys: "
                f"{missing}"
            )
        return result


REGISTRY: Dict[str, Scenario] = {}


def register(scenario: Scenario) -> Scenario:
    if not scenario.name:
        raise ValueError("scenario needs a name")
    if scenario.name in REGISTRY:
        raise ValueError(f"duplicate scenario name: {scenario.name}")
    REGISTRY[scenario.name] = scenario
    return scenario
