"""Baseline recording + regression diffing (DESIGN.md §13).

``BENCH_baseline.json`` is the committed per-scenario reference: for each
(scenario, mode) it stores the metrics and counters of a recorded run.
``check_result`` diffs a fresh (or recorded) ``Result`` against it under
the scenario's declared gates and returns ``Finding``s; any finding with
``is_failure`` set fails the check.  Rebaselining is an explicit,
reviewed act: ``python -m benchmarks.harness rebaseline`` rewrites the
file from a fresh full run and the diff lands in the PR.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .record import Result
from .scenario import Gate

BASELINE_PATH = "BENCH_baseline.json"
BASELINE_SCHEMA = 1
DEFAULT_BAND = 0.25

# finding statuses that fail a check
_FAILING = (
    "regression",
    "invariant_violated",
    "missing_metric",
    "missing_baseline",
)


class BaselineError(Exception):
    """Base for baseline-handling failures."""


class MissingBaselineError(BaselineError):
    """No baseline file — record one with ``harness rebaseline``."""


class MissingScenarioError(BaselineError):
    """The baseline has no entry for this (scenario, mode)."""


@dataclass
class Finding:
    """One gate evaluation: what was compared, what happened."""

    scenario: str
    metric: str
    kind: str  # gate kind, or "schema"
    status: str  # ok | improvement | regression | invariant_violated
    #              | missing_metric | missing_baseline | new_metric
    current: Optional[float] = None
    reference: Optional[float] = None
    band: Optional[float] = None
    detail: str = ""

    @property
    def is_failure(self) -> bool:
        return self.status in _FAILING

    def to_dict(self) -> Dict[str, Any]:
        return {
            "scenario": self.scenario,
            "metric": self.metric,
            "kind": self.kind,
            "status": self.status,
            "current": self.current,
            "reference": self.reference,
            "band": self.band,
            "detail": self.detail,
        }


def load_baseline(path: str = BASELINE_PATH) -> Dict[str, Any]:
    if not os.path.exists(path):
        raise MissingBaselineError(
            f"{path} not found — record one with "
            f"`python -m benchmarks.harness rebaseline`"
        )
    with open(path) as f:
        base = json.load(f)
    if base.get("schema") != BASELINE_SCHEMA:
        raise BaselineError(
            f"{path}: schema {base.get('schema')} != {BASELINE_SCHEMA}"
        )
    return base


def save_baseline(
    results: Sequence[Result],
    path: str = BASELINE_PATH,
    band_default: float = DEFAULT_BAND,
) -> Dict[str, Any]:
    """Write (or merge into) the baseline file.

    Existing (scenario, mode) entries not re-recorded in ``results`` are
    preserved, so ``rebaseline --mode smoke`` does not wipe the full-mode
    references."""
    try:
        base = load_baseline(path)
    except BaselineError:
        base = {"schema": BASELINE_SCHEMA, "scenarios": {}}
    base["band_default"] = band_default
    base["recorded_t"] = time.time()
    for r in results:
        entry = base["scenarios"].setdefault(r.scenario, {})
        entry[r.mode] = {
            "backend": r.backend,
            "t": r.t,
            "metrics": {k: float(v) for k, v in r.metrics.items()},
            "counters": {k: int(v) for k, v in r.counters.items()},
        }
    with open(path, "w") as f:
        json.dump(base, f, indent=2, sort_keys=True)
        f.write("\n")
    return base


def _baseline_entry(
    base: Dict[str, Any], scenario: str, mode: str
) -> Dict[str, Any]:
    scenarios = base.get("scenarios", {})
    if scenario not in scenarios:
        raise MissingScenarioError(
            f"baseline has no scenario {scenario!r} — rebaseline to add it"
        )
    if mode not in scenarios[scenario]:
        raise MissingScenarioError(
            f"baseline scenario {scenario!r} has no {mode!r} record — "
            f"rebaseline --mode {mode} to add it"
        )
    return scenarios[scenario][mode]


def _cmp(op: str, a: float, b: float) -> bool:
    if op == "==":
        return a == b
    if op == "<=":
        return a <= b
    return a >= b  # ">="


def check_result(
    result: Result,
    baseline: Dict[str, Any],
    gates: Sequence[Gate],
    default_band: float = None,
) -> List[Finding]:
    """Evaluate every gate of one scenario result; returns all findings
    (passing gates included, status "ok"/"improvement"), so the report
    artifact documents what was checked, not only what failed."""
    findings: List[Finding] = []
    name = result.scenario
    if default_band is None:
        default_band = baseline.get("band_default", DEFAULT_BAND)

    entry = None
    if any(g.kind == "walltime" for g in gates):
        try:
            entry = _baseline_entry(baseline, name, result.mode)
        except MissingScenarioError as e:
            findings.append(
                Finding(name, "*", "walltime", "missing_baseline", detail=str(e))
            )

    for g in gates:
        section = result.counters if g.kind == "invariant" else result.metrics
        if g.metric not in section:
            findings.append(
                Finding(
                    name, g.metric, g.kind, "missing_metric",
                    detail=f"run did not record {g.source()}.{g.metric}",
                )
            )
            continue
        cur = float(section[g.metric])

        if g.kind in ("invariant", "ratio"):
            ok = _cmp(g.op, cur, float(g.value))
            findings.append(
                Finding(
                    name, g.metric, g.kind,
                    "ok" if ok else (
                        "invariant_violated" if g.kind == "invariant"
                        else "regression"
                    ),
                    current=cur, reference=float(g.value),
                    detail=f"{g.metric} {cur:g} {g.op} {g.value:g}"
                    + ("" if ok else " VIOLATED"),
                )
            )
            continue

        # walltime: band comparison against the recorded baseline
        if entry is None:
            continue  # missing_baseline already recorded once
        ref = entry.get("metrics", {}).get(g.metric)
        if ref is None:
            findings.append(
                Finding(
                    name, g.metric, g.kind, "missing_baseline",
                    current=cur,
                    detail=f"baseline entry lacks metrics.{g.metric}",
                )
            )
            continue
        ref = float(ref)
        band = g.band if g.band is not None else default_band
        lo, hi = ref * (1.0 - band), ref * (1.0 + band)
        if g.higher_is_better:
            status = (
                "regression" if cur < lo
                else "improvement" if cur > hi
                else "ok"
            )
        else:
            status = (
                "regression" if cur > hi
                else "improvement" if cur < lo
                else "ok"
            )
        arrow = "higher" if g.higher_is_better else "lower"
        findings.append(
            Finding(
                name, g.metric, g.kind, status,
                current=cur, reference=ref, band=band,
                detail=(
                    f"{g.metric} {cur:g} vs baseline {ref:g} "
                    f"(band ±{band:.0%}, {arrow} is better)"
                ),
            )
        )
    return findings


def summarize(findings: Sequence[Finding]) -> Tuple[bool, str]:
    """(ok, human summary) over all findings of a check."""
    fails = [f for f in findings if f.is_failure]
    improvements = [f for f in findings if f.status == "improvement"]
    lines = []
    for f in fails:
        lines.append(f"FAIL  {f.scenario}.{f.metric}: {f.status} — {f.detail}")
    for f in improvements:
        lines.append(f"  ++  {f.scenario}.{f.metric}: {f.detail}")
    n_ok = sum(1 for f in findings if f.status == "ok")
    lines.append(
        f"{len(findings)} gates: {n_ok} ok, {len(improvements)} improved, "
        f"{len(fails)} failed"
    )
    return (not fails), "\n".join(lines)
