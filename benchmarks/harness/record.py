"""Unified benchmark result record + the longitudinal trend file.

Every scenario run produces one ``Result``; serialized as a single JSON
line it is appended to ``BENCH_trend.jsonl`` — the append-only,
machine-readable perf trajectory of the repo (TaPS-style; DESIGN.md §13).
``validate_line`` is the schema contract CI gates on: a bench that stops
emitting a tracked key fails loudly instead of silently dropping out of
the trend.
"""

from __future__ import annotations

import json
import numbers
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

SCHEMA_VERSION = 1
TREND_PATH = "BENCH_trend.jsonl"

# every trend line must carry exactly these top-level keys
REQUIRED_KEYS = (
    "schema",
    "t",
    "scenario",
    "workload",
    "backend",
    "mode",
    "graphs",
    "metrics",
    "counters",
)


@dataclass
class Result:
    """One scenario run: identity + numeric metrics + exact counters.

    ``metrics`` hold measured quantities (times, throughputs, ratios —
    floats, band- or threshold-gated); ``counters`` hold exact integers
    (compile/launch/group counts, derived 0/1 invariant witnesses —
    gated with exact comparisons).
    """

    scenario: str
    workload: str
    mode: str  # "smoke" | "full"
    backend: str
    graphs: Sequence[str]
    metrics: Dict[str, float]
    counters: Dict[str, int]
    t: Optional[float] = None
    schema: int = SCHEMA_VERSION
    extra: Dict[str, Any] = field(default_factory=dict)  # not gated, kept

    def __post_init__(self):
        if self.t is None:
            self.t = time.time()

    def to_line(self) -> Dict[str, Any]:
        d = {
            "schema": self.schema,
            "t": self.t,
            "scenario": self.scenario,
            "workload": self.workload,
            "backend": self.backend,
            "mode": self.mode,
            "graphs": list(self.graphs),
            "metrics": {k: float(v) for k, v in self.metrics.items()},
            "counters": {k: int(v) for k, v in self.counters.items()},
        }
        if self.extra:
            d["extra"] = self.extra
        return d

    @classmethod
    def from_line(cls, d: Dict[str, Any]) -> "Result":
        errors = validate_line(d)
        if errors:
            raise ValueError(
                "invalid trend record: " + "; ".join(errors)
            )
        return cls(
            scenario=d["scenario"],
            workload=d["workload"],
            mode=d["mode"],
            backend=d["backend"],
            graphs=list(d["graphs"]),
            metrics=dict(d["metrics"]),
            counters=dict(d["counters"]),
            t=d["t"],
            schema=d["schema"],
            extra=dict(d.get("extra", {})),
        )


def validate_line(d: Any) -> List[str]:
    """Schema-check one trend record; returns a list of problems (empty
    means valid).  Kept as data-in/problems-out so both the CI gate and
    the unit tests drive it directly."""
    if not isinstance(d, dict):
        return [f"record is {type(d).__name__}, not an object"]
    problems = [f"missing key: {k}" for k in REQUIRED_KEYS if k not in d]
    if problems:
        return problems
    if d["schema"] != SCHEMA_VERSION:
        problems.append(
            f"schema {d['schema']} != supported {SCHEMA_VERSION}"
        )
    for k in ("scenario", "workload", "backend", "mode"):
        if not isinstance(d[k], str) or not d[k]:
            problems.append(f"{k} must be a non-empty string")
    if d.get("mode") not in ("smoke", "full", None) and isinstance(
        d.get("mode"), str
    ):
        pass  # free-form modes allowed; smoke/full are the conventional two
    if not isinstance(d["graphs"], (list, tuple)):
        problems.append("graphs must be a list")
    if not isinstance(d["t"], numbers.Real):
        problems.append("t must be a number")
    for section, want_int in (("metrics", False), ("counters", True)):
        sec = d[section]
        if not isinstance(sec, dict):
            problems.append(f"{section} must be an object")
            continue
        for k, v in sec.items():
            if not isinstance(v, numbers.Real) or isinstance(v, bool):
                problems.append(f"{section}.{k} is not numeric: {v!r}")
            elif want_int and int(v) != v:
                problems.append(f"counters.{k} is not an integer: {v!r}")
    return problems


def append_trend(result: Result, path: str = TREND_PATH) -> Dict[str, Any]:
    """Append one schema-valid line; returns the written record."""
    line = result.to_line()
    problems = validate_line(line)
    if problems:
        raise ValueError(
            f"refusing to append invalid trend line: {'; '.join(problems)}"
        )
    with open(path, "a") as f:
        f.write(json.dumps(line, sort_keys=True) + "\n")
    return line


def read_trend(path: str = TREND_PATH) -> List[Result]:
    """Parse a trend file into ``Result`` records (raises on bad lines)."""
    out = []
    with open(path) as f:
        for ln, raw in enumerate(f, 1):
            raw = raw.strip()
            if not raw:
                continue
            try:
                d = json.loads(raw)
            except ValueError as e:
                raise ValueError(f"{path}:{ln}: not JSON: {e}") from None
            out.append(Result.from_line(d))
    return out
