"""Paper Fig. 3(a) analog: Cholesky through task-flow configs C1-C6.

Single computing node (here: the local CPU device), UTP graphs:
    direct    monolithic jnp.linalg.cholesky (the "framework-only" bar)
    g1        D -> cpuBLAS (eager leaf tasks)
    g2        D -> SuperGlue-analog wave batching -> jnp leaves
    g2p       D -> wave batching -> Pallas tile kernels (interpret on CPU)

Derived column: GFLOP/s (n^3/3).  The paper's claim re-validated: the UTP
layer's throughput tracks the direct execution (no material overhead), and
wave batching >= eager dispatch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import spd_matrix
from repro.linalg import run_cholesky

from .common import chol_flops, row, timeit


GRAPHS = ("g1", "g2", "g2p")


def measure(quick: bool = True) -> dict:
    """Run the config-sweep measurement; returns the raw report dict
    (seconds + GF/s per (graph, n) plus ``*_max`` aliases for the largest
    size, which are the mode-independent keys the harness gates on;
    DESIGN.md §13)."""
    sizes = [(256, 4), (512, 8)] if quick else [(512, 8), (1024, 8), (2048, 16)]
    report = {"bench": "cholesky", "backend": jax.default_backend(),
              "sizes": sizes, "by_config": {}}
    for n, p in sizes:
        a = spd_matrix(n)
        t = timeit(lambda: jnp.linalg.cholesky(a))
        row(f"cholesky_direct_n{n}", t, f"{chol_flops(n)/t/1e9:.2f}GF/s")
        report["by_config"][f"direct_n{n}"] = {
            "s": t, "gf": chol_flops(n) / t / 1e9,
        }
        for graph in GRAPHS:
            parts = ((p, p),)
            t = timeit(lambda g=graph: run_cholesky(a, graph=g, partitions=parts),
                       warmup=1, iters=2)
            row(
                f"cholesky_{graph}_n{n}_p{p}",
                t,
                f"{chol_flops(n)/t/1e9:.2f}GF/s",
            )
            report["by_config"][f"{graph}_n{n}_p{p}"] = {
                "s": t, "gf": chol_flops(n) / t / 1e9,
            }
    n, p = sizes[-1]
    report["n_max"], report["p_max"] = n, p
    report["direct_gf_max"] = report["by_config"][f"direct_n{n}"]["gf"]
    for graph in GRAPHS:
        report[f"{graph}_gf_max"] = (
            report["by_config"][f"{graph}_n{n}_p{p}"]["gf"]
        )
    report["g2_over_direct_time_ratio"] = (
        report["by_config"][f"g2_n{n}_p{p}"]["s"]
        / report["by_config"][f"direct_n{n}"]["s"]
    )
    return report


def main(quick: bool = True) -> None:
    measure(quick=quick)


if __name__ == "__main__":
    main()
