"""LM-side benchmarks: train-step throughput and serve-engine latency for a
reduced model (real execution on the local device), plus the UTP task-tree
step (fused) vs the direct jit step — the framework-parity claim on the LM
side."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import ARCHS
from repro.models import build_model
from repro.serving import EngineConfig, Request, ServeEngine
from repro.train import UTPTrainStep

from .common import row, timeit


def measure(quick: bool = True) -> dict:
    """Run the LM-side measurement; returns the raw report dict (the
    harness scenario's ``evaluate`` hook reuses this; DESIGN.md §13)."""
    cfg = ARCHS["qwen3-32b"].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    ocfg = optim.AdamWConfig(lr=1e-3)
    opt = optim.init(params, ocfg)
    B, S = 8, 64
    batch = {
        "tokens": jnp.ones((B, S), jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }

    @jax.jit
    def step(p, o, b):
        (l, met), g = jax.value_and_grad(lambda pp: m.loss(pp, b), has_aux=True)(p)
        return optim.update(g, o, p, ocfg)

    t = timeit(step, params, opt, batch)
    row("lm_train_step_direct", t, f"{B*S/t:.0f}tok/s")

    utp = UTPTrainStep(lambda p, b: m.loss(p, b), ocfg, microbatches=2,
                       executor="fused")
    t2 = timeit(lambda: utp(params, opt, batch), warmup=1, iters=2)
    row("lm_train_step_utp_fused_m2", t2, f"overhead={100*(t2-t)/t:+.1f}%")

    # serving: time-per-output-token across batched requests
    eng = ServeEngine(cfg, params, EngineConfig(slots=4, max_seq=128))
    rng = np.random.default_rng(0)
    for i in range(8):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8),
                           max_new_tokens=8))
    import time

    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    row("lm_serve_batched", dt / max(n_tok, 1), f"{n_tok}tok_total")
    return {
        "bench": "lm",
        "backend": jax.default_backend(),
        "batch": B,
        "seq": S,
        "train_step_direct_us": t * 1e6,
        "train_tok_per_s": B * S / t,
        "train_step_utp_fused_us": t2 * 1e6,
        "utp_over_direct_ratio": t2 / t,
        "serve_tokens": n_tok,
        "serve_us_per_token": dt / max(n_tok, 1) * 1e6,
        "serve_tok_per_s": n_tok / dt if dt > 0 else 0.0,
    }


def main(quick: bool = True) -> None:
    measure(quick=quick)


if __name__ == "__main__":
    main()
