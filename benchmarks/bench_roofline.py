"""Roofline-table reporter: aggregates benchmarks/results/<mesh>/*.json
(written by launch/dryrun.py) into the EXPERIMENTS.md tables."""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"


def load(mesh: str):
    out = []
    d = RESULTS / mesh
    if not d.exists():
        return out
    for p in sorted(d.glob("*.json")):
        out.append(json.loads(p.read_text()))
    return out


def fmt_table(mesh: str) -> str:
    rows = load(mesh)
    if not rows:
        return f"(no {mesh} results yet)"
    hdr = (
        "| arch | shape | step | compute_ms | memory_ms | coll_ms | "
        "bottleneck | useful | MFU_bound | HBM/chip_GB |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        if r.get("rules_variant", "default") != "default" or "__" in r.get("tag", ""):
            continue
        mem = r.get("memory") or {}
        hbm = mem.get("total")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} "
            f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
            f"| {r['collective_s']*1e3:.1f} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.2f} | {r['mfu_bound']:.3f} "
            f"| {hbm/1e9:.1f} |" if hbm else
            f"| {r['arch']} | {r['shape']} | {r['step']} "
            f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
            f"| {r['collective_s']*1e3:.1f} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.2f} | {r['mfu_bound']:.3f} | n/a |"
        )
    return hdr + "\n".join(lines)


def main(quick: bool = True) -> None:
    for mesh in ("pod", "multipod"):
        rows = load(mesh)
        print(f"# roofline[{mesh}]: {len(rows)} cells")
        for r in rows:
            print(
                f"roofline_{mesh}_{r['arch']}_{r['shape']},"
                f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e6:.1f},"
                f"bottleneck={r['bottleneck']};mfu_bound={r['mfu_bound']:.3f}"
            )


if __name__ == "__main__":
    main()
