"""Fault injection (DESIGN.md §10): registry semantics and the recovery
invariants at every named site.

Each site test asserts the post-failure guarantee the failure model
promises — a failed drain leaves no half-captured memo entry, the executor
and dispatcher stay reusable, corruption is detectable via ``check_finite``,
and the stacked path's value-dependent-split fallback produces the same
numerics as the healthy stacked drain.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import Dispatcher, GData, GTask, dd_matrix
from repro.core.executors import clear_compile_cache, drain_memo_stats
from repro.core.operation import OpRegistry
from repro.errors import NumericalError
from repro.linalg import run_lu
from repro.linalg.lu import _unpack
from repro.serve import BatchServer
from repro.testing import faults


@pytest.fixture(autouse=True)
def _no_leaked_faults():
    yield
    faults.reset()


# -- registry semantics --------------------------------------------------------
def test_unknown_site_rejected():
    with pytest.raises(ValueError, match="unknown fault site"):
        with faults.inject("no.such.site", RuntimeError("x")):
            pass
    with pytest.raises(ValueError, match="probability"):
        faults.Fault("leaf.fn", p=1.5)


def test_arming_scoped_to_context():
    assert not faults.active()
    with faults.inject("executor.launch", RuntimeError("boom")):
        assert faults.active()
        with pytest.raises(RuntimeError, match="boom"):
            faults.fire("executor.launch")
    assert not faults.active()
    faults.fire("executor.launch")  # disarmed: no-op


def test_times_after_and_when():
    with faults.inject(
        "executor.launch",
        RuntimeError("boom"),
        when=lambda ctx: ctx.get("batch", 0) > 1,
        after=1,
        times=1,
    ) as f:
        faults.fire("executor.launch", batch=0)  # when=False: not a match
        faults.fire("executor.launch", batch=4)  # match 1 skipped by after
        with pytest.raises(RuntimeError):
            faults.fire("executor.launch", batch=4)  # fires
        faults.fire("executor.launch", batch=4)  # times budget spent
        assert f.matches == 3 and f.fired == 1


def test_delay_injection_sleeps_at_site():
    import time

    with faults.inject("drain.stall", delay_s=0.05) as f:
        t0 = time.perf_counter()
        faults.fire("drain.stall")  # delay-only: sleeps, does NOT raise
        assert time.perf_counter() - t0 >= 0.05
        assert f.fired == 1
    # delay composes with an exception: sleep first, then raise
    with faults.inject("drain.stall", RuntimeError("late"), delay_s=0.01):
        t0 = time.perf_counter()
        with pytest.raises(RuntimeError, match="late"):
            faults.fire("drain.stall")
        assert time.perf_counter() - t0 >= 0.01
    with pytest.raises(ValueError, match="delay_s"):
        faults.Fault("drain.stall", delay_s=-1.0)


def test_self_healing_sites_registered():
    assert {"drain.stall", "launch.oom"} <= faults.KNOWN_SITES
    assert len(faults.KNOWN_SITES) == 12


def test_probabilistic_firing_is_seeded():
    def run(seed):
        hits = []
        with faults.inject(
            "executor.launch", RuntimeError("x"), p=0.5, seed=seed, times=None
        ):
            for i in range(20):
                try:
                    faults.fire("executor.launch")
                    hits.append(False)
                except RuntimeError:
                    hits.append(True)
        return hits

    a, b = run(7), run(7)
    assert a == b and 0 < sum(a) < 20  # reproducible, actually probabilistic


def test_record_probe_observes_without_perturbing():
    with faults.inject("serve.drain", record=True, times=None) as probe:
        faults.fire("serve.drain", rids=[3, 4], op="getrf", size=2)
        faults.fire("serve.drain", rids=[5], op="getrf", size=1)
    assert [e["rids"] for e in probe.log] == [[3, 4], [5]]


def test_reset_disarms_everything():
    cm = faults.inject("executor.launch", RuntimeError("x"))
    cm.__enter__()
    assert faults.active()
    faults.reset()
    assert not faults.active()
    faults.fire("executor.launch")  # no-op after reset


# -- site recovery invariants --------------------------------------------------
def _lu_ref(n, seed, parts=((2, 2),)):
    a = dd_matrix(n, seed=seed)
    l, u = run_lu(a, partitions=parts)
    return a, np.asarray(l), np.asarray(u)


def test_launch_failure_then_clean_retry():
    """A raised program launch propagates, but the very next identical
    call succeeds with correct numerics — no capture window or epoch state
    leaks out of the failed drain."""
    clear_compile_cache()
    a, rl, ru = _lu_ref(32, seed=0)
    with faults.inject("executor.launch", RuntimeError("device lost")):
        with pytest.raises(RuntimeError, match="device lost"):
            run_lu(a, partitions=((2, 2),))
    l, u = run_lu(a, partitions=((2, 2),))
    np.testing.assert_allclose(np.asarray(l), rl, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(u), ru, rtol=1e-6)


def test_leaf_kernel_failure_propagates_and_recovers():
    clear_compile_cache()  # programs must actually build for leaf.fn to hit
    a = dd_matrix(32, seed=1)
    with faults.inject("leaf.fn", RuntimeError("bad kernel")):
        with pytest.raises(RuntimeError, match="bad kernel"):
            run_lu(a, partitions=((2, 2),))
    l, u = run_lu(a, partitions=((2, 2),))
    np.testing.assert_allclose(
        np.asarray(l) @ np.asarray(u), np.asarray(a), rtol=2e-4, atol=2e-4
    )


def test_capture_failure_leaves_memo_unchanged():
    """Satellite invariant: an injected failure in the drain-memo capture
    path leaves ``drain_memo_stats()`` unchanged — no half-captured entry —
    and the next drain recompiles and memoizes cleanly."""
    clear_compile_cache()  # the injected drain must be a memo MISS
    a = dd_matrix(32, seed=2)
    with faults.inject("memo.capture", RuntimeError("capture torn")):
        with pytest.raises(RuntimeError, match="capture torn"):
            run_lu(a, partitions=((2, 2),))
    assert drain_memo_stats()["entries"] == 0  # nothing half-captured
    l, u = run_lu(a, partitions=((2, 2),))
    np.testing.assert_allclose(
        np.asarray(l) @ np.asarray(u), np.asarray(a), rtol=2e-4, atol=2e-4
    )
    assert drain_memo_stats()["entries"] == 1  # clean re-capture
    hits0 = drain_memo_stats()["hits"]
    run_lu(a, partitions=((2, 2),))
    assert drain_memo_stats()["hits"] == hits0 + 1  # and it replays


def test_memo_replay_observed_via_probe():
    clear_compile_cache()
    a = dd_matrix(32, seed=3)
    with faults.inject("executor.launch", record=True, times=None) as probe:
        run_lu(a, partitions=((2, 2),))
        run_lu(a, partitions=((2, 2),))
    replays = [e["replay"] for e in probe.log]
    assert not any(replays[: len(replays) // 2])  # first drain: fresh launches
    assert all(replays[len(replays) // 2 :])  # second drain: pure replay


def test_output_corruption_caught_by_check_finite():
    clear_compile_cache()
    a = dd_matrix(32, seed=4)
    with faults.inject("executor.output"):
        with pytest.raises(NumericalError, match="non-finite"):
            run_lu(a, partitions=((2, 2),), check_finite=True)
    # without the check, corruption flows through silently (the default
    # hot path must not pay a materializing reduce)
    with faults.inject("executor.output"):
        l, _ = run_lu(a, partitions=((2, 2),))
        assert np.isnan(np.asarray(l)).any()
    l, _ = run_lu(a, partitions=((2, 2),), check_finite=True)  # healthy again
    assert np.isfinite(np.asarray(l)).all()


def test_value_dependent_split_falls_back_with_identical_numerics():
    """Satellite invariant: forcing the collect-mode abort on a stacked
    drain falls back to the interleaved path and still produces the same
    results the stacked path would have."""
    clear_compile_cache()
    n, N = 32, 4
    mats = [dd_matrix(n, seed=s) for s in range(N)]
    srv = BatchServer(graph="g2")
    futs = [srv.lu(m, partitions=((2, 2),)) for m in mats]
    rep = srv.tick()
    assert rep.stacked_drains == 1
    stacked = [tuple(np.asarray(x) for x in f.result()) for f in futs]

    clear_compile_cache()
    srv2 = BatchServer(graph="g2")
    futs2 = [srv2.lu(m, partitions=((2, 2),)) for m in mats]
    with faults.inject("split.value_dependent", times=None) as f:
        rep2 = srv2.tick()
    assert f.fired > 0 and rep2.stacked_drains == 0  # abort -> interleaved
    assert rep2.resolved == N
    for (sl, su), f2 in zip(stacked, futs2):
        l2, u2 = f2.result()
        np.testing.assert_allclose(np.asarray(l2), sl, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(u2), su, rtol=1e-5, atol=1e-5)


def test_dispatcher_reusable_after_failed_drain():
    """The same Dispatcher instance serves a clean drain after one of its
    drains raised mid-flight."""
    clear_compile_cache()
    d = Dispatcher(graph="g2")
    op = OpRegistry.get("getrf")

    def submit(seed):
        a = dd_matrix(32, seed=seed)
        data = GData(
            a.shape, partitions=((2, 2),), dtype=a.dtype, value=a
        )
        d.submit_task(GTask(op, None, [data.root_view()]))
        return a, data

    a0, _ = submit(0)
    with faults.inject("executor.launch", RuntimeError("flaky")):
        with pytest.raises(RuntimeError, match="flaky"):
            d.run()
    a1, data1 = submit(1)
    d.run()
    l, u = _unpack(data1)
    np.testing.assert_allclose(
        np.asarray(l) @ np.asarray(u), np.asarray(a1), rtol=2e-4, atol=2e-4
    )
