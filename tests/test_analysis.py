"""Static analysis (DESIGN.md §11): hazard cross-check, plan verifier,
operation linter — and the mutation faults proving each pass detects
exactly the bug class it claims to.

Structure:
  - hazard unit tests on hand-built task streams and fabricated DAGs
  - verifier green end-to-end: every drain entry point under verify mode
  - mutation tests: each ``plan.*`` fault site must be caught with the
    right invariant name, and must be SILENT with verification off
  - the ``_StackedAbort`` fallback blind-spot regression
  - linter unit tests on deliberately broken Operations + the registry gate
"""

import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Access, Dispatcher, DepTracker, GData, GTask, Operation
from repro.core import dd_matrix, spd_matrix
from repro.core.executors import clear_compile_cache
from repro.core.versioning import TaskDag
from repro.errors import LintError, ScheduleVerificationError
from repro.analysis import (
    LostParallelismWarning,
    analyze_hazards,
    clear_verified_cache,
    lint_operation,
    lint_or_raise,
    lint_registry,
    recompute_conflicts,
    verifier_stats,
    verify_plan,
    verify_stacked_members,
)
from repro.linalg import run_cholesky, run_lu, run_lu_solve
from repro.linalg.lu import run_inv, run_lu_batched, run_lu_many, utp_getrf
from repro.testing import faults


@pytest.fixture(autouse=True)
def _clean_slate():
    clear_compile_cache()
    clear_verified_cache()
    yield
    faults.reset()


class NopOp(Operation):
    name = "nop"

    def __init__(self, modes):
        self._modes = modes

    def default_modes(self, n):
        return self._modes


def mktask(data, accesses):
    """accesses: list of ((r, c), Access)."""
    views = [data(r, c) for (r, c), _ in accesses]
    modes = [m for _, m in accesses]
    return GTask(NopOp(modes), None, views, modes)


def _tracked(tasks):
    d = DepTracker()
    for t in tasks:
        d.add(t)
    return d.dag()


# -- hazard analysis unit tests ------------------------------------------------
def test_recompute_conflicts_kinds():
    A = GData((4, 4), partitions=((2, 2),))
    w1 = mktask(A, [((0, 0), Access.WRITE)])
    r1 = mktask(A, [((0, 0), Access.READ)])
    w2 = mktask(A, [((0, 0), Access.WRITE)])
    kinds = {
        (c.pred, c.succ): c.kind for c in recompute_conflicts([w1, r1, w2])
    }
    assert kinds[(w1.id, r1.id)] == "RAW"
    assert kinds[(r1.id, w2.id)] == "WAR"
    assert kinds[(w1.id, w2.id)] == "WAW"


def test_hazards_clean_on_tracker_dag():
    A = GData((8, 8), partitions=((2, 2),))
    tasks = [
        mktask(A, [((0, 0), Access.WRITE)]),
        mktask(A, [((0, 0), Access.READ), ((0, 1), Access.WRITE)]),
        mktask(A, [((0, 1), Access.READWRITE)]),
        mktask(A, [((1, 1), Access.WRITE)]),  # independent of the rest
    ]
    report = analyze_hazards(tasks, _tracked(tasks))
    assert report.ok and not report.spurious
    assert report.n_conflicts >= 2


def test_hazards_transitively_implied_edge_is_not_a_race():
    # w1 -> w2 -> w3 WAW chain: the tracker records only last-writer edges
    # (w1->w2, w2->w3); the recomputed conflict (w1, w3) must be accepted
    # through the PATH, not demand a direct edge.
    A = GData((4, 4), partitions=((2, 2),))
    tasks = [mktask(A, [((0, 0), Access.WRITE)]) for _ in range(3)]
    dag = _tracked(tasks)
    assert tasks[2].id not in dag.edges.get(tasks[0].id, set())
    assert analyze_hazards(tasks, dag).ok


def test_hazards_missing_edge_is_a_race():
    A = GData((4, 4), partitions=((2, 2),))
    w = mktask(A, [((0, 0), Access.WRITE)])
    r = mktask(A, [((0, 0), Access.READ)])
    dag = TaskDag({w.id: w, r.id: r}, {}, {})  # tracker "forgot" the edge
    with pytest.raises(ScheduleVerificationError) as ei:
        analyze_hazards([w, r], dag)
    assert ei.value.site == "hazards"
    assert ei.value.pair == (w.id, r.id)
    report = analyze_hazards([w, r], dag, raise_on_race=False)
    assert not report.ok and report.races[0].kind == "RAW"


def test_hazards_spurious_edge_warns_lost_parallelism():
    A = GData((4, 4), partitions=((2, 2),))
    t1 = mktask(A, [((0, 0), Access.WRITE)])
    t2 = mktask(A, [((1, 1), Access.WRITE)])  # disjoint: truly independent
    dag = TaskDag(
        {t1.id: t1, t2.id: t2},
        {t1.id: {t2.id}},
        {t2.id: {t1.id}},
    )
    with pytest.warns(LostParallelismWarning):
        report = analyze_hazards([t1, t2], dag)
    assert report.ok  # pessimal, not racy
    assert report.spurious == [(t1.id, t2.id)]


def test_stacked_member_alias_rejected():
    A = GData((4, 4), partitions=((2, 2),), value=jnp.zeros((4, 4)))
    B = GData((4, 4), partitions=((2, 2),), value=jnp.zeros((4, 4)))
    verify_stacked_members([[A, B]])
    with pytest.raises(ScheduleVerificationError) as ei:
        verify_stacked_members([[A, A]])
    assert ei.value.site == "verify_stacked.lane_alias"


# -- verifier green end-to-end -------------------------------------------------
def _drain_lu(d, n=64, seed=0):
    a = dd_matrix(n, seed=seed)
    A = GData(a.shape, partitions=((4, 4),), dtype=a.dtype, value=jnp.asarray(a))
    utp_getrf(d, A)
    d.run()
    return A


@pytest.mark.parametrize("graph", ["g1", "g2", "g2p"])
def test_verify_green_lu_all_graphs(graph):
    d = Dispatcher(graph=graph, verify=True)
    _drain_lu(d)
    assert d.stats["verified_scopes"] >= 1
    assert d.executor.verify


@pytest.mark.parametrize(
    "run",
    [
        lambda a: run_lu(a),
        lambda a: run_cholesky(spd_matrix(64, seed=0)),
        lambda a: run_lu_solve(a, np.asarray(dd_matrix(64, seed=9))[:, :32]),
        lambda a: run_inv(a),
    ],
    ids=["run_lu", "run_cholesky", "run_lu_solve", "run_inv"],
)
def test_verify_green_drains_env(run, monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "1")
    before = verifier_stats()["verified"]
    run(dd_matrix(64, seed=3))
    assert verifier_stats()["verified"] > before


def test_verify_green_cross_root_fusion(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "1")
    mats = [dd_matrix(64, seed=s) for s in range(3)]
    for (L, U), a in zip(run_lu_many(mats), mats):
        np.testing.assert_allclose(L @ U, a, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("n_roots", [1, 4, 16])
def test_verify_green_stacked(n_roots, monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "1")
    mats = [dd_matrix(32, seed=s) for s in range(n_roots)]
    for (L, U), a in zip(run_lu_batched(mats, partitions=((2, 2),)), mats):
        np.testing.assert_allclose(L @ U, a, rtol=2e-2, atol=2e-2)


def test_verify_env_off_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_VERIFY", raising=False)
    assert not Dispatcher(graph="g2").verify
    monkeypatch.setenv("REPRO_VERIFY", "0")
    assert not Dispatcher(graph="g2").verify
    monkeypatch.setenv("REPRO_VERIFY", "1")
    assert Dispatcher(graph="g2").verify


def test_verdict_cache_absorbs_structural_repeats():
    d1 = Dispatcher(graph="g2", verify=True)
    _drain_lu(d1, seed=0)
    s1 = verifier_stats()
    assert s1["verified"] >= 1
    # same structure, fresh dispatcher, drain memo cleared: the plan is
    # re-planned but its verdict comes from the structural cache
    clear_compile_cache()
    d2 = Dispatcher(graph="g2", verify=True)
    _drain_lu(d2, seed=1)
    s2 = verifier_stats()
    assert s2["cache_hits"] > s1["cache_hits"]
    assert s2["verified"] == s1["verified"]


def test_replay_skips_verification_entirely():
    d = Dispatcher(graph="g2", verify=True)
    _drain_lu(d, seed=0)
    scopes = d.stats["verified_scopes"]
    stats = verifier_stats()
    _drain_lu(d, seed=1)  # memo replay
    assert d.stats["memo_hits"] == 1
    assert d.stats["verified_scopes"] == scopes
    assert verifier_stats() == stats


# -- mutation faults: the verifier detects what it claims to -------------------
def test_mutation_drop_edge_caught():
    d = Dispatcher(graph="g2", verify=True)
    with faults.inject("plan.drop_edge") as f:
        with pytest.raises(ScheduleVerificationError) as ei:
            _drain_lu(d)
    assert f.fired == 1
    assert ei.value.site == "hazards"
    assert "race" in str(ei.value)


def test_mutation_merge_groups_caught():
    d = Dispatcher(graph="g2", verify=True)
    with faults.inject("plan.merge_groups") as f:
        with pytest.raises(ScheduleVerificationError) as ei:
            _drain_lu(d)
    assert f.fired == 1
    assert ei.value.site == "verify_plan.group_independence"
    assert len(ei.value.pair) == 2


def test_mutation_alias_lane_caught(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "1")
    mats = [dd_matrix(32, seed=s) for s in range(4)]
    with faults.inject("plan.alias_lane") as f:
        with pytest.raises(ScheduleVerificationError) as ei:
            run_lu_batched(mats, partitions=((2, 2),))
    assert f.fired == 1
    assert ei.value.site == "verify_stacked.lane_alias"


def test_mutation_silent_without_verifier():
    """The mutations inject REAL silent bugs: with verification off the
    corrupted drains complete without any error — which is exactly why the
    verifier has to exist."""
    d = Dispatcher(graph="g2", verify=False)
    with faults.inject("plan.merge_groups") as f:
        A = _drain_lu(d)
    assert f.fired == 1
    assert A.has_value  # completed; numerics are garbage, nothing raised


# -- _StackedAbort fallback blind spot (regression) ----------------------------
def test_stacked_fallback_still_verifies(monkeypatch):
    """A value-dependent split aborts the stacked collect and re-drains
    through the normal path; the verify flag lives on the EXECUTOR, so the
    fallback's schedules are still proven (the pre-fix blind spot)."""
    d = Dispatcher(graph="g2", verify=True)
    mats = [dd_matrix(32, seed=s) for s in range(4)]
    roots = []
    with faults.inject("split.value_dependent"):
        for a in mats:
            A = GData(
                a.shape, partitions=((2, 2),), dtype=a.dtype,
                value=jnp.asarray(a),
            )
            utp_getrf(d, A)
            roots.append(A)
        d.run()
    assert d.stats["stacked_drains"] == 0  # the fallback really ran
    assert d.stats["verified_scopes"] >= 1
    assert d.executor.stats["verified_plans"] >= 1


def test_stacked_fallback_catches_corrupt_plan():
    d = Dispatcher(graph="g2", verify=True)
    with faults.inject("split.value_dependent"), faults.inject(
        "plan.merge_groups"
    ):
        for s in range(4):
            a = dd_matrix(32, seed=s)
            A = GData(
                a.shape, partitions=((2, 2),), dtype=a.dtype,
                value=jnp.asarray(a),
            )
            utp_getrf(d, A)
        with pytest.raises(ScheduleVerificationError) as ei:
            d.run()
    assert ei.value.site == "verify_plan.group_independence"


# -- serving: verification failures are non-retryable --------------------------
def test_serve_verification_failure_fails_fast(monkeypatch):
    from repro.serve import BatchServer

    monkeypatch.setenv("REPRO_VERIFY", "1")
    srv = BatchServer(graph="g2", max_retries=3)
    fut = srv.lu(dd_matrix(64, seed=0))
    with faults.inject("plan.merge_groups", times=None):
        srv.tick()
    assert fut.done
    with pytest.raises(ScheduleVerificationError):
        fut.result()
    # fail-fast: no retry budget burned on a deterministic failure
    assert srv.stats["retried"] == 0 and srv.stats["failed"] == 1


# -- operation linter ----------------------------------------------------------
def test_registry_lints_clean():
    import repro.linalg.ops  # noqa: F401 — populate

    assert lint_registry(execute=True) == []
    assert lint_or_raise() >= 10


class _ValueDependentSplitOp(Operation):
    name = "_lint_bad_split"

    def default_modes(self, n):
        return [Access.READWRITE] * n

    def split(self, task, submit):
        v = task.args[0]
        if v.data.value[0, 0] > 0:  # reads values in a memoizable split
            submit(GTask(self, task, [v]))


class _RngSplitOp(Operation):
    name = "_lint_rng_split"

    def split(self, task, submit):
        import random

        if random.random() > 0.5:
            submit(GTask(self, task, [task.args[0]]))


class _BadModesOp(Operation):
    name = "_lint_bad_modes"

    def default_modes(self, n):
        return [Access.READ] * (n + 1)  # arity mismatch

    def leaf_fn(self, backend):
        return lambda a, b: a + b


class _ReadOnlyOp(Operation):
    name = "_lint_read_only"

    def default_modes(self, n):
        return [Access.READ] * n  # no write arg: no output

    def leaf_fn(self, backend):
        return lambda a: a


class _WrongOutputCountOp(Operation):
    name = "_lint_wrong_out"

    def default_modes(self, n):
        return [Access.READWRITE, Access.READ]

    def leaf_fn(self, backend):
        return lambda a, b: (a, b)  # two outputs for one write arg


def test_lint_flags_value_dependent_split():
    issues = lint_operation(_ValueDependentSplitOp())
    assert any(i.check == "L1" and ".value" in i.detail for i in issues)
    # declaring the split value-dependent silences L1 (the contract is met)
    op = _ValueDependentSplitOp()
    op.memoizable = False
    assert not [i for i in lint_operation(op) if i.check == "L1"]


def test_lint_flags_rng_split():
    issues = lint_operation(_RngSplitOp())
    assert any(i.check == "L1" and "random" in i.detail for i in issues)


def test_lint_flags_mode_arity_mismatch():
    issues = lint_operation(_BadModesOp())
    assert any(i.check == "L2" for i in issues)


def test_lint_flags_all_read_op():
    issues = lint_operation(_ReadOnlyOp())
    assert any(i.check == "L2" and "no write-mode" in i.detail for i in issues)


def test_lint_flags_wrong_output_count():
    issues = lint_operation(_WrongOutputCountOp(), execute=True)
    assert any(i.check == "L3" and "returns 2" in i.detail for i in issues)


def test_lint_error_formatting():
    issues = lint_operation(_BadModesOp())
    err = LintError(issues)
    assert err.issues == issues
    assert "_lint_bad_modes" in str(err) and "[L2]" in str(err)


def test_lint_cli_runs_clean():
    import subprocess
    import sys
    from pathlib import Path

    repo = Path(__file__).resolve().parents[1]
    out = subprocess.run(
        [sys.executable, str(repo / "scripts" / "lint_ops.py"),
         "--no-execute"],
        capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ops lint OK" in out.stdout


# -- error type context --------------------------------------------------------
def test_schedule_verification_error_context():
    e = ScheduleVerificationError("verify_plan.slot_order", "bad", pair=(3, 7))
    assert e.site == "verify_plan.slot_order"
    assert e.pair == (3, 7)
    assert "[verify_plan.slot_order]" in str(e) and "3, 7" in str(e)
