"""Dependency-exact scheduling: cross-wave fusion + lookahead (DESIGN.md §2).

Covers: the fusion-legality query (``TaskDag.independent``), hypothesis
property tests on random task DAGs (the dependency-exact schedule is a
valid topological order, every fused group is edge-free internally, and
slot-launch semantics match the sequential program order exactly),
multi-root drains (LU of A + Cholesky of B in one compiled program; LU + LU
fusing same-signature groups across roots into shared launches), lookahead
ordering inside issue slots, and the plan-time flat index array that replay
reuses device-resident.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import Access, Dispatcher, DepTracker, GData, GTask, Operation
from repro.core import dd_matrix, spd_matrix
from repro.core.executors import clear_compile_cache, plan_schedule
from repro.core.executors.jit_wave import _DRAIN_MEMO
from repro.linalg import run_cholesky, run_lu, run_lu_many
from repro.linalg.cholesky import utp_cholesky
from repro.linalg.lu import utp_getrf, utp_lu_solve, utp_solve


# --------------------------------------------------------------------------
# Fusion-legality query (versioning.TaskDag)
# --------------------------------------------------------------------------
class _Nop(Operation):
    def __init__(self, modes):
        self._modes = list(modes)
        self.name = "nop_" + "".join(m.value[0] for m in self._modes)

    def default_modes(self, n):
        return list(self._modes)


_NOPS = {}


def mktask(data, accesses):
    """accesses: list of ((r, c), Access); ops shared per modes tuple so
    same-mode tasks share a signature (as registered singletons would)."""
    modes = tuple(m for _, m in accesses)
    op = _NOPS.setdefault(modes, _Nop(modes))
    views = [data(r, c) for (r, c), _ in accesses]
    return GTask(op, None, views, list(modes))


def _track(tasks):
    tr = DepTracker()
    for t in tasks:
        tr.add(t)
    return tr


def test_independent_query_basics():
    A = GData((8, 8), partitions=((2, 2),))
    w = mktask(A, [((0, 0), Access.WRITE)])
    r = mktask(A, [((0, 0), Access.READ)])
    other = mktask(A, [((1, 1), Access.WRITE)])
    dag = _track([w, r, other]).dag()
    assert not dag.independent([w.id], [r.id])  # RAW path
    assert dag.independent([w.id], [other.id])  # disjoint blocks
    assert dag.independent([w.id, other.id], [w.id, other.id])  # edge-free set
    assert not dag.independent([w.id, r.id], [w.id, r.id])  # internal edge


def test_independent_sees_transitive_paths():
    A = GData((8, 8), partitions=((2, 2),))
    t1 = mktask(A, [((0, 0), Access.WRITE)])
    t2 = mktask(A, [((0, 0), Access.READ), ((0, 1), Access.WRITE)])
    t3 = mktask(A, [((0, 1), Access.READ), ((1, 1), Access.WRITE)])
    dag = _track([t1, t2, t3]).dag()
    assert not dag.independent([t1.id], [t3.id])  # only via t2


def test_heights_follow_critical_path():
    A = GData((8, 8), partitions=((2, 2),))
    chain = [mktask(A, [((0, 0), Access.READWRITE)]) for _ in range(3)]
    lone = mktask(A, [((1, 1), Access.WRITE)])
    dag = _track(chain + [lone]).dag()
    h = dag.heights()
    assert h[chain[0].id] == 2 and h[chain[2].id] == 0 and h[lone.id] == 0


# --------------------------------------------------------------------------
# Multi-root drains (ROADMAP item): independent workloads share one program
# --------------------------------------------------------------------------
def test_multiroot_lu_and_cholesky_one_drain():
    clear_compile_cache()
    n, p = 64, 4
    a = dd_matrix(n, seed=11)
    b = spd_matrix(n, seed=12)
    ref_l, ref_u = run_lu(a, partitions=((p, p),))
    ref_c = run_cholesky(b, partitions=((p, p),))
    clear_compile_cache()

    def drain():
        d = Dispatcher(graph="g2")
        A = GData(a.shape, partitions=((p, p),), dtype=a.dtype, value=a)
        B = GData(b.shape, partitions=((p, p),), dtype=b.dtype, value=b)
        utp_getrf(d, A)
        utp_cholesky(d, B)
        n_leaf = d.run()
        return d, A, B, n_leaf

    d1, A1, B1, n1 = drain()
    # both workloads interleave into ONE compiled program / ONE launch
    assert d1.executor.stats["launches"] == 1
    assert d1.executor.stats["compiles"] == 1
    packed = np.asarray(A1.value)
    np.testing.assert_allclose(
        np.tril(packed, -1) + np.eye(n), np.asarray(ref_l), rtol=1e-6
    )
    np.testing.assert_allclose(np.triu(packed), np.asarray(ref_u), rtol=1e-6)
    np.testing.assert_allclose(
        np.tril(np.asarray(B1.value)), np.asarray(ref_c), rtol=1e-6
    )
    # structurally repeated combined drain: memo replay, 0 recompiles
    d2, A2, B2, n2 = drain()
    assert n2 == n1
    assert d2.stats["split"] == d1.stats["split"]  # replay mirrors stats
    assert d2.executor.stats["launches"] == 1
    assert d2.executor.stats.get("compiles", 0) == 0
    np.testing.assert_allclose(np.asarray(A2.value), packed, rtol=1e-6)


def test_multiroot_lu_pair_fuses_groups_across_roots():
    clear_compile_cache()
    n, p = 64, 4
    a = dd_matrix(n, seed=21)
    b = dd_matrix(n, seed=22)
    # stack_roots=False pins the PR-3 segment-fusion path: a homogeneous
    # pair would otherwise take the stacked batched-program path
    # (DESIGN.md §7, tests/test_stacked_drain.py)
    d = Dispatcher(graph="g2", stack_roots=False)
    A = GData(a.shape, partitions=((p, p),), dtype=a.dtype, value=a)
    B = GData(b.shape, partitions=((p, p),), dtype=b.dtype, value=b)
    utp_getrf(d, A)
    utp_getrf(d, B)
    d.run()
    st = d.executor.stats
    assert st["launches"] == 1
    # the two independent LU DAGs run in SHARED launches: the fused group
    # count equals one workload's (every group carries both roots' tasks)
    # and is strictly below the pre-fusion barrier-wave group count
    assert st["groups"] < st["groups_prefusion"]
    assert st["groups_prefusion"] == 2 * st["groups"]
    # numerics match the single-root reference factorizations
    for M, m in ((A, a), (B, b)):
        packed = np.asarray(M.value)
        l = np.tril(packed, -1) + np.eye(n)
        u = np.triu(packed)
        np.testing.assert_allclose(l @ u, np.asarray(m), rtol=2e-4, atol=2e-4)


def test_run_lu_many_replays_with_zero_recompiles():
    clear_compile_cache()
    n, p = 64, 4
    mats = [dd_matrix(n, seed=s) for s in (31, 32)]
    outs1 = run_lu_many(mats, partitions=((p, p),))
    # structurally repeated multi-root drain on fresh values: pure replay
    mats2 = [dd_matrix(n, seed=s) for s in (33, 34)]
    outs2 = run_lu_many(mats2, partitions=((p, p),))
    for (l, u), m in zip(outs1 + outs2, mats + mats2):
        np.testing.assert_allclose(
            np.asarray(l) @ np.asarray(u), np.asarray(m), rtol=2e-4, atol=2e-4
        )
    # the second drain hit the drain memo (captured by the first)
    assert len(_DRAIN_MEMO) >= 1


def test_lu_solve_overlaps_solve_groups_with_factor_groups():
    """The composed factor+solve drain (DESIGN.md §4): ONE WaveProgram where
    the dependency-exact pass (a) fuses solve groups into independent
    same-signature factor groups (row-i forward substitutions share a slot
    with step-i panel solves — unlike single-root LU, the combined DAG has
    slack) and (b) schedules the pipeline in strictly fewer issue slots
    than the three barrier-separated drains need in total."""
    clear_compile_cache()
    n, p = 64, 4
    a = dd_matrix(n, seed=51)
    b = jnp.asarray(
        np.random.default_rng(7).standard_normal((n, n)).astype(np.float32)
    )

    def fresh(val):
        return GData(val.shape, partitions=((p, p),), dtype=val.dtype, value=val)

    # baseline: factor, forward solve, backward solve as separate drains
    d1 = Dispatcher(graph="g2")
    A1 = fresh(a)
    utp_getrf(d1, A1)
    d1.run()
    packed = A1.value
    d2 = Dispatcher(graph="g2")
    A2, B2 = fresh(packed), fresh(b)
    utp_solve(d2, A2, B2, lower=True)
    d2.run()
    d3 = Dispatcher(graph="g2")
    A3, B3 = fresh(packed), fresh(B2.value)
    utp_solve(d3, A3, B3, lower=False, side="left")
    d3.run()
    separate_slots = sum(d.executor.stats["slots"] for d in (d1, d2, d3))
    separate_groups = sum(d.executor.stats["groups"] for d in (d1, d2, d3))

    # composed: the same pipeline as ONE LUSOLVE root -> one WaveProgram
    d = Dispatcher(graph="g2")
    A, B = fresh(a), fresh(b)
    utp_lu_solve(d, A, B)
    d.run()
    st = d.executor.stats
    assert st["launches"] == 1
    # solve groups fused into factor groups: single-root lu_solve has slack
    # (contrast test_single_root_lu_is_at_its_chain_lower_bound below)
    assert st["groups"] < st["groups_prefusion"]
    assert st["groups"] < separate_groups
    # overlap: solve slots interleave with late factor slots instead of
    # queueing behind them
    assert st["slots"] < separate_slots
    # and the composed drain computes the same x as the staged pipeline
    np.testing.assert_allclose(
        np.asarray(B.value), np.asarray(B3.value), rtol=2e-4, atol=2e-4
    )


def test_single_root_lu_is_at_its_chain_lower_bound():
    """Honest negative: single-matrix LU's same-signature chains (GETRF ->
    ... -> GETRF, per-C-block GEMMNN chains) make every Kahn group minimal,
    so fusion must NOT merge anything — the group-count win is multi-root
    (above); merging here would be a legality bug (DESIGN.md §2)."""
    clear_compile_cache()
    n, p = 64, 4
    a = dd_matrix(n, seed=41)
    d = Dispatcher(graph="g2")
    A = GData(a.shape, partitions=((p, p),), dtype=a.dtype, value=a)
    utp_getrf(d, A)
    d.run()
    st = d.executor.stats
    assert st["groups"] == st["groups_prefusion"] == 3 * (p - 1) + p


# --------------------------------------------------------------------------
# Lookahead: critical-path-first ordering inside an issue slot
# --------------------------------------------------------------------------
def test_lookahead_orders_critical_group_first():
    A = GData(
        (16, 16),
        partitions=((4, 4),),
        value=np.zeros((16, 16), dtype=np.float32),
    )
    # slot 0: a long chain head on block (0,0) vs trailing one-shot writes;
    # the chain head must be traced first despite later submission order
    trailing = [mktask(A, [((i, j), Access.WRITE)]) for i, j in ((2, 2), (3, 3))]
    chain = [mktask(A, [((0, 0), Access.READWRITE), ((1, 1), Access.WRITE)])]
    chain += [mktask(A, [((0, 0), Access.READWRITE)]) for _ in range(3)]
    tasks = trailing + chain  # trailing submitted first
    tr = _track(tasks)
    plan = plan_schedule(tr.waves(), tr.dag())
    first_slot = plan.slots[0]
    assert len(first_slot) == 2
    heights = [g.height for g in first_slot]
    assert heights == sorted(heights, reverse=True)
    # the chain head group (height 3) leads the trailing group (height 0)
    assert first_slot[0].height == 3 and first_slot[-1].height == 0
