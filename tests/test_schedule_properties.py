"""Hypothesis property tests for the dependency-exact scheduler.

On random task DAGs: the schedule is a valid topological order, every
fused group is internally edge-free and same-signature (fusion legality,
verified against ``TaskDag.independent``), groups sharing an issue slot
are mutually independent, and slot-launch semantics (gather all reads,
then scatter all writes) reproduce the sequential program order exactly.

Separate module from test_schedule_fusion so the property machinery stays
out of the deterministic tests' import path.  When hypothesis is absent
(offline CI container) the vendored fallback engine runs the same
properties — these tests never skip (DESIGN.md §13).
"""

import numpy as np

from repro.core import Access, DepTracker, GData
from repro.core.executors import plan_schedule

from test_schedule_fusion import _track, mktask

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: vendored fallback (DESIGN.md §13)
    from repro.testing.proptest import given, settings, strategies as st


@st.composite
def task_stream(draw):
    n_tasks = draw(st.integers(1, 24))
    grid = draw(st.sampled_from([2, 3]))
    stream = []
    for _ in range(n_tasks):
        n_args = draw(st.integers(1, 3))
        accesses = []
        for _ in range(n_args):
            rc = (draw(st.integers(0, grid - 1)), draw(st.integers(0, grid - 1)))
            mode = draw(st.sampled_from(list(Access)))
            accesses.append((rc, mode))
        stream.append(accesses)
    return grid, stream


def _plan(grid, stream):
    A = GData(
        (4 * grid, 4 * grid),
        partitions=((grid, grid),),
        value=np.zeros((4 * grid, 4 * grid), dtype=np.float32),
    )
    tasks = [mktask(A, acc) for acc in stream]
    tr = _track(tasks)
    dag = tr.dag()
    plan = plan_schedule(tr.waves(), dag)
    assert plan is not None
    return tasks, dag, plan


def _plan_groups_as_task_sets(plan, tasks):
    """Partition plan.tasks back into (slot, group) structure by walking
    slot/group sizes in order (plan.tasks is built in that order)."""
    it = iter(plan.tasks)
    out = []
    for slot in plan.slots:
        row = []
        for g in slot:
            row.append([next(it) for _ in range(g.size)])
        out.append(row)
    return out


@settings(max_examples=60, deadline=None)
@given(task_stream())
def test_exact_schedule_properties(spec):
    grid, stream = spec
    tasks, dag, plan = _plan(grid, stream)
    assert sorted(t.id for t in plan.tasks) == sorted(t.id for t in tasks)
    groups = _plan_groups_as_task_sets(plan, tasks)
    slot_of = {
        t.id: si for si, row in enumerate(groups) for ts in row for t in ts
    }
    # (a) valid topological order: every edge crosses to a later slot
    for pred, succs in dag.edges.items():
        for succ in succs:
            assert slot_of[pred] < slot_of[succ]
    # (b) every fused group is edge-free internally (fusion legality), and
    #     all groups sharing a slot are mutually independent
    for row in groups:
        for ts in row:
            ids = [t.id for t in ts]
            assert dag.independent(ids, ids)
        for i in range(len(row)):
            for j in range(i + 1, len(row)):
                assert dag.independent(
                    [t.id for t in row[i]], [t.id for t in row[j]]
                )
    # (c) fused groups share one signature
    for row, slot in zip(groups, plan.slots):
        for ts, g in zip(row, slot):
            assert len({t.op.name for t in ts}) == 1
            assert all(
                tuple(i for i, m in enumerate(t.modes) if m.writes)
                == g.write_pos
                for t in ts
            )


@settings(max_examples=30, deadline=None)
@given(task_stream())
def test_slot_launch_semantics_match_sequential(spec):
    """Executing fused groups slot by slot with launch semantics (gather
    all reads, then scatter all writes) must equal sequential program
    order exactly — the numerics half of the fusion-legality argument."""
    grid, stream = spec
    tasks, dag, plan = _plan(grid, stream)
    by_id = {t.id: acc for t, acc in zip(tasks, stream)}

    def bump(M, acc):
        reads = [M[rc] for rc, m in acc if m.reads]
        return 1.0 + float(np.sum(reads))

    seq = np.zeros((grid, grid))
    for t in tasks:
        b = bump(seq, by_id[t.id])
        for rc, m in by_id[t.id]:
            if m.writes:
                seq[rc] = seq[rc] + b

    par = np.zeros((grid, grid))
    for row in _plan_groups_as_task_sets(plan, tasks):
        pre = par.copy()  # all reads in a slot see the pre-slot state
        writes = []
        for ts in row:
            for t in ts:
                b = bump(pre, by_id[t.id])
                for rc, m in by_id[t.id]:
                    if m.writes:
                        writes.append((rc, b))
        for rc, b in writes:
            par[rc] = par[rc] + b
    np.testing.assert_allclose(par, seq, rtol=1e-12)


