"""Model-layer correctness: sequence mixers vs naive oracles, chunked
invariances, cache-consistency (prefill+decode == full forward)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.models.attention import _sdpa, _sdpa_chunked
from repro.models.rwkv import wkv6_chunked
from repro.models.ssm import ssd_chunked


def rand(key, *shape, scale=0.5):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# --------------------------------------------------------------------------
# wkv6: chunked == naive sequential recurrence
# --------------------------------------------------------------------------
def wkv6_naive(r, k, v, log_w, u, s0=None):
    B, S, H, K = r.shape
    s = jnp.zeros((B, H, K, K)) if s0 is None else s0
    ys = []
    for t in range(S):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], jnp.exp(log_w[:, t])
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        ys.append(y)
    return jnp.stack(ys, 1), s


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_wkv6_chunked_matches_naive(chunk):
    B, S, H, K = 2, 16, 2, 8
    r, k, v = rand(0, B, S, H, K), rand(1, B, S, H, K), rand(2, B, S, H, K)
    log_w = -jnp.exp(rand(3, B, S, H, K) * 0.5)
    u = rand(4, H, K)
    y, s = wkv6_chunked(r, k, v, log_w, u, chunk)
    y0, s0 = wkv6_naive(r, k, v, log_w, u)
    np.testing.assert_allclose(y, y0, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s, s0, rtol=1e-4, atol=1e-4)


def test_wkv6_state_carry():
    """Processing [first half; second half with carried state] == full."""
    B, S, H, K = 1, 16, 2, 8
    r, k, v = rand(5, B, S, H, K), rand(6, B, S, H, K), rand(7, B, S, H, K)
    log_w = -jnp.exp(rand(8, B, S, H, K) * 0.5)
    u = rand(9, H, K)
    y_full, s_full = wkv6_chunked(r, k, v, log_w, u, 4)
    y1, s1 = wkv6_chunked(r[:, :8], k[:, :8], v[:, :8], log_w[:, :8], u, 4)
    y2, s2 = wkv6_chunked(r[:, 8:], k[:, 8:], v[:, 8:], log_w[:, 8:], u, 4, s0=s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s2, s_full, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# mamba2 SSD: chunked == naive recurrence
# --------------------------------------------------------------------------
def ssd_naive(xs, dt, A, bs, cs, s0=None):
    B, S, H, P = xs.shape
    G, N = bs.shape[2], bs.shape[3]
    hg = H // G
    s = jnp.zeros((B, H, N, P)) if s0 is None else s0
    ys = []
    for t in range(S):
        a_t = jnp.exp(dt[:, t] * A[None])  # (B,H)
        b_t = jnp.repeat(bs[:, t], hg, axis=1)  # (B,H,N)
        c_t = jnp.repeat(cs[:, t], hg, axis=1)
        s = a_t[..., None, None] * s + jnp.einsum(
            "bhn,bhp->bhnp", b_t, xs[:, t] * dt[:, t][..., None]
        )
        ys.append(jnp.einsum("bhn,bhnp->bhp", c_t, s))
    return jnp.stack(ys, 1), s


@pytest.mark.parametrize("chunk", [4, 8])
def test_ssd_chunked_matches_naive(chunk):
    B, S, H, P, G, N = 2, 16, 4, 8, 1, 4
    xs = rand(10, B, S, H, P)
    dt = jax.nn.softplus(rand(11, B, S, H))
    A = -jnp.exp(rand(12, H) * 0.3)
    bs, cs = rand(13, B, S, G, N), rand(14, B, S, G, N)
    y, s = ssd_chunked(xs, dt, A, bs, cs, chunk)
    y0, s0 = ssd_naive(xs, dt, A, bs, cs)
    np.testing.assert_allclose(y, y0, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s, s0, rtol=1e-4, atol=1e-4)


def test_ssd_state_carry():
    B, S, H, P, G, N = 1, 16, 2, 4, 1, 4
    xs = rand(15, B, S, H, P)
    dt = jax.nn.softplus(rand(16, B, S, H))
    A = -jnp.exp(rand(17, H) * 0.3)
    bs, cs = rand(18, B, S, G, N), rand(19, B, S, G, N)
    y_full, s_full = ssd_chunked(xs, dt, A, bs, cs, 4)
    y1, s1 = ssd_chunked(xs[:, :8], dt[:, :8], A, bs[:, :8], cs[:, :8], 4)
    y2, s2 = ssd_chunked(xs[:, 8:], dt[:, 8:], A, bs[:, 8:], cs[:, 8:], 4, s0=s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s2, s_full, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# attention invariances
# --------------------------------------------------------------------------
@pytest.mark.parametrize("window", [0, 8])
def test_chunked_attention_matches_direct(window):
    B, S, H, D = 2, 32, 4, 8
    q, k, v = rand(20, B, S, H, D), rand(21, B, S, H, D), rand(22, B, S, H, D)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    direct = _sdpa(q, k, v, pos, pos, None, window)
    chunked = _sdpa_chunked(q, k, v, pos, pos, None, window, q_chunk=8)
    np.testing.assert_allclose(chunked, direct, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# cache consistency: prefill + decode == full forward, for EVERY family
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_matches_forward(arch):
    import dataclasses

    cfg = ARCHS[arch].reduced()
    if cfg.is_moe:
        # capacity-based routing is batch-global: a token's expert slot (and
        # hence dropping) depends on the other tokens in the batch, so
        # prefix-forward only matches when capacity is ample (no drops).
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    if cfg.frontend:
        full = {"embeds": rand(23, B, S, cfg.d_model, scale=0.1)}
        part = lambda sl: {"embeds": full["embeds"][:, sl]}
    else:
        toks = jax.random.randint(jax.random.PRNGKey(24), (B, S), 0, cfg.vocab)
        full = {"tokens": toks}
        part = lambda sl: {"tokens": toks[:, sl]}

    # ground truth: full no-cache forward
    h_full, _, _ = m.forward(params, full)
    from repro.models.model import lm_logits

    want = lm_logits(cfg, params, h_full)  # (B,S,V)

    # prefill on the first S-2 tokens, then decode 2 tokens
    cache = m.init_cache(B, S)
    logits_p, cache = m.prefill(params, part(slice(0, S - 2)), cache)
    np.testing.assert_allclose(
        logits_p, want[:, S - 3], rtol=2e-3, atol=2e-3
    )
    lg1, cache = m.decode_step(
        params, cache, part(slice(S - 2, S - 1)), jnp.asarray(S - 2, jnp.int32)
    )
    np.testing.assert_allclose(lg1, want[:, S - 2], rtol=2e-3, atol=2e-3)
    lg2, cache = m.decode_step(
        params, cache, part(slice(S - 1, S)), jnp.asarray(S - 1, jnp.int32)
    )
    np.testing.assert_allclose(lg2, want[:, S - 1], rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# arch smoke: one train step on CPU, shapes + finiteness (deliverable (f))
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_step(arch):
    cfg = ARCHS[arch].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = (
        {"embeds": rand(25, B, S, cfg.d_model, scale=0.1)}
        if cfg.frontend
        else {"tokens": jnp.ones((B, S), jnp.int32)}
    )
    batch["labels"] = jnp.zeros((B, S), jnp.int32)
    from repro import optim

    ocfg = optim.AdamWConfig(lr=1e-3)
    state = optim.init(params, ocfg)

    @jax.jit
    def step(p, s, b):
        (loss, metrics), g = jax.value_and_grad(
            lambda pp: m.loss(pp, b), has_aux=True
        )(p)
        p2, s2, om = optim.update(g, s, p, ocfg)
        return p2, s2, {**metrics, **om}

    p2, s2, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = sum(
        float(jnp.abs(a - b).sum()) for a, b in
        zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert moved > 0
    # logits shapes
    h, _, _ = m.forward(p2, batch if "tokens" in batch else {"embeds": batch["embeds"]})
    assert h.shape == (B, S, cfg.d_model)


def test_param_counts_match_published():
    """Exact-template N vs published sizes (coarse bands)."""
    from repro.models.model import param_counts

    bands = {
        "qwen3-32b": (30e9, 35e9),
        "nemotron-4-340b": (330e9, 350e9),
        "starcoder2-7b": (6.5e9, 8e9),
        "gemma3-12b": (10.5e9, 13e9),
        "rwkv6-3b": (2.7e9, 3.3e9),
        "zamba2-2.7b": (2.2e9, 3.0e9),
        "granite-moe-1b-a400m": (1.2e9, 1.5e9),
        "llama4-maverick-400b-a17b": (380e9, 410e9),
        "pixtral-12b": (11e9, 13e9),
        "musicgen-large": (2.2e9, 2.6e9),
    }
    for name, (lo, hi) in bands.items():
        n = param_counts(ARCHS[name])["total"]
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
    # MoE actives
    a = param_counts(ARCHS["granite-moe-1b-a400m"])["active"]
    assert 0.3e9 <= a <= 0.55e9
    a = param_counts(ARCHS["llama4-maverick-400b-a17b"])["active"]
    assert 12e9 <= a <= 20e9
