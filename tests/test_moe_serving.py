"""MoE dispatch equivalence (local gather vs dense vs shard_map EP) and the
serving engine end-to-end."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.models.layers import init_params
from repro.models.moe import MoeCtx, moe_apply, moe_template
from repro.serving import EngineConfig, Request, ServeEngine


def moe_cfg(**kw):
    cfg = ARCHS["granite-moe-1b-a400m"].reduced()
    return dataclasses.replace(cfg, **kw)


def make_params(cfg):
    return init_params(moe_template(cfg), jax.random.PRNGKey(0), jnp.float32)


def test_gather_vs_dense_dispatch():
    cfg = moe_cfg(capacity_factor=8.0)  # no drops -> exact equality
    p = make_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    out_g, aux_g = moe_apply(dataclasses.replace(cfg, moe_dispatch="gather"), p, x)
    out_d, aux_d = moe_apply(dataclasses.replace(cfg, moe_dispatch="dense"), p, x)
    np.testing.assert_allclose(out_g, out_d, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(aux_g, aux_d, rtol=1e-5, atol=1e-6)


def test_ep_matches_local():
    """shard_map EP on a 1x1 mesh == the local gather path."""
    cfg = moe_cfg(capacity_factor=8.0)
    p = make_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model)) * 0.5
    out_local, aux_local = moe_apply(cfg, p, x)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MoeCtx(mesh=mesh, batch_axes=("data",), model_axis="model")
    with mesh:
        out_ep, aux_ep = jax.jit(lambda pp, xx: moe_apply(cfg, pp, xx, ctx=ctx))(p, x)
    np.testing.assert_allclose(out_ep, out_local, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(aux_ep, aux_local, rtol=1e-4, atol=1e-6)


def test_ep_grads_flow():
    cfg = moe_cfg()
    p = make_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model)) * 0.5
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = MoeCtx(mesh=mesh, batch_axes=("data",), model_axis="model")

    def loss(pp):
        out, aux = moe_apply(cfg, pp, x, ctx=ctx)
        return (out**2).mean() + 0.01 * aux

    with mesh:
        g = jax.jit(jax.grad(loss))(p)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_capacity_drops_tokens():
    cfg = moe_cfg(capacity_factor=0.05)  # force drops
    p = make_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, cfg.d_model)) * 0.5
    out, aux = moe_apply(cfg, p, x)
    assert np.isfinite(np.asarray(out)).all()


# --------------------------------------------------------------------------
# serving engine
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["starcoder2-7b", "rwkv6-3b"])
def test_engine_generates(arch):
    cfg = ARCHS[arch].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, EngineConfig(slots=2, max_seq=64))
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=5 + i),
                max_new_tokens=4)
        for i in range(4)
    ]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained(max_steps=200)
    assert len(done) == 4
    for r in done:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_engine_greedy_matches_model():
    """Engine output == argmax decoding straight through the model."""
    cfg = ARCHS["starcoder2-7b"].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompt = np.array([1, 2, 3, 4, 5], dtype=np.int32)
    new = 4

    # reference: naive full-forward argmax loop
    toks = list(prompt)
    from repro.models.model import lm_logits

    for _ in range(new):
        h, _, _ = m.forward(params, {"tokens": jnp.asarray([toks])})
        nxt = int(jnp.argmax(lm_logits(cfg, params, h[:, -1]), axis=-1)[0])
        toks.append(nxt)
    want = toks[len(prompt):]

    eng = ServeEngine(cfg, params, EngineConfig(slots=2, max_seq=32))
    r = Request(rid=0, prompt=prompt, max_new_tokens=new)
    eng.submit(r)
    eng.run_until_drained(max_steps=50)
    assert r.out_tokens == want


def test_engine_continuous_batching_slot_reuse():
    cfg = ARCHS["starcoder2-7b"].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, EngineConfig(slots=1, max_seq=32))
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=3),
                    max_new_tokens=3) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained(max_steps=100)
    assert len(done) == 3  # one slot served all three sequentially
