"""Training substrate: checkpoint atomicity/elasticity, trainer fault
recovery, UTP step-ops equivalence (eager == fused == direct jit)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.models import build_model
from repro.train import Checkpointer, Trainer, TrainerConfig, UTPTrainStep


def tiny_cfg():
    return ARCHS["qwen3-32b"].reduced()


# --------------------------------------------------------------------------
# checkpoint
# --------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2,), jnp.int32)},
    }
    ck.save(5, state)
    out, step = ck.restore(state)
    assert step == 5
    np.testing.assert_array_equal(out["a"], state["a"])
    np.testing.assert_array_equal(out["b"]["c"], state["b"]["c"])


def test_checkpoint_gc_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_crc_detects_corruption(tmp_path):
    import json

    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, {"x": jnp.arange(8.0)})
    # tamper: stored CRC no longer matches the array bytes
    d = tmp_path / "step_00000001"
    meta = json.loads((d / "meta.json").read_text())
    meta["crc"]["x"] ^= 0xDEADBEEF
    (d / "meta.json").write_text(json.dumps(meta))
    with pytest.raises(IOError):
        ck.restore({"x": jnp.zeros(8)})


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(7, {"x": jnp.ones((4,))})
    ck.wait()
    assert ck.latest_step() == 7


def test_checkpoint_elastic_resharding(tmp_path):
    """Save, then restore with an explicit (trivial) sharding tree — the
    elastic path used when the mesh changes between runs."""
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    ck = Checkpointer(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, state)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out, _ = ck.restore(state, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["w"]))
    assert out["w"].sharding == sh["w"]


# --------------------------------------------------------------------------
# trainer: loss falls, resume works, failures recover
# --------------------------------------------------------------------------
def small_trainer(tmp_path, steps=12, ckpt_every=4):
    cfg = tiny_cfg()
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    t = Trainer(
        cfg, shape, mesh,
        TrainerConfig(
            steps=steps, ckpt_every=ckpt_every, ckpt_dir=str(tmp_path),
            log_every=100, seed=0,
        ),
        opt_cfg=optim.AdamWConfig(lr=3e-3),
    )
    return t


def test_trainer_loss_decreases(tmp_path):
    t = small_trainer(tmp_path, steps=30)
    out = t.train()
    losses = [m["loss"] for m in out["metrics"]]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1
    assert out["step"] == 30


def test_trainer_resume(tmp_path):
    t1 = small_trainer(tmp_path, steps=8, ckpt_every=4)
    out1 = t1.train()
    # new trainer, same dir -> resumes at 8 and continues to 12
    t2 = small_trainer(tmp_path, steps=12, ckpt_every=4)
    out2 = t2.train()
    assert out2["step"] == 12
    assert out2["metrics"][0]["step"] == 9  # continued, not restarted


def test_trainer_failure_recovery(tmp_path):
    t = small_trainer(tmp_path, steps=10, ckpt_every=2)
    fail_at = {6}

    def inject(step):
        if step in fail_at:
            fail_at.discard(step)  # fail once
            return True
        return False

    out = t.train(inject_failure=inject)
    assert out["step"] == 10
    assert out["failures"] == 1


def test_trainer_too_many_failures_raises(tmp_path):
    t = small_trainer(tmp_path, steps=10, ckpt_every=2)
    t.tcfg.max_failures = 1
    with pytest.raises(RuntimeError):
        t.train(inject_failure=lambda s: True)


# --------------------------------------------------------------------------
# UTP step ops: the task-tree step == the direct jit step
# --------------------------------------------------------------------------
@pytest.mark.parametrize("executor", ["eager", "fused"])
@pytest.mark.parametrize("m", [1, 2])
def test_utp_train_step_matches_direct(executor, m):
    cfg = tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ocfg = optim.AdamWConfig(lr=1e-3)
    opt = optim.init(params, ocfg)
    B, S = 4, 16
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab),
    }

    def loss_fn(p, b):
        return model.loss(p, b)

    utp = UTPTrainStep(loss_fn, ocfg, microbatches=m, executor=executor)
    p_utp, o_utp, metrics = utp(params, opt, batch)

    # direct reference: microbatched grad accumulation
    def direct(p, o, b):
        mb = jax.tree.map(lambda x: x.reshape((m, B // m) + x.shape[1:]), b)
        gs = [
            jax.grad(lambda pp: loss_fn(pp, jax.tree.map(lambda x: x[i], mb))[0])(p)
            for i in range(m)
        ]
        g = jax.tree.map(lambda *xs: sum(xs) / m, *gs)
        return optim.update(g, o, p, ocfg)

    p_ref, o_ref, _ = direct(params, opt, batch)
    for a, b in zip(jax.tree.leaves(p_utp), jax.tree.leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)
    assert "loss" in metrics or metrics  # metrics aggregated


def test_utp_fused_compiles_once():
    cfg = tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ocfg = optim.AdamWConfig(lr=1e-3)
    opt = optim.init(params, ocfg)
    batch = {
        "tokens": jnp.ones((2, 16), jnp.int32),
        "labels": jnp.ones((2, 16), jnp.int32),
    }
    utp = UTPTrainStep(lambda p, b: model.loss(p, b), ocfg, executor="fused")
    p1, o1, _ = utp(params, opt, batch)
    p2, o2, _ = utp(p1, o1, batch)  # second call reuses cached jit
    assert np.isfinite(float(jax.tree.leaves(p2)[0].sum()))


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------
def test_data_deterministic_and_learnable():
    dc = DataConfig(vocab=64, seq_len=32, global_batch=4, seed=1)
    ds1 = SyntheticLMDataset(dc)
    ds2 = SyntheticLMDataset(dc)
    b1, b2 = ds1.batch(5), ds2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # structure: top-1 bigram prediction from the table beats chance by a lot
    table = ds1.table
    toks, labels = b1["tokens"], b1["labels"]
    any_hit = (table[toks] == labels[..., None]).any(-1).mean()
    assert any_hit > 0.9
