"""Hypothesis property test: stacked drains match independent drains.

For random batch sizes (pow2 and not), geometries, and task-flow graphs,
every per-request result of one stacked batched drain must match the same
request run as its own independent drain.  Tolerance note: the stacked
program compiles DIFFERENT XLA programs (leaf stacks of size B*s instead
of s), so bit-exactness across the two compilations is not guaranteed by
XLA; observed differences are ~1 ulp and the assertion uses a 1e-6
tolerance several orders tighter than the factorization's own error.

Separate module from test_serve so the hypothesis importorskip (as in
test_core_versioning / test_schedule_properties) does not skip the
deterministic serving tests.
"""

import numpy as np
import pytest

from repro.core import dd_matrix
from repro.core.executors import clear_compile_cache
from repro.linalg import run_lu, run_lu_batched, run_lu_solve, run_lu_solve_batched

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(max_examples=12, deadline=None)
@given(
    n_roots=st.integers(1, 6),
    geom=st.sampled_from([(32, 2), (32, 4), (64, 4)]),
    graph=st.sampled_from(["g1", "g2"]),
    seed=st.integers(0, 1000),
)
def test_stacked_lu_matches_independent_drains(n_roots, geom, graph, seed):
    n, p = geom
    mats = [dd_matrix(n, seed=seed + s) for s in range(n_roots)]
    clear_compile_cache()
    stacked = run_lu_batched(mats, graph=graph, partitions=((p, p),))
    clear_compile_cache()
    singles = [run_lu(m, graph=graph, partitions=((p, p),)) for m in mats]
    for (ls, us), (li, ui) in zip(stacked, singles):
        np.testing.assert_allclose(
            np.asarray(ls), np.asarray(li), rtol=1e-6, atol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(us), np.asarray(ui), rtol=1e-6, atol=1e-6
        )


@settings(max_examples=8, deadline=None)
@given(
    n_roots=st.integers(1, 5),
    m_cols=st.sampled_from([1, 4]),
    graph=st.sampled_from(["g1", "g2"]),
    seed=st.integers(0, 1000),
)
def test_stacked_lu_solve_matches_independent_drains(
    n_roots, m_cols, graph, seed
):
    n, p = 32, 4
    rng = np.random.default_rng(seed)
    mats = [dd_matrix(n, seed=seed + s) for s in range(n_roots)]
    rhss = [
        rng.standard_normal((n, m_cols)).astype(np.float32)
        for _ in range(n_roots)
    ]
    clear_compile_cache()
    stacked = run_lu_solve_batched(
        mats, rhss, graph=graph, partitions=((p, p),), b_partitions=((p, 1),)
    )
    clear_compile_cache()
    singles = [
        run_lu_solve(
            a, b, graph=graph, partitions=((p, p),), b_partitions=((p, 1),)
        )
        for a, b in zip(mats, rhss)
    ]
    for xs, xi in zip(stacked, singles):
        np.testing.assert_allclose(
            np.asarray(xs), np.asarray(xi), rtol=1e-6, atol=1e-6
        )
