"""Hypothesis property test: stacked drains match independent drains.

For random batch sizes (pow2 and not), geometries, and task-flow graphs,
every per-request result of one stacked batched drain must match the same
request run as its own independent drain.  Tolerance note: the stacked
program compiles DIFFERENT XLA programs (leaf stacks of size B*s instead
of s), so bit-exactness across the two compilations is not guaranteed by
XLA; observed differences are ~1 ulp and the assertion uses a 1e-6
tolerance several orders tighter than the factorization's own error.

Separate module from test_serve so the property machinery stays out of
the deterministic serving tests' import path.  When hypothesis is absent
(offline CI container) the vendored fallback engine runs the same
properties — these tests never skip (DESIGN.md §13).
"""

import numpy as np

from repro.core import dd_matrix, spd_matrix
from repro.core.executors import clear_compile_cache
from repro.linalg import (
    run_cholesky,
    run_lu,
    run_lu_batched,
    run_lu_solve,
    run_lu_solve_batched,
)
from repro.serve import BatchServer

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: vendored fallback (DESIGN.md §13)
    from repro.testing.proptest import given, settings, strategies as st


@settings(max_examples=12, deadline=None)
@given(
    n_roots=st.integers(1, 6),
    geom=st.sampled_from([(32, 2), (32, 4), (64, 4)]),
    graph=st.sampled_from(["g1", "g2"]),
    seed=st.integers(0, 1000),
)
def test_stacked_lu_matches_independent_drains(n_roots, geom, graph, seed):
    n, p = geom
    mats = [dd_matrix(n, seed=seed + s) for s in range(n_roots)]
    clear_compile_cache()
    stacked = run_lu_batched(mats, graph=graph, partitions=((p, p),))
    clear_compile_cache()
    singles = [run_lu(m, graph=graph, partitions=((p, p),)) for m in mats]
    for (ls, us), (li, ui) in zip(stacked, singles):
        np.testing.assert_allclose(
            np.asarray(ls), np.asarray(li), rtol=1e-6, atol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(us), np.asarray(ui), rtol=1e-6, atol=1e-6
        )


@settings(max_examples=8, deadline=None)
@given(
    n_roots=st.integers(1, 5),
    m_cols=st.sampled_from([1, 4]),
    graph=st.sampled_from(["g1", "g2"]),
    seed=st.integers(0, 1000),
)
def test_stacked_lu_solve_matches_independent_drains(
    n_roots, m_cols, graph, seed
):
    n, p = 32, 4
    rng = np.random.default_rng(seed)
    mats = [dd_matrix(n, seed=seed + s) for s in range(n_roots)]
    rhss = [
        rng.standard_normal((n, m_cols)).astype(np.float32)
        for _ in range(n_roots)
    ]
    clear_compile_cache()
    stacked = run_lu_solve_batched(
        mats, rhss, graph=graph, partitions=((p, p),), b_partitions=((p, 1),)
    )
    clear_compile_cache()
    singles = [
        run_lu_solve(
            a, b, graph=graph, partitions=((p, p),), b_partitions=((p, 1),)
        )
        for a, b in zip(mats, rhss)
    ]
    for xs, xi in zip(stacked, singles):
        np.testing.assert_allclose(
            np.asarray(xs), np.asarray(xi), rtol=1e-6, atol=1e-6
        )


# -- mixed-signature traffic ---------------------------------------------------

_N, _P = 32, 2
_KINDS = ("lu", "cholesky", "lu_solve")


def _rhs(seed: int) -> np.ndarray:
    return np.random.default_rng(1000 + seed).standard_normal(_N).astype(
        np.float32
    )


def _submit(srv: BatchServer, kind: str, seed: int):
    if kind == "lu":
        return srv.lu(dd_matrix(_N, seed=seed), partitions=((_P, _P),))
    if kind == "cholesky":
        return srv.cholesky(spd_matrix(_N, seed=seed), partitions=((_P, _P),))
    return srv.lu_solve(
        dd_matrix(_N, seed=seed), _rhs(seed), partitions=((_P, _P),)
    )


def _sequential(kind: str, seed: int):
    """The same request as its own independent drain (no serving layer)."""
    if kind == "lu":
        return run_lu(dd_matrix(_N, seed=seed), partitions=((_P, _P),))
    if kind == "cholesky":
        return run_cholesky(spd_matrix(_N, seed=seed), partitions=((_P, _P),))
    return run_lu_solve(
        dd_matrix(_N, seed=seed),
        _rhs(seed),
        partitions=((_P, _P),),
        b_partitions=((_P, 1),),
    )


def _leaves(result):
    return list(result) if isinstance(result, tuple) else [result]


@st.composite
def traffic(draw):
    """A few ticks of mixed lu/cholesky/lu_solve traffic, each tick's
    submission order an arbitrary interleaving of the three signatures."""
    ticks = []
    for _ in range(draw(st.integers(1, 3))):
        reqs = []
        for kind in _KINDS:
            for _ in range(draw(st.integers(0, 3))):
                reqs.append((kind, draw(st.integers(0, 50))))
        order = draw(st.permutations(list(range(len(reqs)))))
        ticks.append([reqs[i] for i in order])
    return ticks


@settings(max_examples=5, deadline=None)
@given(plan=traffic(), overlap=st.booleans())
def test_mixed_signature_traffic_matches_sequential(plan, overlap):
    """Random interleavings of mixed-signature submits across ticks must
    resolve every future (a) BIT-identically to the canonical server that
    sees the same requests per tick in signature-grouped order (lane
    position and submission interleaving cannot change a request's bits —
    same bucket multiset => same stacked program, lanes independent), with
    ``overlap`` on and off, and (b) numerically equal (1e-6, the DESIGN.md
    §7 stacked-vs-single tolerance: different XLA programs) to the same
    request drained sequentially on its own."""
    clear_compile_cache()
    srv = BatchServer(graph="g2", overlap=overlap)
    canon = BatchServer(graph="g2", overlap=False)
    subject = []  # (kind, seed, future)
    canon_futs = {}  # (kind, seed) -> [futures]
    for tick in plan:
        for kind, seed in tick:
            subject.append((kind, seed, _submit(srv, kind, seed)))
        for kind, seed in sorted(tick, key=lambda r: _KINDS.index(r[0])):
            canon_futs.setdefault((kind, seed), []).append(
                _submit(canon, kind, seed)
            )
        rep = srv.tick()
        canon.tick()
        assert rep.resolved == len(tick) and rep.failed == 0
    for kind, seed, fut in subject:
        got = _leaves(fut.result())
        want_bits = _leaves(canon_futs[(kind, seed)].pop().result())
        for g, w in zip(got, want_bits):
            assert np.array_equal(np.asarray(g), np.asarray(w)), (
                f"{kind}(seed={seed}): interleaved result != canonical "
                f"signature-grouped result (bit-identity)"
            )
        for g, w in zip(got, _leaves(_sequential(kind, seed))):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), rtol=1e-6, atol=1e-6,
                err_msg=f"{kind}(seed={seed}) vs sequential drain",
            )
