"""Grid-resident epoch + WaveProgram compiler (DESIGN.md §2).

Covers: GData grid epoch coherence, whole-schedule compilation (one
compiled program per structural schedule, reused across drains), numerical
parity of the grid-resident path against the sequential InlineExecutor
reference across g1/g2/g2p/g3, and the power-of-two bucket padding of the
per-group fallback path (wave sizes 1..9, O(log n) distinct compiles,
duplicate-last-task scatter idempotence).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Access, Dispatcher, GData, GTask, Operation, spd_matrix
from repro.core.data import from_grid, to_grid
from repro.core.executors import (
    JitWaveExecutor,
    PallasExecutor,
    clear_compile_cache,
    plan_schedule,
)
from repro.linalg import run_cholesky


def _mesh_1d():
    return jax.make_mesh((1, 1), ("data", "model"))


# --------------------------------------------------------------------------
# GData grid-resident epoch
# --------------------------------------------------------------------------
class TestGridEpoch:
    def test_enter_exit_roundtrip(self):
        a = np.arange(64, dtype=np.float32).reshape(8, 8)
        d = GData((8, 8), partitions=((2, 2),), value=a)
        g = d.enter_grid(4, 4)
        assert d.in_grid_epoch and d.grid_block == (4, 4)
        assert g.shape == (2, 2, 4, 4)
        np.testing.assert_array_equal(np.asarray(g[1, 0]), a[4:, :4])
        # reading .value de-grids lazily and ends the epoch
        np.testing.assert_array_equal(np.asarray(d.value), a)
        assert not d.in_grid_epoch

    def test_reenter_same_block_is_resident(self):
        d = GData((8, 8), value=np.eye(8, dtype=np.float32))
        g1 = d.enter_grid(4, 4)
        g2 = d.enter_grid(4, 4)
        assert g1 is g2  # no layout traffic on re-entry

    def test_set_grid_then_value_reads_through(self):
        a = np.zeros((8, 8), dtype=np.float32)
        d = GData((8, 8), value=a)
        d.enter_grid(4, 4)
        g = jnp.asarray(np.arange(64, dtype=np.float32).reshape(2, 2, 4, 4))
        d.set_grid(g)
        np.testing.assert_array_equal(np.asarray(d.value), np.asarray(from_grid(g)))

    def test_value_write_invalidates_grid(self):
        d = GData((8, 8), value=np.eye(8, dtype=np.float32))
        d.enter_grid(4, 4)
        d.value = jnp.zeros((8, 8))
        assert not d.in_grid_epoch
        np.testing.assert_array_equal(np.asarray(d.value), np.zeros((8, 8)))

    def test_different_block_flushes_through_root(self):
        a = np.arange(64, dtype=np.float32).reshape(8, 8)
        d = GData((8, 8), value=a)
        d.enter_grid(4, 4)
        g = d.enter_grid(2, 2)
        assert d.grid_block == (2, 2)
        np.testing.assert_array_equal(np.asarray(from_grid(g)), a)

    def test_grid_layout_helpers_inverse(self):
        a = jnp.asarray(np.random.default_rng(0).standard_normal((12, 8)))
        np.testing.assert_array_equal(
            np.asarray(from_grid(to_grid(a, 4, 2))), np.asarray(a)
        )


# --------------------------------------------------------------------------
# WaveProgram: one compiled program per structural schedule
# --------------------------------------------------------------------------
def _drain_cholesky(graph, a, parts):
    d = Dispatcher(graph=graph)
    A = GData(a.shape, partitions=parts, dtype=a.dtype, value=a)
    from repro.linalg.cholesky import utp_cholesky

    utp_cholesky(d, A)
    n = d.run()
    return d, A, n


@pytest.mark.parametrize("graph", ["g2", "g2p"])
def test_one_program_per_drain_and_cache_reuse(graph):
    clear_compile_cache()
    a = spd_matrix(64, seed=13)
    d1, A1, n1 = _drain_cholesky(graph, a, ((4, 4),))
    assert n1 == 20
    assert d1.executor.stats["launches"] == 1  # whole schedule = one dispatch
    assert d1.executor.stats["compiles"] == 1  # one compiled program
    assert A1.in_grid_epoch  # root stayed grid-resident
    # repeated drain with the same schedule structure: zero new compiles
    d2, A2, _ = _drain_cholesky(graph, a, ((4, 4),))
    assert d2.executor.stats["launches"] == 1
    assert d2.executor.stats.get("compiles", 0) == 0
    np.testing.assert_allclose(
        np.asarray(A1.value), np.asarray(A2.value), rtol=1e-6
    )


def test_plan_schedule_falls_back_on_nonuniform_blocks():
    class W(Operation):
        name = "w_nonuniform"

        def default_modes(self, n):
            return [Access.READWRITE]

    A = GData((8, 8), partitions=((2, 2), (2, 2)), value=np.eye(8, dtype=np.float32))
    t_big = GTask(W(), None, [A(0, 0)])  # level-0 block (4x4)
    t_small = GTask(W(), None, [A(1, 1)(0, 0)])  # level-1 tile (2x2)
    assert plan_schedule([[t_big], [t_small]]) is None


def test_plan_schedule_requires_value():
    class W(Operation):
        name = "w_novalue"

        def default_modes(self, n):
            return [Access.READWRITE]

    A = GData((8, 8), partitions=((2, 2),))  # no value materialized
    assert plan_schedule([[GTask(W(), None, [A(0, 0)])]]) is None


# --------------------------------------------------------------------------
# Drain memo: structurally repeated drains replay without re-splitting
# --------------------------------------------------------------------------
def test_drain_memo_is_value_independent():
    """The memo keys on structure; fresh GData with different *values* must
    replay the captured programs and still be numerically exact."""
    clear_compile_cache()
    a1 = spd_matrix(64, seed=21)
    a2 = spd_matrix(64, seed=22)
    L1 = run_cholesky(a1, graph="g2", partitions=((4, 4),))
    L2 = run_cholesky(a2, graph="g2", partitions=((4, 4),))  # replayed drain
    np.testing.assert_allclose(
        np.asarray(L1), np.asarray(jnp.linalg.cholesky(a1)), rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(L2), np.asarray(jnp.linalg.cholesky(a2)), rtol=2e-4, atol=2e-4
    )


def test_drain_memo_replay_preserves_stats_and_count():
    clear_compile_cache()
    a = spd_matrix(32, seed=5)

    def drain():
        d = Dispatcher(graph="g2")
        A = GData(a.shape, partitions=((4, 4),), dtype=a.dtype, value=a)
        from repro.linalg.cholesky import utp_cholesky

        task = utp_cholesky(d, A)
        n = d.run()
        return d, task, n

    d1, t1, n1 = drain()  # capture
    d2, t2, n2 = drain()  # replay
    assert n1 == n2 == 20
    assert d1.stats["split"] == d2.stats["split"] == 1
    assert d1.stats["waves"] == d2.stats["waves"]
    assert t2.state.name == "FINISHED"
    assert d2.executor.stats["launches"] == 1
    assert d2.executor.stats.get("compiles", 0) == 0


def test_memoize_drains_opt_out():
    clear_compile_cache()
    a = spd_matrix(32, seed=6)
    outs = []
    for _ in range(2):
        d = Dispatcher(graph="g2", memoize_drains=False)
        A = GData(a.shape, partitions=((4, 4),), dtype=a.dtype, value=a)
        from repro.linalg.cholesky import utp_cholesky

        utp_cholesky(d, A)
        n = d.run()
        assert d.stats["split"] == 1  # really re-split, not replayed
        assert n == 20
        outs.append(np.asarray(A.value))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6)


def test_flat_idxs_built_at_plan_time_and_replay_reuses_device_array():
    """The concatenated block-index array is constructed ONCE at plan time
    (a SchedulePlan field, not a per-execution host concatenation) and the
    drain memo's ProgramRecord carries that device array, so replays reuse
    it without any host work or transfer."""
    from repro.core.executors.jit_wave import _DRAIN_MEMO

    clear_compile_cache()
    a = spd_matrix(32, seed=9)
    _drain_cholesky("g2", a, ((4, 4),))  # capture
    assert len(_DRAIN_MEMO) == 1
    (memo,) = _DRAIN_MEMO.values()
    (rec,) = memo["records"]
    assert isinstance(rec.idxs, jnp.ndarray) and rec.idxs.shape[1] == 2
    before = id(rec.idxs)
    _drain_cholesky("g2", a, ((4, 4),))  # replay
    (memo2,) = _DRAIN_MEMO.values()
    (rec2,) = memo2["records"]
    assert id(rec2.idxs) == before  # device-resident array reused as-is
    # plan-time construction: SchedulePlan.flat_idxs is data, not a method
    from repro.core import DepTracker, GData as GD
    from repro.linalg.ops import SYRK

    A = GD((8, 8), partitions=((2, 2),), value=np.eye(8, dtype=np.float32))
    tasks = [GTask(SYRK, None, [A(i, i), A(1 - i, 1 - i)]) for i in range(1)]
    tr = DepTracker()
    for t in tasks:
        tr.add(t)
    plan = plan_schedule(tr.waves(), tr.dag())
    assert isinstance(plan.flat_idxs, jnp.ndarray)


# --------------------------------------------------------------------------
# Numerical parity: grid-resident path vs sequential InlineExecutor (g1)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("graph", ["g2", "g2p", "g3"])
@pytest.mark.parametrize("n", [32, 64])
def test_grid_resident_matches_inline_reference(graph, n):
    a = spd_matrix(n, seed=n + 1)
    ref = run_cholesky(a, graph="g1", partitions=((4, 4),))
    if graph == "g3":
        got = run_cholesky(
            a, graph=graph, partitions=((2, 2), (2, 2)), mesh=_mesh_1d()
        )
    else:
        got = run_cholesky(a, graph=graph, partitions=((4, 4),))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=2e-4, atol=2e-4
    )


# --------------------------------------------------------------------------
# Power-of-two bucket padding in the per-group fallback path (_run_group)
# --------------------------------------------------------------------------
class AddBiasOp(Operation):
    """WRITE-mode op: out block <- bias (constant), ignores prior contents."""

    name = "add_bias_w"

    def default_modes(self, n):
        return [Access.READ, Access.WRITE]

    def leaf_fn(self, backend):
        return lambda src, dst: src + 1.0


class BumpOp(Operation):
    """READWRITE op: block <- block + 1 (gather-before-scatter sensitivity)."""

    name = "bump_rw"

    def default_modes(self, n):
        return [Access.READWRITE]

    def leaf_fn(self, backend):
        return lambda b: b + 1.0


def _grid_data(p, b=4):
    val = np.zeros((p * b, p * b), dtype=np.float32)
    return GData((p * b, p * b), partitions=((p, p),), value=val)


@pytest.mark.parametrize("size", range(1, 10))
def test_bucket_padding_correct_for_all_wave_sizes(size):
    """Wave sizes 1..9 through the padded fallback path all scatter exactly
    once per distinct block — the duplicated last task is idempotent."""
    ex = JitWaveExecutor()
    p = 3  # 9 blocks
    A = _grid_data(p)
    tasks = [
        GTask(BumpOp(), None, [A(i // p, i % p)]) for i in range(size)
    ]
    ex._run_group(tasks)
    got = np.asarray(A.value)
    exp = np.zeros_like(got)
    for i in range(size):
        r, c = i // p, i % p
        exp[r * 4 : r * 4 + 4, c * 4 : c * 4 + 4] = 1.0
    np.testing.assert_array_equal(got, exp)


def test_bucket_padding_idempotent_for_write_mode_op():
    ex = JitWaveExecutor()
    p = 2
    A = _grid_data(p)
    B = _grid_data(p)
    # 3 tasks -> bucket 4 -> last task duplicated once in the batch
    tasks = [
        GTask(AddBiasOp(), None, [A(i // p, i % p), B(i // p, i % p)])
        for i in range(3)
    ]
    ex._run_group(tasks)
    got = np.asarray(B.value)
    exp = np.zeros_like(got)
    exp[:4, :] = 1.0  # blocks (0,0), (0,1)
    exp[4:, :4] = 1.0  # block (1,0)
    np.testing.assert_array_equal(got, exp)


def test_bucket_padding_compiles_olog_n():
    """Sizes 1..9 bucket to {1, 2, 4, 8, 16}: at most 5 distinct compiles."""
    clear_compile_cache()
    op = BumpOp()
    compiles = []
    for size in range(1, 10):
        ex = JitWaveExecutor()
        A = _grid_data(4)  # 16 blocks >= max size
        tasks = [GTask(op, None, [A(i // 4, i % 4)]) for i in range(size)]
        ex._run_group(tasks)
        compiles.append(ex.stats.get("compiles", 0))
    assert sum(compiles) <= 5, compiles


# --------------------------------------------------------------------------
# Exact (unpadded) group sizes through the WaveProgram path, incl. fused
# pallas groups, across wave sizes 1..9
# --------------------------------------------------------------------------
@pytest.mark.parametrize("cls", [JitWaveExecutor, PallasExecutor])
@pytest.mark.parametrize("size", [1, 2, 5, 9])
def test_program_path_wave_sizes(cls, size):
    from repro.linalg.ops import SYRK

    p = 3
    rng = np.random.default_rng(size)
    base = rng.standard_normal((4 * p, 4 * p)).astype(np.float32)
    A = GData((4 * p, 4 * p), partitions=((p, p),), value=base)
    C = GData((4 * p, 4 * p), partitions=((p, p),), value=np.array(base))
    tasks = [
        GTask(SYRK, None, [A(i // p, i % p), C(i // p, i % p)])
        for i in range(size)
    ]
    ex = cls()
    n = ex.execute_waves([tasks])
    assert n == size
    got = np.asarray(C.value)
    exp = np.array(base)
    for i in range(size):
        r, c = i // p, i % p
        blk_a = base[r * 4 : r * 4 + 4, c * 4 : c * 4 + 4]
        exp[r * 4 : r * 4 + 4, c * 4 : c * 4 + 4] = (
            exp[r * 4 : r * 4 + 4, c * 4 : c * 4 + 4] - blk_a @ blk_a.T
        )
    np.testing.assert_allclose(got, exp, rtol=1e-5, atol=1e-5)
