"""BatchServer: signature-bucketed batched serving (DESIGN.md §7, §10).

Covers: future resolution + numerics for lu / cholesky / lu_solve requests
(vector and matrix right-hand sides), per-signature bucketing inside one
tick, the repeat-tick contract (0 compiles / 1 launch / 1 stacked drain per
signature bucket), max_batch chunking, the unresolved-future error, and the
failure model — bisect isolation of poisoned requests, lane-isolated finite
checks, deadlines, admission control, retry budget/backoff, FIFO re-queue
ordering, and latency percentiles.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import dd_matrix, spd_matrix
from repro.core.executors import clear_compile_cache
from repro.errors import (
    DeadlineExceeded,
    DrainError,
    NumericalError,
    RejectedError,
)
from repro.linalg import run_lu, run_lu_solve
from repro.serve import BatchServer
from repro.testing import faults


@pytest.fixture(autouse=True)
def _no_leaked_faults():
    yield
    faults.reset()


def _rhs(n, m=None, seed=0):
    rng = np.random.default_rng(seed)
    shape = (n,) if m is None else (n, m)
    return rng.standard_normal(shape).astype(np.float32)


def test_lu_solve_requests_resolve_and_match():
    clear_compile_cache()
    n, N = 64, 5
    srv = BatchServer(graph="g2")
    futs, refs = [], []
    for s in range(N):
        a = dd_matrix(n, seed=s)
        b = _rhs(n, seed=s)
        futs.append(srv.lu_solve(a, b))
        refs.append(run_lu_solve(a, b, partitions=((4, 4),)))
    assert srv.pending() == N and not futs[0].done
    rep = srv.tick()
    assert rep.requests == N and rep.buckets == 1
    assert rep.stacked_drains == 1 and rep.launches == 1
    assert srv.pending() == 0
    for f, r in zip(futs, refs):
        assert f.done
        x = f.result()
        assert x.shape == (n,)  # vector rhs round-trips as a vector
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(r), rtol=1e-5, atol=1e-5
        )


def test_mixed_signatures_bucket_separately():
    clear_compile_cache()
    srv = BatchServer(graph="g2")
    lu_futs = [srv.lu(dd_matrix(64, seed=s)) for s in range(3)]
    chol_futs = [
        srv.cholesky(spd_matrix(32, seed=s), partitions=((4, 4),))
        for s in range(2)
    ]
    rep = srv.tick()
    assert rep.buckets == 2 and rep.drains == 2
    assert rep.stacked_drains == 2  # each homogeneous bucket stacked
    for s, f in enumerate(lu_futs):
        l, u = f.result()
        np.testing.assert_allclose(
            np.asarray(l) @ np.asarray(u),
            np.asarray(dd_matrix(64, seed=s)),
            rtol=2e-4,
            atol=2e-4,
        )
    for s, f in enumerate(chol_futs):
        L = np.asarray(f.result())
        np.testing.assert_allclose(
            L @ L.T, np.asarray(spd_matrix(32, seed=s)), rtol=2e-4, atol=2e-4
        )


def test_repeat_tick_replays_zero_compiles_one_launch():
    """The serving steady state: a structurally repeated tick must do NO
    Python re-splitting and NO recompilation — one program launch per
    signature bucket (DESIGN.md §7 acceptance contract)."""
    clear_compile_cache()
    n = 64
    srv = BatchServer(graph="g2")

    def one_tick(seed0):
        for s in range(4):
            srv.lu_solve(dd_matrix(n, seed=seed0 + s), _rhs(n, seed=s))
        return srv.tick()

    one_tick(0)  # capture tick (compiles once)
    for seed0 in (10, 20):
        rep = one_tick(seed0)
        assert rep.compiles == 0, rep
        assert rep.launches == 1 and rep.stacked_drains == 1
        assert rep.memo_hits == 1 and rep.memo_misses == 0
        for b in rep.per_bucket:
            assert b["compiles"] == 0 and b["launches"] == 1


def test_max_batch_chunks_one_signature():
    clear_compile_cache()
    n = 64
    srv = BatchServer(graph="g2", max_batch=2)
    futs = [srv.lu(dd_matrix(n, seed=s)) for s in range(5)]
    rep = srv.tick()
    assert rep.buckets == 1 and rep.drains == 3  # 2 + 2 + 1
    for s, f in enumerate(futs):
        l, u = f.result()
        np.testing.assert_allclose(
            np.asarray(l) @ np.asarray(u),
            np.asarray(dd_matrix(n, seed=s)),
            rtol=2e-4,
            atol=2e-4,
        )


def test_single_request_tick_still_serves():
    clear_compile_cache()
    srv = BatchServer(graph="g2")
    f = srv.lu(dd_matrix(64, seed=91))
    rep = srv.tick()
    # one request cannot stack (nothing to batch) but must still resolve
    assert rep.requests == 1
    l, u = f.result()
    rl, ru = run_lu(dd_matrix(64, seed=91), partitions=((4, 4),))
    np.testing.assert_allclose(np.asarray(l), np.asarray(rl), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(u), np.asarray(ru), rtol=1e-6)


def test_matrix_rhs_lu_solve():
    clear_compile_cache()
    n = 64
    srv = BatchServer(graph="g2")
    a = dd_matrix(n, seed=7)
    b = _rhs(n, m=8, seed=7)
    f = srv.lu_solve(a, b, b_partitions=((4, 1),))
    srv.tick()
    np.testing.assert_allclose(
        np.asarray(f.result()),
        np.asarray(
            run_lu_solve(a, b, partitions=((4, 4),), b_partitions=((4, 1),))
        ),
        rtol=1e-5,
        atol=1e-5,
    )


def test_result_before_tick_raises():
    srv = BatchServer(graph="g2")
    f = srv.lu(dd_matrix(32, seed=1), partitions=((2, 2),))
    with pytest.raises(RuntimeError, match="not drained"):
        f.result()
    srv.tick()
    f.result()  # resolves after the tick


def test_submit_validation():
    srv = BatchServer(graph="g2")
    with pytest.raises(ValueError, match="arrays vs"):
        srv.submit("getrf", [jnp.eye(8)], [])
    with pytest.raises(ValueError, match="shape mismatch"):
        srv.lu_solve(jnp.eye(8), jnp.ones((4,)))
    for bad in (0, 48):  # must be a pow2 so chunks match program buckets
        with pytest.raises(ValueError, match="max_batch"):
            BatchServer(max_batch=bad)


def test_tick_failure_is_contained_and_typed():
    """Failure containment (DESIGN.md §10): a failing chunk drain never
    unwinds ``tick()`` — bisection isolates the poisoned request, which
    fails with a typed ``DrainError`` carrying the cause, while every
    other request (in the same chunk AND in later chunks) resolves in the
    same tick."""
    clear_compile_cache()
    srv = BatchServer(graph="g2", max_batch=2, max_retries=0)
    futs = [srv.lu(dd_matrix(32, seed=s), partitions=((2, 2),)) for s in range(3)]
    poisoned = futs[0].rid
    boom = RuntimeError("executor down")
    with faults.inject(
        "serve.drain",
        boom,
        when=lambda ctx: poisoned in ctx["rids"],
        times=None,
    ):
        rep = srv.tick()  # must NOT raise
    assert rep.resolved == 2 and rep.failed == 1 and rep.bisected == 1
    assert srv.pending() == 0
    err = futs[0].exception()
    assert isinstance(err, DrainError) and err.__cause__ is boom
    with pytest.raises(DrainError, match=f"rid={poisoned}"):
        futs[0].result()
    for s in (1, 2):  # chunk-mate and later chunk both resolved, correct
        l, u = futs[s].result()
        np.testing.assert_allclose(
            np.asarray(l) @ np.asarray(u),
            np.asarray(dd_matrix(32, seed=s)),
            rtol=2e-4,
            atol=2e-4,
        )


def test_bisect_isolates_poisoned_request_in_large_bucket():
    """ISSUE acceptance: 16 requests, one deterministically poisoned —
    the other 15 resolve with correct numerics in the SAME tick via
    bisection, only the poisoned future fails, and a subsequent healthy
    repeat tick still replays at 0 compiles / 1 launch."""
    clear_compile_cache()
    n, N = 32, 16
    srv = BatchServer(graph="g2", max_retries=0)

    def one_tick(seed0):
        futs = [
            srv.lu(dd_matrix(n, seed=seed0 + s), partitions=((2, 2),))
            for s in range(N)
        ]
        return futs, srv.tick()

    one_tick(0)  # healthy capture tick: compiles + memoizes the 16-bucket
    futs, _ = (
        [srv.lu(dd_matrix(n, seed=100 + s), partitions=((2, 2),)) for s in range(N)],
        None,
    )
    poisoned = futs[3].rid
    with faults.inject(
        "serve.drain",
        RuntimeError("lane poisoned"),
        when=lambda ctx: poisoned in ctx["rids"],
        times=None,
    ):
        rep = srv.tick()
    assert rep.resolved == N - 1 and rep.failed == 1, rep
    assert rep.bisected >= 1 and srv.pending() == 0
    for s, f in enumerate(futs):
        if f.rid == poisoned:
            assert isinstance(f.exception(), DrainError)
            continue
        l, u = f.result()
        np.testing.assert_allclose(
            np.asarray(l) @ np.asarray(u),
            np.asarray(dd_matrix(n, seed=100 + s)),
            rtol=2e-4,
            atol=2e-4,
        )
    # serving loop intact: the next healthy full tick replays from the memo
    _, rep = one_tick(200)
    assert rep.compiles == 0 and rep.launches == 1 and rep.stacked_drains == 1


def test_check_finite_fails_only_poisoned_lane():
    """Lane-isolated numerics (DESIGN.md §10): a NaN input poisons its own
    stacked lane only — with ``check_finite=True`` that one request fails
    with ``NumericalError`` while its lane-mates resolve correct results
    from the same drain, without any retry (deterministic error)."""
    clear_compile_cache()
    n = 32
    srv = BatchServer(graph="g2", check_finite=True)
    mats = [np.asarray(dd_matrix(n, seed=s)) for s in range(4)]
    mats[2] = mats[2].copy()
    mats[2][0, 0] = np.nan
    futs = [srv.lu(jnp.asarray(m), partitions=((2, 2),)) for m in mats]
    rep = srv.tick()
    assert rep.resolved == 3 and rep.failed == 1 and rep.retried == 0
    assert isinstance(futs[2].exception(), NumericalError)
    for s in (0, 1, 3):
        l, u = futs[s].result()
        np.testing.assert_allclose(
            np.asarray(l) @ np.asarray(u), mats[s], rtol=2e-4, atol=2e-4
        )


def test_deadline_expires_without_draining():
    clear_compile_cache()
    t = [0.0]
    srv = BatchServer(graph="g2", clock=lambda: t[0])
    doomed = srv.lu(dd_matrix(32, seed=0), partitions=((2, 2),), deadline=5.0)
    healthy = srv.lu(dd_matrix(32, seed=1), partitions=((2, 2),))
    t[0] = 10.0  # past the deadline before any tick
    rep = srv.tick()
    assert rep.expired == 1 and rep.resolved == 1
    assert isinstance(doomed.exception(), DeadlineExceeded)
    l, u = healthy.result()
    np.testing.assert_allclose(
        np.asarray(l) @ np.asarray(u),
        np.asarray(dd_matrix(32, seed=1)),
        rtol=2e-4,
        atol=2e-4,
    )


def test_admission_reject_policy():
    srv = BatchServer(graph="g2", max_pending=2, overload_policy="reject")
    kept = [srv.lu(dd_matrix(32, seed=s), partitions=((2, 2),)) for s in range(2)]
    shed = srv.lu(dd_matrix(32, seed=9), partitions=((2, 2),))
    assert shed.done and isinstance(shed.exception(), RejectedError)
    assert srv.pending() == 2 and srv.stats["shed"] == 1
    srv.tick()
    for f in kept:
        assert f.exception() is None


def test_admission_drop_oldest_policy():
    srv = BatchServer(graph="g2", max_pending=2, overload_policy="drop_oldest")
    first = srv.lu(dd_matrix(32, seed=0), partitions=((2, 2),))
    rest = [srv.lu(dd_matrix(32, seed=s), partitions=((2, 2),)) for s in (1, 2)]
    # the NEW request was admitted; the OLDEST queued one was evicted
    assert isinstance(first.exception(), RejectedError)
    assert srv.pending() == 2 and srv.stats["shed"] == 1
    srv.tick()
    for s, f in zip((1, 2), rest):
        l, u = f.result()
        np.testing.assert_allclose(
            np.asarray(l) @ np.asarray(u),
            np.asarray(dd_matrix(32, seed=s)),
            rtol=2e-4,
            atol=2e-4,
        )


def test_retry_budget_with_backoff_then_recovery():
    """A transient drain failure consumes the retry budget with
    exponential tick backoff, then the request recovers and resolves."""
    clear_compile_cache()
    srv = BatchServer(graph="g2", max_retries=2, retry_backoff=1)
    f = srv.lu(dd_matrix(32, seed=5), partitions=((2, 2),))
    with faults.inject("serve.drain", RuntimeError("transient"), times=2):
        rep1 = srv.tick()  # attempt 1 fails -> eligible next tick
        assert rep1.retried == 1 and not f.done and srv.pending() == 1
        rep2 = srv.tick()  # attempt 2 fails -> backoff holds 1 extra tick
        assert rep2.retried == 1 and not f.done
        rep3 = srv.tick()  # held back: nothing eligible this tick
        assert rep3.buckets == 0 and srv.pending() == 1
    rep4 = srv.tick()  # fault exhausted: drain succeeds
    assert rep4.resolved == 1
    l, u = f.result()
    np.testing.assert_allclose(
        np.asarray(l) @ np.asarray(u),
        np.asarray(dd_matrix(32, seed=5)),
        rtol=2e-4,
        atol=2e-4,
    )


def test_retry_budget_exhaustion_fails_typed():
    clear_compile_cache()
    srv = BatchServer(graph="g2", max_retries=1, retry_backoff=1)
    f = srv.lu(dd_matrix(32, seed=6), partitions=((2, 2),))
    with faults.inject("serve.drain", RuntimeError("hard down"), times=None):
        assert srv.tick().retried == 1
        assert srv.tick().failed == 1
    err = f.exception()
    assert isinstance(err, DrainError) and "2 attempt(s)" in str(err)


def test_requeue_preserves_fifo_and_carries_retry_count():
    """Satellite regression: a re-queued request keeps FIFO order within
    its signature bucket (drains BEFORE anything submitted later) and
    carries its retry count across ticks."""
    clear_compile_cache()
    srv = BatchServer(graph="g2", max_retries=2, retry_backoff=1)
    r0 = srv.lu(dd_matrix(32, seed=0), partitions=((2, 2),))
    r1 = srv.lu(dd_matrix(32, seed=1), partitions=((2, 2),))
    with faults.inject(
        "serve.drain",
        RuntimeError("transient"),
        when=lambda ctx: r1.rid in ctx["rids"],
        times=2,  # the [r0, r1] chunk, then the bisected [r1] singleton
    ):
        srv.tick()
    assert r0.exception() is None and not r1.done
    (pend,) = [p for q in srv._queues.values() for p in q]
    assert pend.future.rid == r1.rid
    assert pend.attempts == 1 and pend.retries_left == 1  # count carried
    r2 = srv.lu(dd_matrix(32, seed=2), partitions=((2, 2),))
    with faults.inject("serve.drain", record=True, times=None) as probe:
        rep = srv.tick()
    assert rep.resolved == 2
    # ONE drain served both, with the re-queued request at the FRONT
    assert probe.log[0]["rids"] == [r1.rid, r2.rid]
    for s, f in ((1, r1), (2, r2)):
        l, u = f.result()
        np.testing.assert_allclose(
            np.asarray(l) @ np.asarray(u),
            np.asarray(dd_matrix(32, seed=s)),
            rtol=2e-4,
            atol=2e-4,
        )


def test_future_ergonomics():
    """Satellite: the pending error names rid + signature; ``exception()``
    mirrors concurrent.futures (None on success, the error on failure,
    pending error before the tick)."""
    srv = BatchServer(graph="g2")
    f = srv.lu(dd_matrix(32, seed=1), partitions=((2, 2),))
    with pytest.raises(RuntimeError, match=f"rid={f.rid}.*getrf"):
        f.result()
    with pytest.raises(RuntimeError, match="not drained"):
        f.exception()
    srv.tick()
    assert f.exception() is None
    f.result()
    g = srv.lu(dd_matrix(32, seed=2), partitions=((2, 2),))
    rejected = BatchServer(graph="g2", max_pending=1, overload_policy="reject")
    rejected.lu(dd_matrix(32, seed=3), partitions=((2, 2),))
    h = rejected.lu(dd_matrix(32, seed=4), partitions=((2, 2),))
    assert isinstance(h.exception(), RejectedError)
    with pytest.raises(RejectedError):
        h.result()
    assert not g.done  # unrelated server state never leaks across futures


def test_tick_reports_latency_percentiles():
    clear_compile_cache()
    t = [0.0]
    srv = BatchServer(graph="g2", clock=lambda: t[0])
    for s in range(3):
        srv.lu(dd_matrix(32, seed=s), partitions=((2, 2),))
    t[0] = 0.25  # every request queued 250ms before the drain completes
    rep = srv.tick()
    assert rep.resolved == 3
    assert rep.p50_ms >= 250.0 and rep.p99_ms >= rep.p50_ms
    pct = srv.latency_percentiles()
    assert pct["samples"] == 3 and pct["p50_ms"] >= 250.0
