"""BatchServer: signature-bucketed batched serving (DESIGN.md §7).

Covers: future resolution + numerics for lu / cholesky / lu_solve requests
(vector and matrix right-hand sides), per-signature bucketing inside one
tick, the repeat-tick contract (0 compiles / 1 launch / 1 stacked drain per
signature bucket), max_batch chunking, and the unresolved-future error.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import dd_matrix, spd_matrix
from repro.core.executors import clear_compile_cache
from repro.linalg import run_lu, run_lu_solve
from repro.serve import BatchServer


def _rhs(n, m=None, seed=0):
    rng = np.random.default_rng(seed)
    shape = (n,) if m is None else (n, m)
    return rng.standard_normal(shape).astype(np.float32)


def test_lu_solve_requests_resolve_and_match():
    clear_compile_cache()
    n, N = 64, 5
    srv = BatchServer(graph="g2")
    futs, refs = [], []
    for s in range(N):
        a = dd_matrix(n, seed=s)
        b = _rhs(n, seed=s)
        futs.append(srv.lu_solve(a, b))
        refs.append(run_lu_solve(a, b, partitions=((4, 4),)))
    assert srv.pending() == N and not futs[0].done
    rep = srv.tick()
    assert rep.requests == N and rep.buckets == 1
    assert rep.stacked_drains == 1 and rep.launches == 1
    assert srv.pending() == 0
    for f, r in zip(futs, refs):
        assert f.done
        x = f.result()
        assert x.shape == (n,)  # vector rhs round-trips as a vector
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(r), rtol=1e-5, atol=1e-5
        )


def test_mixed_signatures_bucket_separately():
    clear_compile_cache()
    srv = BatchServer(graph="g2")
    lu_futs = [srv.lu(dd_matrix(64, seed=s)) for s in range(3)]
    chol_futs = [
        srv.cholesky(spd_matrix(32, seed=s), partitions=((4, 4),))
        for s in range(2)
    ]
    rep = srv.tick()
    assert rep.buckets == 2 and rep.drains == 2
    assert rep.stacked_drains == 2  # each homogeneous bucket stacked
    for s, f in enumerate(lu_futs):
        l, u = f.result()
        np.testing.assert_allclose(
            np.asarray(l) @ np.asarray(u),
            np.asarray(dd_matrix(64, seed=s)),
            rtol=2e-4,
            atol=2e-4,
        )
    for s, f in enumerate(chol_futs):
        L = np.asarray(f.result())
        np.testing.assert_allclose(
            L @ L.T, np.asarray(spd_matrix(32, seed=s)), rtol=2e-4, atol=2e-4
        )


def test_repeat_tick_replays_zero_compiles_one_launch():
    """The serving steady state: a structurally repeated tick must do NO
    Python re-splitting and NO recompilation — one program launch per
    signature bucket (DESIGN.md §7 acceptance contract)."""
    clear_compile_cache()
    n = 64
    srv = BatchServer(graph="g2")

    def one_tick(seed0):
        for s in range(4):
            srv.lu_solve(dd_matrix(n, seed=seed0 + s), _rhs(n, seed=s))
        return srv.tick()

    one_tick(0)  # capture tick (compiles once)
    for seed0 in (10, 20):
        rep = one_tick(seed0)
        assert rep.compiles == 0, rep
        assert rep.launches == 1 and rep.stacked_drains == 1
        assert rep.memo_hits == 1 and rep.memo_misses == 0
        for b in rep.per_bucket:
            assert b["compiles"] == 0 and b["launches"] == 1


def test_max_batch_chunks_one_signature():
    clear_compile_cache()
    n = 64
    srv = BatchServer(graph="g2", max_batch=2)
    futs = [srv.lu(dd_matrix(n, seed=s)) for s in range(5)]
    rep = srv.tick()
    assert rep.buckets == 1 and rep.drains == 3  # 2 + 2 + 1
    for s, f in enumerate(futs):
        l, u = f.result()
        np.testing.assert_allclose(
            np.asarray(l) @ np.asarray(u),
            np.asarray(dd_matrix(n, seed=s)),
            rtol=2e-4,
            atol=2e-4,
        )


def test_single_request_tick_still_serves():
    clear_compile_cache()
    srv = BatchServer(graph="g2")
    f = srv.lu(dd_matrix(64, seed=91))
    rep = srv.tick()
    # one request cannot stack (nothing to batch) but must still resolve
    assert rep.requests == 1
    l, u = f.result()
    rl, ru = run_lu(dd_matrix(64, seed=91), partitions=((4, 4),))
    np.testing.assert_allclose(np.asarray(l), np.asarray(rl), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(u), np.asarray(ru), rtol=1e-6)


def test_matrix_rhs_lu_solve():
    clear_compile_cache()
    n = 64
    srv = BatchServer(graph="g2")
    a = dd_matrix(n, seed=7)
    b = _rhs(n, m=8, seed=7)
    f = srv.lu_solve(a, b, b_partitions=((4, 1),))
    srv.tick()
    np.testing.assert_allclose(
        np.asarray(f.result()),
        np.asarray(
            run_lu_solve(a, b, partitions=((4, 4),), b_partitions=((4, 1),))
        ),
        rtol=1e-5,
        atol=1e-5,
    )


def test_result_before_tick_raises():
    srv = BatchServer(graph="g2")
    f = srv.lu(dd_matrix(32, seed=1), partitions=((2, 2),))
    with pytest.raises(RuntimeError, match="not drained"):
        f.result()
    srv.tick()
    f.result()  # resolves after the tick


def test_submit_validation():
    srv = BatchServer(graph="g2")
    with pytest.raises(ValueError, match="arrays vs"):
        srv.submit("getrf", [jnp.eye(8)], [])
    with pytest.raises(ValueError, match="shape mismatch"):
        srv.lu_solve(jnp.eye(8), jnp.ones((4,)))
    for bad in (0, 48):  # must be a pow2 so chunks match program buckets
        with pytest.raises(ValueError, match="max_batch"):
            BatchServer(max_batch=bad)


def test_tick_failure_fails_chunk_and_requeues_rest():
    """If one chunk's drain raises, its futures carry the error, every
    not-yet-drained request stays queued for the next tick, and the
    exception reaches the tick caller — no request is stranded."""
    clear_compile_cache()
    srv = BatchServer(graph="g2", max_batch=2)
    boom = RuntimeError("executor down")
    good = [srv.lu(dd_matrix(32, seed=s), partitions=((2, 2),)) for s in range(2)]
    later = [srv.lu(dd_matrix(32, seed=9), partitions=((2, 2),))]
    calls = {"n": 0}

    import repro.serve.server as server_mod

    real_dispatcher = server_mod.Dispatcher

    class FailingFirst(real_dispatcher):
        def run(self):
            calls["n"] += 1
            if calls["n"] == 1:
                raise boom
            return super().run()

    server_mod.Dispatcher = FailingFirst
    try:
        with pytest.raises(RuntimeError, match="executor down"):
            srv.tick()
    finally:
        server_mod.Dispatcher = real_dispatcher
    # first chunk failed: its futures re-raise the drain error
    for f in good:
        assert f.done
        with pytest.raises(RuntimeError, match="executor down"):
            f.result()
    # the untouched chunk was re-queued and serves on the next tick
    assert srv.pending() == 1
    srv.tick()
    l, u = later[0].result()
    np.testing.assert_allclose(
        np.asarray(l) @ np.asarray(u),
        np.asarray(dd_matrix(32, seed=9)),
        rtol=2e-4,
        atol=2e-4,
    )
