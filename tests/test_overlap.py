"""Async drain overlap (DESIGN.md §12).

Covers: the ``run_async``/``DrainHandle`` surface, bit-identical results
with overlap on vs. off across graphs and stacked batch sizes, the
donation-safety handshake with two in-flight epochs over the same data
handles, deferred ``check_finite`` validation, the ``drain.inflight``
fault site (chunk bisect recovery, poisoned-request isolation with a typed
``InflightError``, drain-memo invalidation on in-flight failure — no
half-resolved futures in any of them), the tick pipeline counters
(``host_idle_us``/``overlap_ratio``), REPRO_VERIFY=1 under overlap, and
the bounded latency window.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import Dispatcher, DrainHandle, GData, dd_matrix
from repro.core.executors import clear_compile_cache
from repro.core.executors.jit_wave import drain_memo_stats
from repro.errors import DrainError, InflightError, NumericalError
from repro.linalg import run_lu
from repro.linalg.lu import utp_getrf
from repro.serve import BatchServer
from repro.testing import faults


@pytest.fixture(autouse=True)
def _no_leaked_faults():
    yield
    faults.reset()


def _mats(n, count, seed0=0):
    return [dd_matrix(n, seed=seed0 + s) for s in range(count)]


# -- run_async surface ---------------------------------------------------------
def test_run_async_matches_run():
    a = dd_matrix(64, seed=3)
    d1 = Dispatcher(graph="g2")
    A1 = GData((64, 64), partitions=((4, 4),), value=a)
    utp_getrf(d1, A1)
    leaves_sync = d1.run()

    d2 = Dispatcher(graph="g2")
    A2 = GData((64, 64), partitions=((4, 4),), value=a)
    utp_getrf(d2, A2)
    handle = d2.run_async()
    assert isinstance(handle, DrainHandle)
    assert handle.leaves == leaves_sync
    blocked = handle.wait()
    assert blocked >= 0.0 and handle.is_ready()
    assert handle.wait() >= 0.0  # idempotent fence
    np.testing.assert_array_equal(np.asarray(A1.value), np.asarray(A2.value))


def test_run_async_on_inline_executor_is_complete():
    # synchronous executors return an already-complete handle — callers
    # need no capability check (DESIGN.md §12)
    d = Dispatcher(graph="g1")
    A = GData((32, 32), partitions=((4, 4),), value=dd_matrix(32, seed=1))
    utp_getrf(d, A)
    handle = d.run_async()
    assert handle.is_ready() and handle.wait() == 0.0


# -- bit-identical overlap on vs. off -----------------------------------------
@pytest.mark.parametrize("graph", ["g1", "g2"])
@pytest.mark.parametrize("n_req", [1, 4, 16])
def test_overlap_on_off_bit_identical(graph, n_req):
    mats = _mats(32, n_req, seed0=7)
    results = {}
    for overlap in (False, True):
        srv = BatchServer(graph=graph, check_finite=True, overlap=overlap)
        futs = [srv.lu(m) for m in mats]
        rep = srv.tick()
        assert rep.resolved == n_req and rep.failed == 0
        results[overlap] = [f.result() for f in futs]
    for (l_off, u_off), (l_on, u_on) in zip(results[False], results[True]):
        np.testing.assert_array_equal(np.asarray(l_off), np.asarray(l_on))
        np.testing.assert_array_equal(np.asarray(u_off), np.asarray(u_on))


def test_overlap_multi_bucket_matches_reference():
    # several signature buckets launch back-to-back with no fences between
    # them; results must stay BIT-identical to the fenced (overlap-off)
    # server — same compiled programs, only the fencing differs — and
    # numerically close to the single-request reference
    srv_on = BatchServer(graph="g2", overlap=True)
    srv_off = BatchServer(graph="g2", overlap=False)
    futs_on, futs_off, refs = [], [], []
    for i, n in enumerate((32, 48, 64)):
        for s in range(3):
            a = dd_matrix(n, seed=10 * i + s)
            futs_on.append(srv_on.lu(a))
            futs_off.append(srv_off.lu(a))
            refs.append(run_lu(a, partitions=((4, 4),)))
    rep = srv_on.tick()
    srv_off.tick()
    assert rep.buckets == 3 and rep.resolved == 9
    for f_on, f_off, (l_ref, u_ref) in zip(futs_on, futs_off, refs):
        l, u = f_on.result()
        l2, u2 = f_off.result()
        np.testing.assert_array_equal(np.asarray(l), np.asarray(l2))
        np.testing.assert_array_equal(np.asarray(u), np.asarray(u2))
        np.testing.assert_allclose(
            np.asarray(l), np.asarray(l_ref), atol=1e-5, rtol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(u), np.asarray(u_ref), atol=1e-5, rtol=1e-5
        )


# -- donation-safety handshake -------------------------------------------------
def test_donation_safety_two_inflight_epochs():
    """Two overlapped drains over the SAME data handles: the second drain's
    grid-reuse fast path donates the first epoch's grid while the first may
    still be in flight.  Fencing either handle must not raise (deleted
    buffers are skipped — their completion is subsumed by the consuming
    epoch), and the numerics must match a fully fenced run."""
    n, count = 32, 4
    mats = _mats(n, count, seed0=21)

    # reference: same double-factorization, fenced between drains
    ref_datas = [
        GData((n, n), partitions=((4, 4),), value=m) for m in mats
    ]
    for _ in range(2):
        d = Dispatcher(graph="g2")
        for A in ref_datas:
            utp_getrf(d, A)
        h = d.run_async()
        h.wait()
    refs = [np.asarray(A.value) for A in ref_datas]

    datas = [GData((n, n), partitions=((4, 4),), value=m) for m in mats]
    d1 = Dispatcher(graph="g2")
    for A in datas:
        utp_getrf(d1, A)
    h1 = d1.run_async()
    epoch1 = datas[0].lane[0]
    assert all(
        A.lane is not None and A.lane[0] is epoch1 for A in datas
    ), "stacked drain should leave all members lane-resident in one epoch"

    d2 = Dispatcher(graph="g2")
    for A in datas:
        utp_getrf(d2, A)
    h2 = d2.run_async()
    # the repeat-drain fast path must have donated epoch 1's grid into
    # drain 2's program — that is the hazard this handshake exists for
    assert epoch1.grid.is_deleted()
    assert h1.wait() >= 0.0  # must skip the donated buffer, not raise
    assert h2.wait() >= 0.0
    for A, ref in zip(datas, refs):
        np.testing.assert_array_equal(np.asarray(A.value), ref)


# -- deferred validation -------------------------------------------------------
def test_deferred_check_finite_isolates_poisoned_lane():
    srv = BatchServer(graph="g2", check_finite=True, overlap=True)
    mats = _mats(32, 4, seed0=31)
    poisoned = np.array(mats[2])
    poisoned[5, 5] = np.nan
    mats[2] = jnp.asarray(poisoned)
    futs = [srv.lu(m) for m in mats]
    rep = srv.tick()
    assert rep.resolved == 3 and rep.failed == 1
    assert rep.host_idle_us > 0.0  # the deferred fence actually blocked
    err = futs[2].exception()
    assert isinstance(err, NumericalError)
    for i in (0, 1, 3):
        assert futs[i].exception() is None
        futs[i].result()


def test_overlap_counters_fence_free_without_check_finite():
    srv = BatchServer(graph="g2", overlap=True)
    for m in _mats(32, 4, seed0=41):
        srv.lu(m)
    rep = srv.tick()
    assert rep.resolved == 4
    assert rep.host_idle_us == 0.0 and rep.overlap_ratio == 1.0
    assert srv.stats["host_idle_us"] == 0


# -- drain.inflight fault site -------------------------------------------------
def test_inflight_fault_bisects_and_recovers():
    srv = BatchServer(graph="g2", overlap=True, check_finite=True)
    futs = [srv.lu(m) for m in _mats(32, 4, seed0=51)]
    with faults.inject(
        "drain.inflight",
        RuntimeError("device lost mid-flight"),
        when=lambda ctx: "rids" in ctx,  # the serving fence, not wait()
        times=1,
    ) as fault:
        rep = srv.tick()
    assert fault.fired == 1
    # the transient in-flight failure was isolated by synchronous half
    # re-drains; every request still resolved in this tick
    assert rep.bisected >= 1 and rep.resolved == 4 and rep.failed == 0
    for f in futs:
        assert f.done and f.exception() is None
        f.result()


def test_inflight_poisoned_request_fails_typed_and_others_resolve():
    srv = BatchServer(graph="g2", overlap=True, max_retries=1)
    futs = [srv.lu(m) for m in _mats(32, 4, seed0=61)]
    target = futs[1].rid
    with faults.inject(
        "drain.inflight",
        RuntimeError("device lost mid-flight"),
        when=lambda ctx: target in ctx.get("rids", ()),
        times=None,
    ):
        for _ in range(8):
            srv.tick()
            if all(f.done for f in futs):
                break
    # no half-resolved futures: every future is done, exactly one failed
    assert all(f.done for f in futs)
    err = futs[1].exception()
    assert isinstance(err, InflightError) and isinstance(err, DrainError)
    assert "attempt" in str(err)
    for i in (0, 2, 3):
        assert futs[i].exception() is None
        futs[i].result()
    assert srv.stats["retried"] >= 1  # the retry budget was consumed first


def test_inflight_failure_invalidates_drain_memo():
    clear_compile_cache()
    a = dd_matrix(32, seed=71)
    d = Dispatcher(graph="g2")
    A = GData((32, 32), partitions=((4, 4),), value=a)
    utp_getrf(d, A)
    handle = d.run_async()
    before = drain_memo_stats()
    assert before["entries"] == 1  # this drain captured its memo entry
    with faults.inject("drain.inflight", RuntimeError("mid-flight")):
        with pytest.raises(RuntimeError):
            handle.wait()
    after = drain_memo_stats()
    assert after["entries"] == 0
    assert after["invalidations"] == before["invalidations"] + 1
    # the next healthy occurrence simply re-captures
    d2 = Dispatcher(graph="g2")
    A2 = GData((32, 32), partitions=((4, 4),), value=a)
    utp_getrf(d2, A2)
    d2.run_async().wait()
    assert drain_memo_stats()["entries"] == 1


# -- REPRO_VERIFY under overlap ------------------------------------------------
def test_verify_green_under_overlap(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "1")
    srv = BatchServer(graph="g2", overlap=True, check_finite=True)
    futs = [srv.lu(m) for m in _mats(32, 4, seed0=81)]
    rep = srv.tick()
    assert rep.resolved == 4 and rep.failed == 0
    for f in futs:
        l, u = f.result()
        assert np.isfinite(np.asarray(l)).all()


# -- bounded latency window ----------------------------------------------------
def test_latency_window_is_bounded():
    srv = BatchServer(graph="g2", latency_window=8)
    futs = [srv.lu(m) for m in _mats(32, 12, seed0=91)]
    rep = srv.tick()
    assert rep.resolved == 12
    assert srv._latencies.maxlen == 8 and len(srv._latencies) == 8
    pct = srv.latency_percentiles()
    assert pct["samples"] == 8 and pct["p50_ms"] >= 0.0
    # per-tick percentiles still cover the whole tick's resolved set
    assert rep.p50_ms >= 0.0 and rep.p99_ms >= rep.p50_ms
    for f in futs:
        f.result()


def test_latency_window_validation():
    with pytest.raises(ValueError):
        BatchServer(latency_window=0)
