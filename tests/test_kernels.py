"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in kernels/ref.py (assignment deliverable (c))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.data import dd_matrix, spd_matrix
from repro.kernels import ops, ref

DTYPES = [jnp.float32]
SIZES = [8, 16, 32]


def rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype) * 0.3


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_potrf(n, dtype):
    a = spd_matrix(n, dtype=dtype, seed=n)
    out = ops.potrf(a, interpret=True)
    np.testing.assert_allclose(out, ref.potrf(a), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", SIZES)
def test_trsm(n):
    l = ref.potrf(spd_matrix(n, seed=n))
    b = rand(1, n, n)
    out = ops.trsm(l, b, interpret=True)
    np.testing.assert_allclose(out, ref.trsm(l, b), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("n", SIZES)
def test_syrk(n):
    a, c = rand(2, n, n), rand(3, n, n)
    out = ops.syrk(a, c, interpret=True)
    np.testing.assert_allclose(out, ref.syrk(a, c), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", SIZES)
def test_gemm(n):
    a, b, c = rand(4, n, n), rand(5, n, n), rand(6, n, n)
    out = ops.gemm(a, b, c, interpret=True)
    np.testing.assert_allclose(out, ref.gemm(a, b, c), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", SIZES)
def test_getrf(n):
    a = dd_matrix(n, seed=n)
    packed = ops.getrf(a, interpret=True)
    np.testing.assert_allclose(packed, ref.getrf(a), rtol=2e-4, atol=2e-4)
    # packed L\U really factors a: tril(,-1)+I @ triu == a
    l = jnp.tril(packed, -1) + jnp.eye(n)
    u = jnp.triu(packed)
    np.testing.assert_allclose(l @ u, a, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", SIZES)
def test_trsml_trsmu_mask_packed_junk(n):
    """Solve leaves read only their triangle: packed L\\U input is fine."""
    packed = ref.getrf(dd_matrix(n, seed=n))
    b = rand(16, n, n)
    np.testing.assert_allclose(
        ops.trsml(packed, b, interpret=True),
        ref.trsml(packed, b), rtol=2e-3, atol=2e-3,
    )
    np.testing.assert_allclose(
        ops.trsmu(packed, b, interpret=True),
        ref.trsmu(packed, b), rtol=2e-3, atol=2e-3,
    )
    np.testing.assert_allclose(
        ops.trsmul(packed, b, interpret=True),
        ref.trsmul(packed, b), rtol=2e-3, atol=2e-3,
    )


@pytest.mark.parametrize("n", SIZES)
def test_trsmul(n):
    """Left-upper TRSM (the fourth orientation): x = inv(triu(u)) @ b."""
    u = jnp.triu(dd_matrix(n, seed=n))
    b = rand(21, n, n)
    out = ops.trsmul(u, b, interpret=True)
    np.testing.assert_allclose(out, ref.trsmul(u, b), rtol=2e-3, atol=2e-3)
    # solves the actual system
    np.testing.assert_allclose(u @ out, b, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("bc", [1, 8])
def test_trsmul_nonsquare_rhs(bc):
    """RHS tiles may be non-square (blocked vector right-hand sides)."""
    n = 16
    u = jnp.triu(dd_matrix(n, seed=2))
    b = rand(22, n, bc)
    np.testing.assert_allclose(
        ops.trsmul(u, b, interpret=True), ref.trsmul(u, b),
        rtol=2e-3, atol=2e-3,
    )
    np.testing.assert_allclose(
        ops.trsml(u, b, interpret=True), ref.trsml(u, b),
        rtol=2e-3, atol=2e-3,
    )


@pytest.mark.parametrize("n", [8, 16])
def test_lu_solve_leaf(n):
    """The composed LUSOLVE leaf: (packed, x) with a @ x == b."""
    a = dd_matrix(n, seed=n)
    b = rand(23, n, n)
    packed, x = ops.lu_solve(a, b, interpret=True)
    rpacked, rx = ref.lu_solve(a, b)
    np.testing.assert_allclose(packed, rpacked, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(x, rx, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(a @ x, b, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("n", SIZES)
def test_gemmnn(n):
    a, b, c = rand(17, n, n), rand(18, n, n), rand(19, n, n)
    out = ops.gemmnn(a, b, c, interpret=True)
    np.testing.assert_allclose(out, ref.gemmnn(a, b, c), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("batch", [1, 3])
@pytest.mark.parametrize("n", [8, 16])
def test_batched_lu_kernels(batch, n):
    a = jnp.stack([dd_matrix(n, seed=i) for i in range(batch)])
    packed = ops.batched_getrf(a, interpret=True)
    np.testing.assert_allclose(
        packed, jax.vmap(ref.getrf)(a), rtol=2e-4, atol=2e-4
    )
    b = rand(20, batch, n, n)
    np.testing.assert_allclose(
        ops.batched_trsml(packed, b, interpret=True),
        jax.vmap(ref.trsml)(packed, b), rtol=2e-3, atol=2e-3,
    )
    np.testing.assert_allclose(
        ops.batched_trsmu(packed, b, interpret=True),
        jax.vmap(ref.trsmu)(packed, b), rtol=2e-3, atol=2e-3,
    )
    np.testing.assert_allclose(
        ops.batched_trsmul(packed, b, interpret=True),
        jax.vmap(ref.trsmul)(packed, b), rtol=2e-3, atol=2e-3,
    )
    np.testing.assert_allclose(
        ops.batched_gemmnn(packed, b, a, interpret=True),
        jax.vmap(ref.gemmnn)(packed, b, a), rtol=1e-4, atol=1e-4,
    )


@pytest.mark.parametrize("batch", [1, 3])
@pytest.mark.parametrize("n", [8, 16])
def test_batched_kernels(batch, n):
    a = jnp.stack([spd_matrix(n, seed=i) for i in range(batch)])
    L = ops.batched_potrf(a, interpret=True)
    want = jax.vmap(ref.potrf)(a)
    np.testing.assert_allclose(L, want, rtol=2e-4, atol=2e-4)
    b = rand(7, batch, n, n)
    np.testing.assert_allclose(
        ops.batched_trsm(L, b, interpret=True),
        jax.vmap(ref.trsm)(L, b), rtol=2e-3, atol=2e-3,
    )
    np.testing.assert_allclose(
        ops.batched_syrk(b, a, interpret=True),
        jax.vmap(ref.syrk)(b, a), rtol=1e-4, atol=1e-4,
    )


@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (32, 32, 32, 16, 16, 16),
    (64, 128, 32, 32, 64, 16),
    (128, 64, 128, 128, 64, 128),
])
def test_matmul_tiled(m, k, n, bm, bk, bn):
    a = rand(8, m, k)
    b = rand(9, k, n)
    out = ops.matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(out, ref.matmul(a, b), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 2, 2, 32, 8),
    (2, 4, 2, 64, 16),
    (1, 8, 1, 32, 32),  # MQA
])
@pytest.mark.parametrize("window", [0, 16])
def test_flash_attention(dtype, B, Hq, Hkv, S, D, window):
    q = rand(10, B, Hq, S, D).astype(dtype)
    k = rand(11, B, Hkv, S, D).astype(dtype)
    v = rand(12, B, Hkv, S, D).astype(dtype)
    out = ops.flash_attention(
        q, k, v, causal=True, window=window, block_q=16, block_k=16,
        interpret=True,
    )
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        out.astype(np.float32), want.astype(np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_matches_model_sdpa():
    """The kernel and the model's portable _sdpa agree (same semantics)."""
    from repro.models.attention import _sdpa

    B, H, S, D = 2, 4, 32, 16
    q = rand(13, B, S, H, D)
    k = rand(14, B, S, H, D)
    v = rand(15, B, S, H, D)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    want = _sdpa(q, k, v, pos, pos, None, 0)
    out = ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, block_q=16, block_k=16,
        interpret=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)
