"""UTP core: data versioning -> DAG edges -> wave schedule (paper §2.2).

Includes hypothesis property tests: for random task streams over a block
grid, the wave schedule must (a) contain every task exactly once, (b) never
reorder two tasks whose accesses conflict (RAW/WAR/WAW), and (c) equal the
sequential program order semantics when executed.
"""

import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: vendored fallback (DESIGN.md §13)
    from repro.testing.proptest import given, settings, strategies as st

from repro.core import Access, DepTracker, GData, GTask, Operation


class NopOp(Operation):
    name = "nop"

    def __init__(self, modes):
        self._modes = modes

    def default_modes(self, n):
        return self._modes


def mktask(data, accesses):
    """accesses: list of ((r, c), Access)."""
    views = [data(r, c) for (r, c), _ in accesses]
    modes = [m for _, m in accesses]
    return GTask(NopOp(modes), None, views, modes)


def test_raw_dependency():
    A = GData((4, 4), partitions=((2, 2),))
    t1 = mktask(A, [((0, 0), Access.WRITE)])
    t2 = mktask(A, [((0, 0), Access.READ)])
    d = DepTracker()
    d.add(t1)
    d.add(t2)
    waves = d.waves()
    assert [len(w) for w in waves] == [1, 1]
    assert waves[0][0].id == t1.id


def test_independent_tasks_one_wave():
    A = GData((4, 4), partitions=((2, 2),))
    tasks = [mktask(A, [((i, j), Access.WRITE)]) for i in range(2) for j in range(2)]
    d = DepTracker()
    for t in tasks:
        d.add(t)
    assert [len(w) for w in d.waves()] == [4]


def test_war_and_waw():
    A = GData((4, 4), partitions=((2, 2),))
    r = mktask(A, [((1, 1), Access.READ)])
    w1 = mktask(A, [((1, 1), Access.WRITE)])
    w2 = mktask(A, [((1, 1), Access.WRITE)])
    d = DepTracker()
    d.add(r)
    d.add(w1)
    d.add(w2)
    waves = d.waves()
    order = {t.id: i for i, w in enumerate(waves) for t in w}
    assert order[r.id] < order[w1.id] < order[w2.id]


def test_readers_parallel_between_writes():
    A = GData((4, 4), partitions=((2, 2),))
    w1 = mktask(A, [((0, 1), Access.WRITE)])
    r1 = mktask(A, [((0, 1), Access.READ)])
    r2 = mktask(A, [((0, 1), Access.READ)])
    w2 = mktask(A, [((0, 1), Access.WRITE)])
    d = DepTracker()
    for t in (w1, r1, r2, w2):
        d.add(t)
    waves = d.waves()
    order = {t.id: i for i, w in enumerate(waves) for t in w}
    assert order[r1.id] == order[r2.id]  # readers run together
    assert order[w1.id] < order[r1.id] < order[w2.id]


# -- property tests -----------------------------------------------------------
@st.composite
def task_stream(draw):
    n_tasks = draw(st.integers(1, 24))
    grid = draw(st.sampled_from([2, 3]))
    stream = []
    for _ in range(n_tasks):
        n_args = draw(st.integers(1, 3))
        accesses = []
        for _ in range(n_args):
            rc = (draw(st.integers(0, grid - 1)), draw(st.integers(0, grid - 1)))
            mode = draw(st.sampled_from(list(Access)))
            accesses.append((rc, mode))
        stream.append(accesses)
    return grid, stream


def conflicts(a, b):
    for rc1, m1 in a:
        for rc2, m2 in b:
            if rc1 == rc2 and (m1.writes or m2.writes):
                return True
    return False


@settings(max_examples=60, deadline=None)
@given(task_stream())
def test_wave_schedule_respects_program_order(spec):
    grid, stream = spec
    A = GData((4 * grid, 4 * grid), partitions=((grid, grid),))
    tasks = [mktask(A, acc) for acc in stream]
    d = DepTracker()
    for t in tasks:
        d.add(t)
    waves = d.waves()
    flat = [t.id for w in waves for t in w]
    assert sorted(flat) == sorted(t.id for t in tasks)  # completeness
    order = {t.id: i for i, w in enumerate(waves) for t in w}
    for i, ti in enumerate(tasks):
        for j in range(i + 1, len(tasks)):
            tj = tasks[j]
            if conflicts(stream[i], stream[j]):
                assert order[ti.id] < order[tj.id], (
                    f"conflicting tasks reordered: {stream[i]} vs {stream[j]}"
                )


@settings(max_examples=30, deadline=None)
@given(task_stream())
def test_wave_execution_matches_sequential(spec):
    """Executing add-one tasks per wave == executing them sequentially."""
    grid, stream = spec
    # interpret each task as: out_blocks += 1 + sum(read blocks mean)
    def run(order_tasks, stream_by_id):
        M = np.zeros((grid, grid))
        for t, acc in order_tasks:
            reads = [M[rc] for rc, m in acc if m.reads]
            bump = 1.0 + float(np.sum(reads))
            for rc, m in acc:
                if m.writes:
                    M[rc] = M[rc] + bump
        return M

    A = GData((4 * grid, 4 * grid), partitions=((grid, grid),))
    tasks = [mktask(A, acc) for acc in stream]
    d = DepTracker()
    for t in tasks:
        d.add(t)
    waves = d.waves()
    seq = run(list(zip(tasks, stream)), None)
    by_id = {t.id: acc for t, acc in zip(tasks, stream)}
    wave_order = [(t, by_id[t.id]) for w in waves for t in w]
    par = run(wave_order, None)
    np.testing.assert_allclose(par, seq, rtol=1e-12)
