"""Self-healing serving (DESIGN.md §14): breakers, watchdog, degradation.

Deterministic coverage of each mechanism — circuit-breaker trip /
half-open probe / re-close, hung-drain watchdog timeout with typed
``DrainStalledError``, device-OOM cap halving with split re-drains and
slow recovery, the HEALTHY/DEGRADED/DRAINING health machine with graceful
``drain()``, and seeded full-jitter on the retry backoff — plus the chaos
property: a randomized multi-site fault schedule (raise + stall + OOM
across ticks, overlap on and off) must end with every submitted future
resolved-or-typed-failed, no lost futures, no wedged tick, and every
breaker back to CLOSED once faults clear.

When hypothesis is absent (offline CI container) the vendored fallback
engine runs the same property — these tests never skip (DESIGN.md §13).
"""

import time
from contextlib import ExitStack

import numpy as np
import pytest

from repro.core import dd_matrix, spd_matrix
from repro.core.executors import clear_compile_cache
from repro.errors import (
    CircuitOpenError,
    DrainStalledError,
    RejectedError,
    ResourceExhausted,
    ServeError,
)
from repro.serve import BatchServer
from repro.testing import faults

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: vendored fallback (DESIGN.md §13)
    from repro.testing.proptest import given, settings, strategies as st


@pytest.fixture(autouse=True)
def _no_leaked_faults():
    yield
    faults.reset()


_N, _P = 32, 2


def _submit_lu(srv, seed=0):
    return srv.lu(dd_matrix(_N, seed=seed), partitions=((_P, _P),))


def _submit_chol(srv, seed=0):
    return srv.cholesky(spd_matrix(_N, seed=seed), partitions=((_P, _P),))


def _tick_healthy(srv, n=1, seed0=0):
    """One healthy tick: n lu requests, all expected to resolve."""
    futs = [_submit_lu(srv, seed=seed0 + s) for s in range(n)]
    rep = srv.tick()
    for f in futs:
        assert f.exception() is None
    return rep


# -- circuit breakers ----------------------------------------------------------


def test_breaker_trips_open_and_fails_fast():
    clear_compile_cache()
    srv = BatchServer(graph="g2", max_retries=0, breaker_threshold=3)
    boom = RuntimeError("persistently poisoned bucket")
    with faults.inject("serve.drain", lambda: boom, times=None):
        for s in range(3):  # three singleton failures = threshold
            f = _submit_lu(srv, seed=s)
            rep = srv.tick()
            assert f.done and f.exception() is not None
        assert rep.breaker_trips == 1
        assert rep.breaker_state == "open"
        assert srv.health() == "DEGRADED"
    # incoming submits fail fast WITHOUT draining (fault already cleared:
    # a drain would succeed — the breaker fails it before any drain)
    f = _submit_lu(srv, seed=99)
    assert isinstance(f.exception(), CircuitOpenError)
    assert srv.stats["breaker_fast_fails"] == 1


def test_breaker_fails_queued_requests_fast():
    """Requests already IN the queue when their bucket trips (here: held by
    retry backoff) fail fast at the next tick — no drain, no retry."""
    clear_compile_cache()
    srv = BatchServer(
        graph="g2",
        max_retries=1,
        retry_backoff=4,
        breaker_threshold=2,
        breaker_cooldown=100,
    )
    futs = [_submit_lu(srv, seed=s) for s in range(2)]
    with faults.inject("serve.drain", RuntimeError("boom"), times=None):
        rep0 = srv.tick()  # both fail + re-queue with backoff; breaker trips
    assert rep0.retried == 2 and rep0.breaker_trips == 1
    assert not futs[0].done and srv.pending() == 2
    rep = srv.tick()  # fault cleared, but the bucket is OPEN: fail fast
    for f in futs:
        assert isinstance(f.exception(), CircuitOpenError)
    assert rep.breaker_fast_fails == 2 and rep.drains == 0


def test_breaker_half_open_probe_recloses():
    clear_compile_cache()
    srv = BatchServer(
        graph="g2", max_retries=0, breaker_threshold=2, breaker_cooldown=2
    )
    with faults.inject("serve.drain", RuntimeError("boom"), times=None):
        for s in range(2):
            _submit_lu(srv, seed=s)
            srv.tick()
    assert srv.breaker_round_trips() == 0
    # cooldown: two empty ticks; the sweep half-opens at tick start
    srv.tick()
    srv.tick()
    # probe + a second request: only the probe drains this tick, the
    # other rides behind it and resolves next tick once the breaker closes
    probe = _submit_lu(srv, seed=10)
    behind = _submit_lu(srv, seed=11)
    rep = srv.tick()
    assert probe.exception() is None
    assert rep.breaker_closes == 1
    assert not behind.done  # held behind the probe
    rep2 = srv.tick()
    assert behind.exception() is None
    assert srv.breaker_round_trips() == 1
    assert srv.health() == "HEALTHY"
    assert rep2.breaker_state == "closed"


def test_half_open_probe_failure_retrips():
    clear_compile_cache()
    srv = BatchServer(
        graph="g2", max_retries=0, breaker_threshold=2, breaker_cooldown=1
    )
    with faults.inject("serve.drain", RuntimeError("boom"), times=None):
        for s in range(2):
            _submit_lu(srv, seed=s)
            srv.tick()
        srv.tick()  # cooldown elapses: breaker half-opens
        probe = _submit_lu(srv, seed=10)
        rep = srv.tick()  # probe drains, fails -> re-trips OPEN
    assert probe.done and probe.exception() is not None
    assert rep.breaker_trips == 1
    assert srv.breaker_round_trips() == 0
    f = _submit_lu(srv, seed=20)
    assert isinstance(f.exception(), CircuitOpenError)


def test_single_poisoned_request_does_not_trip_breaker():
    """Bisect successes reset the consecutive-failure count: one poisoned
    request among healthy bucket-mates, tick after tick, never trips."""
    clear_compile_cache()
    srv = BatchServer(graph="g2", max_retries=0, breaker_threshold=2)
    for round_ in range(3):
        futs = [_submit_lu(srv, seed=round_ * 8 + s) for s in range(4)]
        poison = futs[0].rid
        with faults.inject(
            "serve.drain",
            RuntimeError("poisoned"),
            when=lambda ctx: poison in ctx["rids"],
            times=None,
        ):
            srv.tick()
        assert futs[0].exception() is not None
        for f in futs[1:]:
            assert f.exception() is None
    assert srv.stats["breaker_trips"] == 0
    assert srv.health() == "HEALTHY"


# -- hung-drain watchdog -------------------------------------------------------


def test_watchdog_fails_stalled_chunk_typed():
    clear_compile_cache()
    srv = BatchServer(graph="g2", watchdog_s=0.05, max_retries=3)
    futs = [_submit_lu(srv, seed=s) for s in range(2)]
    with faults.inject("drain.stall", delay_s=0.2):
        t0 = time.perf_counter()
        rep = srv.tick()
        wall = time.perf_counter() - t0
    assert rep.watchdog_fires == 1
    # NOT retried despite the generous retry budget: both futures carry
    # the typed stall error this same tick
    for f in futs:
        assert isinstance(f.exception(), DrainStalledError)
    assert wall < 5.0  # the tick never blocked past budget + injected delay
    # next tick is healthy again (memo was invalidated, re-captures clean)
    rep2 = _tick_healthy(srv, n=2, seed0=10)
    assert rep2.resolved == 2 and rep2.watchdog_fires == 0


def test_watchdog_unarmed_by_default():
    clear_compile_cache()
    srv = BatchServer(graph="g2")
    with faults.inject("drain.stall", delay_s=0.2):
        rep = _tick_healthy(srv, n=1)
    # no watchdog: the stall site never fires, nothing is delayed or failed
    assert rep.watchdog_fires == 0 and rep.resolved == 1


def test_dispatcher_wait_timeout_raises_typed():
    from repro.core import Dispatcher, GData, GTask
    from repro.core.operation import OpRegistry

    clear_compile_cache()

    def drain_async():
        d = Dispatcher(graph="g2")
        a = dd_matrix(_N, seed=0)
        data = GData(a.shape, partitions=((_P, _P),), dtype=a.dtype, value=a)
        d.submit_task(
            GTask(OpRegistry.get("getrf"), None, [data.root_view()])
        )
        return d.run_async()

    with faults.inject("drain.stall", delay_s=0.2):
        with pytest.raises(DrainStalledError):
            drain_async().wait(timeout=0.05)
    # a fresh drain after the stall is clean (memo was invalidated)
    assert drain_async().wait(timeout=30.0) >= 0.0


# -- adaptive degradation under memory pressure --------------------------------


def test_oom_splits_chunk_and_degrades_cap():
    clear_compile_cache()
    srv = BatchServer(graph="g2", max_batch=4, degrade_recovery=3)
    futs = [_submit_lu(srv, seed=s) for s in range(4)]
    with faults.inject(
        "launch.oom", lambda: ResourceExhausted("RESOURCE_EXHAUSTED: injected")
    ):
        rep = srv.tick()
    # the OOM'd 4-chunk re-drained as two healthy halves, same tick
    assert rep.oom_events == 1
    for f in futs:
        assert f.exception() is None
    # the two same-tick half successes count toward recovery (2 of 3)
    assert rep.degraded_buckets == 1
    assert srv.health() == "DEGRADED"
    sig = futs[0].signature
    assert srv._bucket_cap(sig) == 2  # halved
    # one more healthy drain completes the recovery: cap steps back up
    _tick_healthy(srv, n=1, seed0=100)
    assert srv._bucket_cap(sig) == 4
    assert srv.health() == "HEALTHY"


def test_oom_singleton_fails_typed_never_retried():
    clear_compile_cache()
    srv = BatchServer(graph="g2", max_retries=5)
    f = _submit_lu(srv, seed=0)
    with faults.inject(
        "launch.oom",
        lambda: ResourceExhausted("RESOURCE_EXHAUSTED: injected"),
        times=None,
    ):
        rep = srv.tick()
    # a request that OOMs ALONE reproduces at any size: typed, no retry
    assert isinstance(f.exception(), ResourceExhausted)
    assert rep.retried == 0 and rep.failed == 1


def test_oom_textual_match_wraps_generic_error():
    clear_compile_cache()
    srv = BatchServer(graph="g2", max_retries=5)
    f = _submit_lu(srv, seed=0)
    with faults.inject(
        "launch.oom",
        lambda: RuntimeError("RESOURCE_EXHAUSTED: out of memory allocating"),
        times=None,
    ):
        srv.tick()
    err = f.exception()
    assert isinstance(err, ResourceExhausted)
    assert isinstance(err.__cause__, RuntimeError)


# -- health + graceful shutdown ------------------------------------------------


def test_drain_flushes_queue_and_rejects_new_submits():
    clear_compile_cache()
    srv = BatchServer(graph="g2")
    futs = [_submit_lu(srv, seed=s) for s in range(3)]
    assert srv.health() == "HEALTHY"
    reports = srv.drain()
    assert srv.health() == "DRAINING"
    assert srv.pending() == 0
    assert sum(r.resolved for r in reports) == 3
    for f in futs:
        assert f.exception() is None
    late = _submit_lu(srv, seed=9)
    assert isinstance(late.exception(), RejectedError)


def test_drain_flushes_backoff_held_retries():
    clear_compile_cache()
    srv = BatchServer(graph="g2", max_retries=1, retry_backoff=2)
    with faults.inject("serve.drain", RuntimeError("transient")):
        f = _submit_lu(srv, seed=0)
        srv.tick()  # fails once, re-queued with not_before = tick+2
    assert not f.done
    reports = srv.drain()
    assert f.exception() is None  # retried and resolved during the flush
    assert len(reports) >= 2  # at least the backoff-held ticks


# -- retry jitter --------------------------------------------------------------


def test_retry_jitter_seeded_deterministic_and_bounded():
    clear_compile_cache()

    def run(seed):
        srv = BatchServer(
            graph="g2", max_retries=3, retry_backoff=4, retry_jitter_seed=seed
        )
        f = _submit_lu(srv, seed=0)
        delays = []
        with faults.inject("serve.drain", RuntimeError("boom"), times=3):
            for tick_no in range(200):
                if f.done:
                    break
                before = srv.stats["retried"]
                srv.tick()
                q = [p for q_ in srv._queues.values() for p in q_]
                if srv.stats["retried"] > before and q:
                    delays.append(q[0].not_before - tick_no)
        assert f.exception() is None  # recovered on the final attempt
        return delays

    d1, d2 = run(7), run(7)
    assert d1 == d2  # seeded: reproducible schedule
    for attempt, delay in enumerate(d1, start=1):
        cap = 4 * 2 ** (attempt - 1)
        assert 1 <= delay <= cap  # full jitter stays in [1, cap]
    # and a different seed is allowed to (and here does) differ somewhere
    assert len(d1) == 3


def test_no_jitter_default_keeps_exact_backoff():
    clear_compile_cache()
    srv = BatchServer(graph="g2", max_retries=2, retry_backoff=3)
    f = _submit_lu(srv, seed=0)
    with faults.inject("serve.drain", RuntimeError("boom")):
        srv.tick()  # attempt 1 fails -> not_before = 0 + 3, exactly
        p = next(iter(srv._queues.values()))[0]
        assert p.not_before == 3
    for _ in range(3):
        srv.tick()  # held, held, drained at tick 3
    assert f.exception() is None


# -- chaos property ------------------------------------------------------------


@st.composite
def fault_schedule(draw):
    """A few ticks of traffic, each with an independent fault cocktail:
    0-2 transient drain raises, an optional fence stall, an optional
    launch OOM — overlapping on and off across the schedule."""
    ticks = []
    for _ in range(draw(st.integers(2, 4))):
        ticks.append(
            {
                "lu": draw(st.integers(0, 3)),
                "chol": draw(st.integers(0, 2)),
                "raises": draw(st.integers(0, 2)),
                "stall": draw(st.booleans()),
                "oom": draw(st.booleans()),
            }
        )
    return ticks


@settings(max_examples=4, deadline=None)
@given(plan=fault_schedule(), overlap=st.booleans())
def test_chaos_every_future_resolves_or_fails_typed(plan, overlap):
    """Under a randomized multi-site fault schedule the server must (a)
    resolve or typed-fail 100% of submitted futures — no lost futures, (b)
    never wedge a tick (every tick returns, bounded by the watchdog), and
    (c) return every breaker to CLOSED and health to HEALTHY once the
    faults clear."""
    clear_compile_cache()
    srv = BatchServer(
        graph="g2",
        overlap=overlap,
        max_batch=4,
        max_retries=1,
        watchdog_s=0.3,
        breaker_threshold=3,
        breaker_cooldown=2,
        degrade_recovery=1,
        retry_jitter_seed=42,
    )
    all_futs = []
    seed = 0
    for spec in plan:
        for _ in range(spec["lu"]):
            all_futs.append(_submit_lu(srv, seed=seed))
            seed += 1
        for _ in range(spec["chol"]):
            all_futs.append(_submit_chol(srv, seed=seed))
            seed += 1
        with ExitStack() as stack:
            if spec["raises"]:
                stack.enter_context(
                    faults.inject(
                        "serve.drain",
                        lambda: RuntimeError("chaos: transient drain"),
                        times=spec["raises"],
                    )
                )
            if spec["stall"]:
                stack.enter_context(
                    faults.inject("drain.stall", delay_s=1.0)
                )
            if spec["oom"]:
                stack.enter_context(
                    faults.inject(
                        "launch.oom",
                        lambda: ResourceExhausted("RESOURCE_EXHAUSTED"),
                    )
                )
            t0 = time.perf_counter()
            srv.tick()
            assert time.perf_counter() - t0 < 60.0  # no wedged tick
    # faults cleared: recovery ticks — healthy probes re-close breakers,
    # healthy drains step degraded caps back up, backoff-held retries run
    for i in range(10):
        all_futs.append(_submit_lu(srv, seed=1000 + i))
        all_futs.append(_submit_chol(srv, seed=1000 + i))
        srv.tick()
        if (
            srv.pending() == 0
            and srv.health() == "HEALTHY"
            and all(f.done for f in all_futs)
        ):
            break
    # (a) no lost futures: every one resolved or typed-failed
    for f in all_futs:
        assert f.done, f"lost future rid={f.rid}"
        err = f.exception()
        assert err is None or isinstance(err, ServeError), err
    # (c) breakers all CLOSED, nothing degraded, nothing queued
    assert srv.pending() == 0
    for snap in srv.breakers().values():
        assert snap["state"] == "closed"
    assert srv.health() == "HEALTHY"
    # post-fault steady state: a repeated tick is back to the §7 contract
    rep = _tick_healthy(srv, n=2, seed0=5000)
    rep = _tick_healthy(srv, n=2, seed0=6000)
    assert rep.compiles == 0 and rep.failed == 0
