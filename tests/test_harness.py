"""Evaluation-harness unit tests (DESIGN.md §13): trend-record schema,
baseline diffing on synthetic records, tolerance-band edges, invariant
exactness, and baseline merge semantics.  All synthetic — no benches run."""

import json

import pytest

from benchmarks.harness import (
    Gate,
    MissingBaselineError,
    Result,
    Scenario,
    append_trend,
    check_result,
    load_baseline,
    read_trend,
    save_baseline,
    summarize,
    validate_line,
)
from benchmarks.harness.baseline import Finding


def _result(metrics=None, counters=None, mode="smoke", scenario="synth"):
    return Result(
        scenario=scenario,
        workload="synthetic",
        mode=mode,
        backend="cpu",
        graphs=["g2"],
        metrics=metrics or {},
        counters=counters or {},
        t=1000.0,
    )


def _baseline_for(result, path, band=0.25):
    save_baseline([result], path=str(path), band_default=band)
    return load_baseline(str(path))


# ---------------------------------------------------------------- schema


def test_validate_line_accepts_round_trip():
    line = _result({"a": 1.5}, {"c": 2}).to_line()
    assert validate_line(line) == []
    back = Result.from_line(line)
    assert back.metrics == {"a": 1.5}
    assert back.counters == {"c": 2}


def test_validate_line_flags_problems():
    line = _result({"a": 1.5}, {"c": 2}).to_line()
    for key in ("scenario", "metrics", "counters", "t", "graphs"):
        bad = dict(line)
        del bad[key]
        assert any(key in p for p in validate_line(bad))
    bad = dict(line, schema=99)
    assert any("schema" in p for p in validate_line(bad))
    bad = dict(line, counters={"c": 1.5})
    assert any("not an integer" in p for p in validate_line(bad))
    bad = dict(line, metrics={"a": "fast"})
    assert any("not numeric" in p for p in validate_line(bad))
    bad = dict(line, metrics={"a": True})
    assert any("not numeric" in p for p in validate_line(bad))
    assert validate_line([1, 2]) == ["record is list, not an object"]


def test_append_and_read_trend(tmp_path):
    path = tmp_path / "trend.jsonl"
    r1 = _result({"a": 1.0}, {"c": 0})
    r2 = _result({"a": 2.0}, {"c": 1}, mode="full")
    append_trend(r1, path=str(path))
    append_trend(r2, path=str(path))
    got = read_trend(str(path))
    assert [r.mode for r in got] == ["smoke", "full"]
    assert got[1].metrics["a"] == 2.0


def test_append_trend_refuses_invalid(tmp_path):
    path = tmp_path / "trend.jsonl"
    bad = _result({"a": 1.0}, {"c": 2})
    bad.schema = 99  # future/unknown schema version
    with pytest.raises(ValueError, match="invalid trend line"):
        append_trend(bad, path=str(path))
    assert not path.exists()


# ------------------------------------------------------- baseline diffing


def test_missing_baseline_file_raises(tmp_path):
    with pytest.raises(MissingBaselineError, match="rebaseline"):
        load_baseline(str(tmp_path / "nope.json"))


def test_missing_scenario_is_failure(tmp_path):
    base = _baseline_for(_result({"m": 10.0}), tmp_path / "b.json")
    other = _result({"m": 10.0}, scenario="unrecorded")
    findings = check_result(other, base, [Gate("m", "walltime")])
    assert [f.status for f in findings] == ["missing_baseline"]
    assert findings[0].is_failure
    ok, text = summarize(findings)
    assert not ok and "FAIL" in text


def test_missing_mode_is_failure(tmp_path):
    base = _baseline_for(_result({"m": 10.0}, mode="full"), tmp_path / "b.json")
    smoke = _result({"m": 10.0}, mode="smoke")
    findings = check_result(smoke, base, [Gate("m", "walltime")])
    assert [f.status for f in findings] == ["missing_baseline"]


def test_missing_metric_in_run_is_failure(tmp_path):
    base = _baseline_for(_result({"m": 10.0}), tmp_path / "b.json")
    bare = _result({})
    findings = check_result(bare, base, [Gate("m", "walltime")])
    assert [f.status for f in findings] == ["missing_metric"]
    assert findings[0].is_failure


def test_walltime_regression_and_improvement(tmp_path):
    base = _baseline_for(_result({"m": 100.0}), tmp_path / "b.json")
    gate_hi = [Gate("m", "walltime", higher_is_better=True)]
    # higher_is_better: below the band is a regression...
    f = check_result(_result({"m": 70.0}), base, gate_hi)
    assert [x.status for x in f] == ["regression"] and f[0].is_failure
    # ...above the band is an improvement, and it PASSES
    f = check_result(_result({"m": 140.0}), base, gate_hi)
    assert [x.status for x in f] == ["improvement"] and not f[0].is_failure
    # in-band is ok
    f = check_result(_result({"m": 90.0}), base, gate_hi)
    assert [x.status for x in f] == ["ok"]
    # direction flips with higher_is_better=False
    gate_lo = [Gate("m", "walltime", higher_is_better=False)]
    f = check_result(_result({"m": 140.0}), base, gate_lo)
    assert [x.status for x in f] == ["regression"]
    f = check_result(_result({"m": 70.0}), base, gate_lo)
    assert [x.status for x in f] == ["improvement"]


def test_walltime_band_edges_inclusive(tmp_path):
    base = _baseline_for(_result({"m": 100.0}), tmp_path / "b.json", band=0.25)
    gate = [Gate("m", "walltime", higher_is_better=True)]
    # exactly at ref*(1-band) and ref*(1+band): still ok
    assert check_result(_result({"m": 75.0}), base, gate)[0].status == "ok"
    assert check_result(_result({"m": 125.0}), base, gate)[0].status == "ok"
    # just beyond either edge tips over
    assert (
        check_result(_result({"m": 74.999}), base, gate)[0].status
        == "regression"
    )
    assert (
        check_result(_result({"m": 125.001}), base, gate)[0].status
        == "improvement"
    )


def test_walltime_gate_band_override(tmp_path):
    base = _baseline_for(_result({"m": 100.0}), tmp_path / "b.json", band=0.25)
    tight = [Gate("m", "walltime", band=0.05)]
    assert (
        check_result(_result({"m": 90.0}), base, tight)[0].status
        == "regression"
    )
    loose = [Gate("m", "walltime", band=0.5)]
    assert check_result(_result({"m": 60.0}), base, loose)[0].status == "ok"


def test_invariant_gate_is_exact_and_baseline_free(tmp_path):
    # no walltime gates -> no baseline entry needed at all
    base = {"schema": 1, "scenarios": {}}
    gates = [Gate("compiles", "invariant", "==", 0)]
    ok = check_result(_result(counters={"compiles": 0}), base, gates)
    assert [f.status for f in ok] == ["ok"]
    bad = check_result(_result(counters={"compiles": 1}), base, gates)
    assert [f.status for f in bad] == ["invariant_violated"]
    assert bad[0].is_failure
    ge = [Gate("shed", "invariant", ">=", 1)]
    assert (
        check_result(_result(counters={"shed": 3}), base, ge)[0].status
        == "ok"
    )


def test_ratio_gate_threshold(tmp_path):
    base = {"schema": 1, "scenarios": {}}
    gates = [Gate("speedup", "ratio", ">=", 1.0)]
    assert (
        check_result(_result({"speedup": 1.0}), base, gates)[0].status
        == "ok"
    )
    f = check_result(_result({"speedup": 0.93}), base, gates)
    assert [x.status for x in f] == ["regression"] and f[0].is_failure


def test_gate_validation():
    with pytest.raises(ValueError, match="kind"):
        Gate("m", "latency")
    with pytest.raises(ValueError, match="needs value"):
        Gate("m", "invariant")
    with pytest.raises(ValueError, match="op"):
        Gate("m", "ratio", "<", 1.0)
    # walltime gates need neither op nor value
    Gate("m", "walltime")


def test_save_baseline_merges_modes(tmp_path):
    path = tmp_path / "b.json"
    save_baseline([_result({"m": 1.0}, mode="full")], path=str(path))
    save_baseline([_result({"m": 2.0}, mode="smoke")], path=str(path))
    base = load_baseline(str(path))
    entry = base["scenarios"]["synth"]
    assert entry["full"]["metrics"]["m"] == 1.0
    assert entry["smoke"]["metrics"]["m"] == 2.0
    # and the file on disk is valid, sorted JSON
    on_disk = json.loads(path.read_text())
    assert on_disk["schema"] == 1


def test_scenario_run_rejects_dropped_gated_keys():
    class Broken(Scenario):
        name = "broken"
        gates = (Gate("present", "invariant", "==", 1),)

        def evaluate(self, cfg, gen):
            return {}

        def report(self, cfg, raw):
            return _result(counters={"other": 1}, scenario="broken")

    with pytest.raises(ValueError, match="dropped gated keys"):
        Broken().run("smoke")

    class Fine(Broken):
        name = "fine"

        def report(self, cfg, raw):
            return _result(counters={"present": 1}, scenario="fine")

    assert Fine().run("smoke").counters["present"] == 1


def test_scenario_rejects_unknown_mode():
    with pytest.raises(ValueError, match="unknown mode"):
        Scenario().config("nightly")
