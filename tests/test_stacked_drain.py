"""Homogeneous-root stacking: batched WavePrograms + stacked drain memo
(DESIGN.md §7).

Covers: the stacked-epoch lane machinery on GData, stacking detection
(homogeneous streams stack, heterogeneous / data-sharing / opted-out
streams keep the PR-3 segment-fusion path), one-launch one-compile stacked
drains on both backends, pow2 bucket padding with O(log N) compiles over a
batch-size sweep, the N-independent stacked memo key (N=3 replays the N=4
bucket's capture), the composed LUSOLVE pipeline under stacking, and the
LRU drain memo (eviction + re-capture + counters — satellites of this PR).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    Access,
    Dispatcher,
    GData,
    GTask,
    Operation,
    TaskFlowGraph,
    dd_matrix,
    spd_matrix,
)
from repro.core.data import StackedEpoch, from_grid, to_grid
from repro.core.executors import clear_compile_cache
from repro.core.executors.jit_wave import (
    _DRAIN_MEMO,
    drain_memo_stats,
    set_drain_memo_capacity,
)
from repro.linalg import run_lu, run_lu_batched, run_lu_solve, run_lu_solve_batched
from repro.linalg.cholesky import utp_cholesky
from repro.linalg.lu import utp_getrf


# --------------------------------------------------------------------------
# GData stacked-epoch lanes
# --------------------------------------------------------------------------
class TestStackedEpochLanes:
    def _epoch(self, vals, br=4, bc=4):
        grid = jnp.stack([to_grid(jnp.asarray(v), br, bc) for v in vals])
        return StackedEpoch(grid, (br, bc))

    def test_value_reads_lane(self):
        vals = [
            np.arange(64, dtype=np.float32).reshape(8, 8) + 100 * i
            for i in range(3)
        ]
        ep = self._epoch(vals)
        datas = [GData((8, 8)) for _ in range(3)]
        for i, d in enumerate(datas):
            d.adopt_lane(ep, i)
            assert d.has_value and not d.in_grid_epoch
        for i, d in enumerate(datas):
            np.testing.assert_array_equal(np.asarray(d.value), vals[i])
            assert d.lane is None  # resolved

    def test_enter_grid_slices_lane_without_roundtrip(self):
        vals = [np.full((8, 8), float(i), dtype=np.float32) for i in range(2)]
        ep = self._epoch(vals)
        d = GData((8, 8))
        d.adopt_lane(ep, 1)
        g = d.enter_grid(4, 4)
        assert d.in_grid_epoch and d.grid_block == (4, 4)
        np.testing.assert_array_equal(np.asarray(from_grid(g)), vals[1])

    def test_enter_grid_other_block_flushes_through_value(self):
        vals = [np.arange(64, dtype=np.float32).reshape(8, 8)]
        ep = self._epoch(vals)
        d = GData((8, 8))
        d.adopt_lane(ep, 0)
        g = d.enter_grid(2, 2)
        np.testing.assert_array_equal(np.asarray(from_grid(g)), vals[0])

    def test_value_write_drops_lane(self):
        ep = self._epoch([np.zeros((8, 8), dtype=np.float32)])
        d = GData((8, 8))
        d.adopt_lane(ep, 0)
        d.value = jnp.ones((8, 8))
        assert d.lane is None
        np.testing.assert_array_equal(np.asarray(d.value), np.ones((8, 8)))

    def test_adopt_lane_shape_mismatch_raises(self):
        ep = self._epoch([np.zeros((8, 8), dtype=np.float32)])
        d = GData((16, 16))
        with pytest.raises(ValueError, match="stacked lane shape"):
            d.adopt_lane(ep, 0)


# --------------------------------------------------------------------------
# Stacked drains: detection, one launch/compile, numerics
# --------------------------------------------------------------------------
def _stacked_lu_drain(mats, p, graph="g2"):
    d = Dispatcher(graph=graph)
    roots = []
    for m in mats:
        A = GData(m.shape, partitions=((p, p),), dtype=m.dtype, value=m)
        utp_getrf(d, A)
        roots.append(A)
    n = d.run()
    return d, roots, n


@pytest.mark.parametrize("graph", ["g2", "g2p"])
def test_stacked_lu_one_launch_one_compile(graph):
    clear_compile_cache()
    n, p, N = 64, 4, 3
    mats = [dd_matrix(n, seed=s) for s in range(N)]
    refs = [run_lu(m, partitions=((p, p),)) for m in mats]
    clear_compile_cache()
    d, roots, leaf = _stacked_lu_drain(mats, p, graph)
    assert d.stats["stacked_drains"] == 1
    assert d.executor.stats["launches"] == 1
    assert d.executor.stats["compiles"] == 1
    # the drain expands ONE template: leaf count is the single-root count
    assert leaf == 30
    for A, (rl, ru) in zip(roots, refs):
        packed = np.asarray(A.value)
        l = np.tril(packed, -1) + np.eye(n)
        u = np.triu(packed)
        np.testing.assert_allclose(l, np.asarray(rl), rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(u, np.asarray(ru), rtol=1e-6, atol=1e-6)


def test_stacked_memo_key_is_bucket_not_n():
    """N=3 and N=4 share the pow2 bucket 4: after an N=4 capture, an N=3
    drain is a pure replay — zero recompiles, zero re-splitting, the memo
    key is independent of the exact request count (DESIGN.md §7)."""
    clear_compile_cache()
    n, p = 64, 4
    d4, roots4, _ = _stacked_lu_drain(
        [dd_matrix(n, seed=s) for s in range(4)], p
    )
    assert d4.executor.stats["compiles"] == 1
    assert d4.stats["memo_misses"] == 1
    mats3 = [dd_matrix(n, seed=10 + s) for s in range(3)]
    d3, roots3, _ = _stacked_lu_drain(mats3, p)
    assert d3.stats["memo_hits"] == 1
    assert d3.stats["split"] == d4.stats["split"]  # replay mirrors stats
    assert d3.executor.stats.get("compiles", 0) == 0
    assert d3.executor.stats["launches"] == 1
    for A, m in zip(roots3, mats3):
        packed = np.asarray(A.value)
        l = np.tril(packed, -1) + np.eye(n)
        u = np.triu(packed)
        np.testing.assert_allclose(
            l @ u, np.asarray(m), rtol=2e-4, atol=2e-4
        )


def test_stacked_compile_sweep_is_olog_n():
    """Batch sizes 1..9 bucket to {1(unstacked), 2, 4, 8, 16}: at most 5
    compiled programs across the whole sweep."""
    clear_compile_cache()
    n, p = 32, 2
    total = 0
    for N in range(1, 10):
        d, roots, _ = _stacked_lu_drain(
            [dd_matrix(n, seed=N * 16 + s) for s in range(N)], p
        )
        total += d.executor.stats.get("compiles", 0)
        for A, s in zip(roots, range(N)):
            packed = np.asarray(A.value)
            l = np.tril(packed, -1) + np.eye(n)
            u = np.triu(packed)
            np.testing.assert_allclose(
                l @ u,
                np.asarray(dd_matrix(n, seed=N * 16 + s)),
                rtol=2e-4,
                atol=2e-4,
            )
    assert total <= 5, total


def test_stacked_composed_lu_solve():
    """N composed LUSOLVE roots stack: the full factor+forward+backward
    pipeline runs as one batched program and matches per-request
    run_lu_solve."""
    clear_compile_cache()
    n, p, N = 64, 4, 3
    rng = np.random.default_rng(3)
    mats = [dd_matrix(n, seed=40 + s) for s in range(N)]
    rhss = [rng.standard_normal((n, 8)).astype(np.float32) for _ in range(N)]
    refs = [
        run_lu_solve(a, b, partitions=((p, p),), b_partitions=((p, 1),))
        for a, b in zip(mats, rhss)
    ]
    clear_compile_cache()
    xs = run_lu_solve_batched(
        mats, rhss, partitions=((p, p),), b_partitions=((p, 1),)
    )
    for x, r in zip(xs, refs):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(r), rtol=1e-5, atol=1e-5
        )


def test_run_lu_batched_replays_and_matches():
    clear_compile_cache()
    n, p = 64, 4
    mats = [dd_matrix(n, seed=60 + s) for s in range(4)]
    outs = run_lu_batched(mats, partitions=((p, p),))
    mats2 = [dd_matrix(n, seed=70 + s) for s in range(4)]
    outs2 = run_lu_batched(mats2, partitions=((p, p),))  # memo replay
    for (l, u), m in zip(outs + outs2, mats + mats2):
        np.testing.assert_allclose(
            np.asarray(l) @ np.asarray(u), np.asarray(m), rtol=2e-4, atol=2e-4
        )


def test_redraining_subset_of_stacked_members_keeps_bystander_lane_valid():
    """Donation-safety regression: after a stacked N=4 drain, re-draining
    only 3 of the members must NOT donate the shared epoch grid back into
    the next program (the 4th member still holds a lane of it).  The
    holders refcount on StackedEpoch guards this."""
    clear_compile_cache()
    n, p = 32, 2
    mats = [dd_matrix(n, seed=90 + s) for s in range(4)]
    d, roots, _ = _stacked_lu_drain(mats, p)
    assert d.stats["stacked_drains"] == 1
    # second stacked drain over the first three members' RESULTS
    d2 = Dispatcher(graph="g2")
    for A in roots[:3]:
        utp_getrf(d2, A)
    d2.run()
    assert d2.stats["stacked_drains"] == 1
    # the bystander's lane must still read its ORIGINAL factor
    packed = np.asarray(roots[3].value)
    l = np.tril(packed, -1) + np.eye(n)
    u = np.triu(packed)
    np.testing.assert_allclose(
        l @ u, np.asarray(mats[3]), rtol=2e-4, atol=2e-4
    )


def test_repeat_drain_on_same_members_reuses_epoch_grid():
    """The repeat-tick fast path: draining the SAME member set again finds
    them as lanes 0..N-1 of one epoch (sole holders) and restacks for
    free.  Semantics check: the second factor runs on the first's output."""
    clear_compile_cache()
    n, p = 32, 2
    mats = [dd_matrix(n, seed=95 + s) for s in range(2)]
    d, roots, _ = _stacked_lu_drain(mats, p)
    d2 = Dispatcher(graph="g2")
    for A in roots:
        utp_getrf(d2, A)
    d2.run()
    assert d2.stats["stacked_drains"] == 1
    assert d2.executor.stats.get("compiles", 0) == 0  # same bucket program
    # reference: factor-of-factor computed through the unstacked path
    for A, m in zip(roots, mats):
        ref1 = run_lu(m, partitions=((p, p),))
        ref_packed = np.tril(np.asarray(ref1[0]), -1) + np.asarray(ref1[1])
        ref2 = run_lu(ref_packed, partitions=((p, p),))
        packed = np.asarray(A.value)
        l = np.tril(packed, -1) + np.eye(n)
        u = np.triu(packed)
        np.testing.assert_allclose(
            l, np.asarray(ref2[0]), rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(
            u, np.asarray(ref2[1]), rtol=1e-5, atol=1e-5
        )


# --------------------------------------------------------------------------
# Fallback contract: when streams do NOT stack (DESIGN.md §7)
# --------------------------------------------------------------------------
def test_heterogeneous_stream_keeps_segment_fusion():
    """LU + Cholesky roots: different ops -> no stacking; the PR-3 path
    still compiles both workloads into one program."""
    clear_compile_cache()
    n, p = 64, 4
    a = dd_matrix(n, seed=81)
    b = spd_matrix(n, seed=82)
    d = Dispatcher(graph="g2")
    A = GData(a.shape, partitions=((p, p),), dtype=a.dtype, value=a)
    B = GData(b.shape, partitions=((p, p),), dtype=b.dtype, value=b)
    utp_getrf(d, A)
    utp_cholesky(d, B)
    d.run()
    assert d.stats["stacked_drains"] == 0
    assert d.executor.stats["launches"] == 1


def test_shared_data_roots_do_not_stack():
    """Two GETRF roots on the SAME datum are a dependent chain, not a
    batch: stacking must refuse (args not data-disjoint) and the normal
    versioned drain must run both in order."""
    clear_compile_cache()
    n, p = 64, 4
    m = dd_matrix(n, seed=83)
    d = Dispatcher(graph="g2")
    X = GData(m.shape, partitions=((p, p),), dtype=m.dtype, value=m)
    utp_getrf(d, X)
    utp_getrf(d, X)
    d.run()
    assert d.stats["stacked_drains"] == 0


def test_mixed_geometry_stream_does_not_stack():
    clear_compile_cache()
    p = 4
    d = Dispatcher(graph="g2")
    for n in (64, 32):
        m = dd_matrix(n, seed=84)
        A = GData(m.shape, partitions=((p, p),), dtype=m.dtype, value=m)
        utp_getrf(d, A)
    d.run()
    assert d.stats["stacked_drains"] == 0


def test_stack_roots_opt_out_pins_segment_fusion():
    """Dispatcher(stack_roots=False) reproduces the PR-3 cross-root
    segment fusion exactly: half the prefusion group count, one launch."""
    clear_compile_cache()
    n, p = 64, 4
    d = Dispatcher(graph="g2", stack_roots=False)
    for s in (85, 86):
        m = dd_matrix(n, seed=s)
        A = GData(m.shape, partitions=((p, p),), dtype=m.dtype, value=m)
        utp_getrf(d, A)
    d.run()
    st = d.executor.stats
    assert d.stats["stacked_drains"] == 0
    assert st["launches"] == 1
    assert st["groups_prefusion"] == 2 * st["groups"]


class _InnerValueDepOp(Operation):
    """Non-memoizable block op: its split is allowed to read data values,
    which collect mode cannot honor (nothing has executed yet)."""

    name = "stk_inner_vd"
    memoizable = False

    def default_modes(self, n):
        return [Access.READWRITE]

    def leaf_fn(self, backend):
        return lambda b: b + 1.0

    def split(self, task, submit):
        A = task.args[0]
        for i in range(A.row_part_num()):
            for j in range(A.col_part_num()):
                submit(GTask(_INNER_VD, task, [A(i, j)]))


class _OuterOp(Operation):
    """Memoizable root whose expansion contains non-memoizable children."""

    name = "stk_outer"

    def default_modes(self, n):
        return [Access.READWRITE]

    def leaf_fn(self, backend):
        return lambda b: b + 1.0

    def split(self, task, submit):
        A = task.args[0]
        for i in range(A.row_part_num()):
            for j in range(A.col_part_num()):
                submit(GTask(_INNER_VD, task, [A(i, j)]))


_INNER_VD = _InnerValueDepOp()
_OUTER = _OuterOp()


def test_value_dependent_split_below_root_aborts_stacking():
    """A memoizable root whose expansion SPLITS a non-memoizable op must
    not run stacked: collect mode defers all execution, but a value-
    dependent split may read values earlier leaf scopes produce.  The
    drain must fall back to the normal interleaved path and stay exact."""
    clear_compile_cache()
    graph = TaskFlowGraph("g2deep", split_levels=2, leaf_executor="jit_wave")
    d = Dispatcher(graph=graph)
    roots = []
    for _ in range(2):
        A = GData(
            (8, 8),
            partitions=((2, 2), (2, 2)),
            value=np.zeros((8, 8), dtype=np.float32),
        )
        d.submit_task(GTask(_OUTER, None, [A.root_view()]))
        roots.append(A)
    d.run()
    assert d.stats["stacked_drains"] == 0  # aborted, not stacked
    for A in roots:
        np.testing.assert_array_equal(
            np.asarray(A.value), np.ones((8, 8), dtype=np.float32)
        )


# --------------------------------------------------------------------------
# Dispatcher memo counters (satellite): visible without executor internals
# --------------------------------------------------------------------------
def test_dispatcher_memo_counters_on_unstacked_drains():
    clear_compile_cache()
    a = spd_matrix(32, seed=5)

    def drain():
        d = Dispatcher(graph="g2")
        A = GData(a.shape, partitions=((4, 4),), dtype=a.dtype, value=a)
        utp_cholesky(d, A)
        d.run()
        return d

    d1 = drain()
    assert d1.stats["memo_misses"] == 1 and d1.stats["memo_hits"] == 0
    d2 = drain()
    assert d2.stats["memo_hits"] == 1 and d2.stats["memo_misses"] == 0


# --------------------------------------------------------------------------
# LRU drain memo (satellite): bounded, counted, re-captures after eviction
# --------------------------------------------------------------------------
def test_drain_memo_lru_eviction_and_recapture():
    clear_compile_cache()
    old_cap = _DRAIN_MEMO.capacity
    try:
        set_drain_memo_capacity(2)
        n = 32

        def drain(p):
            a = spd_matrix(n, seed=p)
            d = Dispatcher(graph="g2")
            A = GData(a.shape, partitions=((p, p),), dtype=a.dtype, value=a)
            utp_cholesky(d, A)
            d.run()
            return d

        ev0 = _DRAIN_MEMO.evictions
        drain(2)  # memo: {p2}
        drain(4)  # memo: {p2, p4}
        drain(8)  # memo: {p4, p8} — p2 evicted (LRU)
        assert len(_DRAIN_MEMO) == 2
        assert _DRAIN_MEMO.evictions == ev0 + 1
        d = drain(2)  # evicted structure: miss + re-capture, still correct
        assert d.stats["memo_misses"] == 1 and d.stats["memo_hits"] == 0
        assert len(_DRAIN_MEMO) == 2
        d = drain(2)  # now memoized again
        assert d.stats["memo_hits"] == 1
        stats = drain_memo_stats()
        assert stats["capacity"] == 2 and stats["entries"] == 2
        assert stats["evictions"] >= ev0 + 2  # p8 or p4 fell out above
    finally:
        set_drain_memo_capacity(old_cap)
        clear_compile_cache()


def test_set_drain_memo_capacity_validates():
    with pytest.raises(ValueError):
        set_drain_memo_capacity(0)


def test_drain_memo_capacity_shrink_evicts_immediately():
    clear_compile_cache()
    old_cap = _DRAIN_MEMO.capacity
    try:
        set_drain_memo_capacity(8)
        for p in (2, 4, 8):
            a = spd_matrix(32, seed=p)
            d = Dispatcher(graph="g2")
            A = GData(a.shape, partitions=((p, p),), dtype=a.dtype, value=a)
            utp_cholesky(d, A)
            d.run()
        assert len(_DRAIN_MEMO) == 3
        set_drain_memo_capacity(1)
        assert len(_DRAIN_MEMO) == 1
    finally:
        set_drain_memo_capacity(old_cap)
        clear_compile_cache()
