"""Blocked pivot-free LU + triangular solve as first-class task workloads.

The unified-interface claim (paper abstract, DESIGN.md §6): the SAME
dispatcher, executors, and task-flow graphs g1–g4 that run Cholesky must
run the LU family with zero changes to executor code.  Numerics are checked
against ``jax.scipy.linalg.lu`` / ``solve_triangular`` on strictly
column-diagonally-dominant inputs (where partial pivoting provably selects
P == I, making the pivoted library factors directly comparable), across
both leaf backends, with non-square block counts, and the repeated-drain
compile-cache behaviour is asserted via the PR-1 drain memo.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.scipy.linalg import lu as scipy_lu, solve_triangular

from repro.core import Dispatcher, GData, OpRegistry, dd_matrix, utp_get_parameters
from repro.core.executors import clear_compile_cache
from repro.linalg import run_lu, run_solve
from repro.linalg.lu import utp_getrf


def _mesh_1d():
    return jax.make_mesh((1, 1), ("data", "model"))


def _lu_ref(a):
    p, l, u = scipy_lu(np.asarray(a))
    np.testing.assert_array_equal(np.asarray(p), np.eye(a.shape[0]))
    return np.asarray(l), np.asarray(u)


# --------------------------------------------------------------------------
# run_lu vs jax.scipy.linalg.lu across every graph, both backends
# --------------------------------------------------------------------------
@pytest.mark.parametrize("graph", ["g1", "g2", "g2p"])
@pytest.mark.parametrize("n,parts", [(32, ((2, 2),)), (64, ((4, 4),))])
def test_lu_single_level(graph, n, parts):
    a = dd_matrix(n, seed=n)
    L, U = run_lu(a, graph=graph, partitions=parts)
    l_ref, u_ref = _lu_ref(a)
    np.testing.assert_allclose(np.asarray(L), l_ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(U), u_ref, atol=1e-5)


@pytest.mark.parametrize("graph", ["g3", "g4", "g3flat"])
def test_lu_distributed_graphs(graph):
    n = 64
    a = dd_matrix(n, seed=7)
    parts = ((2, 2), (2, 2)) if graph in ("g3", "g4") else ((4, 4),)
    L, U = run_lu(a, graph=graph, partitions=parts, mesh=_mesh_1d())
    l_ref, u_ref = _lu_ref(a)
    np.testing.assert_allclose(np.asarray(L), l_ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(U), u_ref, atol=1e-5)


def test_lu_same_program_all_graphs_identical():
    """Portability: ONE run_lu program, any graph, same factors."""
    a = dd_matrix(32, seed=11)
    outs = {}
    for g in ("g1", "g2", "g2p"):
        L, U = run_lu(a, graph=g, partitions=((2, 2),))
        outs[g] = (np.asarray(L), np.asarray(U))
    for g, (L, U) in outs.items():
        np.testing.assert_allclose(L, outs["g1"][0], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(U, outs["g1"][1], rtol=1e-5, atol=1e-5)


def test_lu_hierarchical_matches_flat():
    a = dd_matrix(64, seed=9)
    Lf, Uf = run_lu(a, graph="g2", partitions=((4, 4),))
    Lh, Uh = run_lu(a, graph="g3", partitions=((2, 2), (2, 2)), mesh=_mesh_1d())
    np.testing.assert_allclose(np.asarray(Lf), np.asarray(Lh), atol=1e-5)
    np.testing.assert_allclose(np.asarray(Uf), np.asarray(Uh), atol=1e-5)


# --------------------------------------------------------------------------
# run_solve vs solve_triangular, incl. non-square block counts
# --------------------------------------------------------------------------
@pytest.mark.parametrize("graph", ["g1", "g2", "g2p"])
@pytest.mark.parametrize("bshape,bparts", [((64, 64), ((4, 4),)), ((64, 32), ((4, 2),))])
def test_solve_lower(graph, bshape, bparts):
    a = dd_matrix(64, seed=3)
    b = jnp.asarray(
        np.random.default_rng(0).standard_normal(bshape).astype(np.float32)
    )
    x = run_solve(a, b, lower=True, graph=graph, partitions=((4, 4),), b_partitions=bparts)
    want = solve_triangular(a, b, lower=True, unit_diagonal=True)
    np.testing.assert_allclose(np.asarray(x), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("graph", ["g1", "g2", "g2p"])
@pytest.mark.parametrize("bshape,bparts", [((64, 64), ((4, 4),)), ((32, 64), ((2, 4),))])
def test_solve_upper(graph, bshape, bparts):
    a = dd_matrix(64, seed=4)
    b = jnp.asarray(
        np.random.default_rng(1).standard_normal(bshape).astype(np.float32)
    )
    x = run_solve(a, b, lower=False, graph=graph, partitions=((4, 4),), b_partitions=bparts)
    # x @ triu(a) = b  <=>  triu(a)^T x^T = b^T
    want = solve_triangular(a, b.T, lower=False, trans="T").T
    np.testing.assert_allclose(np.asarray(x), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("graph", ["g3", "g4"])
def test_solve_distributed(graph):
    a = dd_matrix(64, seed=6)
    b = jnp.asarray(
        np.random.default_rng(2).standard_normal((64, 32)).astype(np.float32)
    )
    x = run_solve(
        a, b, lower=True, graph=graph,
        partitions=((2, 2), (2, 2)), b_partitions=((2, 2), (2, 1)),
        mesh=_mesh_1d(),
    )
    want = solve_triangular(a, b, lower=True, unit_diagonal=True)
    np.testing.assert_allclose(np.asarray(x), np.asarray(want), atol=1e-5)


def test_lu_then_solve_round_trip():
    """Forward+backward substitution through the packed factor solves a@x=b."""
    n = 64
    a = dd_matrix(n, seed=8)
    b = jnp.asarray(
        np.random.default_rng(3).standard_normal((n, n)).astype(np.float32)
    )
    L, U = run_lu(a, graph="g2", partitions=((4, 4),))
    packed = jnp.tril(L, -1) + U
    y = run_solve(packed, b, lower=True, partitions=((4, 4),))  # L y = b
    # U x = y  <=>  x^T @ U^T = y^T; use the right-sided upper solve on U^T?
    # U^T is lower non-unit — outside the algebra; verify via residual instead.
    np.testing.assert_allclose(
        np.asarray(L @ y), np.asarray(b), atol=1e-4
    )
    np.testing.assert_allclose(np.asarray(L @ U), np.asarray(a), atol=1e-5)


# --------------------------------------------------------------------------
# Wave-program cache: repeated LU drains compile once (PR-1 drain memo)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("graph", ["g2", "g2p"])
def test_repeated_lu_drains_compile_once(graph):
    clear_compile_cache()
    stats = []
    for seed in (1, 2, 3):
        d = Dispatcher(graph=graph)
        A = GData((64, 64), partitions=((4, 4),), dtype=jnp.float32,
                  value=dd_matrix(64, seed=seed))
        utp_getrf(d, A)
        n = d.run()
        stats.append(
            (n, d.executor.stats.get("launches", 0),
             d.executor.stats.get("compiles", 0))
        )
    # 4x4 right-looking LU: sum_k 1 + 2*(3-k) + (3-k)^2 = 16+9+4+1 = 30
    assert stats[0] == (30, 1, 1)  # one compiled WaveProgram, one dispatch
    for rep in stats[1:]:
        assert rep == (30, 1, 0)  # replayed drains: 0 recompiles


def test_lu_ops_registered_and_memoizable():
    for name in ("getrf", "trsml", "trsmu", "gemmnn"):
        op = OpRegistry.get(name)
        assert op.memoizable  # geometry-pure splits ride the drain memo


# --------------------------------------------------------------------------
# Satellite: utp_get_parameters rejects non-positive sizes/partitions
# --------------------------------------------------------------------------
def test_utp_get_parameters_accepts_positive():
    assert utp_get_parameters(["1024", "8", "4"]) == (1024, 8, 4)
    assert utp_get_parameters([]) == (1024, 4, 4)


@pytest.mark.parametrize("argv", [["-4"], ["1024", "-8"], ["1024", "8", "0"], ["0"]])
def test_utp_get_parameters_rejects_nonpositive(argv):
    with pytest.raises(ValueError, match="positive"):
        utp_get_parameters(argv)
