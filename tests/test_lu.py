"""Blocked pivot-free LU + triangular solve as first-class task workloads.

The unified-interface claim (paper abstract, DESIGN.md §6): the SAME
dispatcher, executors, and task-flow graphs g1–g4 that run Cholesky must
run the LU family with zero changes to executor code.  Numerics are checked
against ``jax.scipy.linalg.lu`` / ``solve_triangular`` on strictly
column-diagonally-dominant inputs (where partial pivoting provably selects
P == I, making the pivoted library factors directly comparable), across
both leaf backends, with non-square block counts, and the repeated-drain
compile-cache behaviour is asserted via the PR-1 drain memo.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.scipy.linalg import (
    lu as scipy_lu,
    lu_factor,
    lu_solve as scipy_lu_solve,
    solve_triangular,
)

from repro.core import Dispatcher, GData, OpRegistry, dd_matrix, utp_get_parameters
from repro.core.executors import clear_compile_cache
from repro.linalg import run_inv, run_lu, run_lu_solve, run_solve
from repro.linalg.lu import utp_getrf, utp_lu_solve


def _mesh_1d():
    return jax.make_mesh((1, 1), ("data", "model"))


def _lu_ref(a):
    p, l, u = scipy_lu(np.asarray(a))
    np.testing.assert_array_equal(np.asarray(p), np.eye(a.shape[0]))
    return np.asarray(l), np.asarray(u)


# --------------------------------------------------------------------------
# run_lu vs jax.scipy.linalg.lu across every graph, both backends
# --------------------------------------------------------------------------
@pytest.mark.parametrize("graph", ["g1", "g2", "g2p"])
@pytest.mark.parametrize("n,parts", [(32, ((2, 2),)), (64, ((4, 4),))])
def test_lu_single_level(graph, n, parts):
    a = dd_matrix(n, seed=n)
    L, U = run_lu(a, graph=graph, partitions=parts)
    l_ref, u_ref = _lu_ref(a)
    np.testing.assert_allclose(np.asarray(L), l_ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(U), u_ref, atol=1e-5)


@pytest.mark.parametrize("graph", ["g3", "g4", "g3flat"])
def test_lu_distributed_graphs(graph):
    n = 64
    a = dd_matrix(n, seed=7)
    parts = ((2, 2), (2, 2)) if graph in ("g3", "g4") else ((4, 4),)
    L, U = run_lu(a, graph=graph, partitions=parts, mesh=_mesh_1d())
    l_ref, u_ref = _lu_ref(a)
    np.testing.assert_allclose(np.asarray(L), l_ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(U), u_ref, atol=1e-5)


def test_lu_same_program_all_graphs_identical():
    """Portability: ONE run_lu program, any graph, same factors."""
    a = dd_matrix(32, seed=11)
    outs = {}
    for g in ("g1", "g2", "g2p"):
        L, U = run_lu(a, graph=g, partitions=((2, 2),))
        outs[g] = (np.asarray(L), np.asarray(U))
    for g, (L, U) in outs.items():
        np.testing.assert_allclose(L, outs["g1"][0], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(U, outs["g1"][1], rtol=1e-5, atol=1e-5)


def test_lu_hierarchical_matches_flat():
    a = dd_matrix(64, seed=9)
    Lf, Uf = run_lu(a, graph="g2", partitions=((4, 4),))
    Lh, Uh = run_lu(a, graph="g3", partitions=((2, 2), (2, 2)), mesh=_mesh_1d())
    np.testing.assert_allclose(np.asarray(Lf), np.asarray(Lh), atol=1e-5)
    np.testing.assert_allclose(np.asarray(Uf), np.asarray(Uh), atol=1e-5)


# --------------------------------------------------------------------------
# run_solve vs solve_triangular, incl. non-square block counts
# --------------------------------------------------------------------------
@pytest.mark.parametrize("graph", ["g1", "g2", "g2p"])
@pytest.mark.parametrize("bshape,bparts", [((64, 64), ((4, 4),)), ((64, 32), ((4, 2),))])
def test_solve_lower(graph, bshape, bparts):
    a = dd_matrix(64, seed=3)
    b = jnp.asarray(
        np.random.default_rng(0).standard_normal(bshape).astype(np.float32)
    )
    x = run_solve(a, b, lower=True, graph=graph, partitions=((4, 4),), b_partitions=bparts)
    want = solve_triangular(a, b, lower=True, unit_diagonal=True)
    np.testing.assert_allclose(np.asarray(x), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("graph", ["g1", "g2", "g2p"])
@pytest.mark.parametrize("bshape,bparts", [((64, 64), ((4, 4),)), ((32, 64), ((2, 4),))])
def test_solve_upper(graph, bshape, bparts):
    a = dd_matrix(64, seed=4)
    b = jnp.asarray(
        np.random.default_rng(1).standard_normal(bshape).astype(np.float32)
    )
    x = run_solve(a, b, lower=False, graph=graph, partitions=((4, 4),), b_partitions=bparts)
    # x @ triu(a) = b  <=>  triu(a)^T x^T = b^T
    want = solve_triangular(a, b.T, lower=False, trans="T").T
    np.testing.assert_allclose(np.asarray(x), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("graph", ["g3", "g4"])
def test_solve_distributed(graph):
    a = dd_matrix(64, seed=6)
    b = jnp.asarray(
        np.random.default_rng(2).standard_normal((64, 32)).astype(np.float32)
    )
    x = run_solve(
        a, b, lower=True, graph=graph,
        partitions=((2, 2), (2, 2)), b_partitions=((2, 2), (2, 1)),
        mesh=_mesh_1d(),
    )
    want = solve_triangular(a, b, lower=True, unit_diagonal=True)
    np.testing.assert_allclose(np.asarray(x), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("graph", ["g1", "g2", "g2p"])
def test_solve_upper_left(graph):
    """TRSMUL — the fourth TRSM orientation: x = inv(triu(a)) @ b."""
    a = dd_matrix(64, seed=5)
    b = jnp.asarray(
        np.random.default_rng(4).standard_normal((64, 32)).astype(np.float32)
    )
    x = run_solve(
        a, b, lower=False, side="left", graph=graph,
        partitions=((4, 4),), b_partitions=((4, 2),),
    )
    want = solve_triangular(a, b, lower=False)
    np.testing.assert_allclose(np.asarray(x), np.asarray(want), atol=1e-5)


def test_solve_side_validation():
    a = dd_matrix(32, seed=1)
    b = jnp.zeros((32, 32), jnp.float32)
    with pytest.raises(ValueError, match="left"):
        run_solve(a, b, lower=True, side="right", partitions=((2, 2),))
    with pytest.raises(ValueError, match="side"):
        run_solve(a, b, lower=False, side="up", partitions=((2, 2),))


def test_lu_then_solve_round_trip():
    """Forward+backward substitution through the packed factor solves a@x=b."""
    n = 64
    a = dd_matrix(n, seed=8)
    b = jnp.asarray(
        np.random.default_rng(3).standard_normal((n, n)).astype(np.float32)
    )
    L, U = run_lu(a, graph="g2", partitions=((4, 4),))
    packed = jnp.tril(L, -1) + U
    np.testing.assert_allclose(np.asarray(L @ U), np.asarray(a), atol=1e-5)
    y = run_solve(packed, b, lower=True, partitions=((4, 4),))  # L y = b
    np.testing.assert_allclose(np.asarray(L @ y), np.asarray(b), atol=1e-4)
    # U x = y: the left-upper orientation (TRSMUL) completes the round trip
    x = run_solve(packed, y, lower=False, side="left", partitions=((4, 4),))
    np.testing.assert_allclose(np.asarray(a @ x), np.asarray(b), atol=1e-4)


# --------------------------------------------------------------------------
# run_lu_solve: the end-to-end factor+solve pipeline in ONE drain
# --------------------------------------------------------------------------
def _lu_solve_ref(a, b):
    # partial pivoting selects P == I on dd matrices (asserted by _lu_ref
    # elsewhere), so the pivoted library solve is directly comparable
    return scipy_lu_solve(lu_factor(a), b)


@pytest.mark.parametrize("graph", ["g1", "g2", "g2p"])
@pytest.mark.parametrize(
    "bshape,bparts",
    [((64, 64), ((4, 4),)), ((64, 32), ((4, 2),)), ((64,), None)],
)
def test_lu_solve_single_level(graph, bshape, bparts):
    a = dd_matrix(64, seed=13)
    b = jnp.asarray(
        np.random.default_rng(5).standard_normal(bshape).astype(np.float32)
    )
    x = run_lu_solve(
        a, b, graph=graph, partitions=((4, 4),), b_partitions=bparts
    )
    assert x.shape == b.shape
    np.testing.assert_allclose(
        np.asarray(x), np.asarray(_lu_solve_ref(a, b)), atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(a @ x), np.asarray(b), atol=1e-4
    )


@pytest.mark.parametrize("graph", ["g3", "g4", "g3flat"])
def test_lu_solve_distributed_graphs(graph):
    n = 64
    a = dd_matrix(n, seed=14)
    b = jnp.asarray(
        np.random.default_rng(6).standard_normal((n, n)).astype(np.float32)
    )
    parts = ((2, 2), (2, 2)) if graph in ("g3", "g4") else ((4, 4),)
    x = run_lu_solve(a, b, graph=graph, partitions=parts, mesh=_mesh_1d())
    np.testing.assert_allclose(
        np.asarray(x), np.asarray(_lu_solve_ref(a, b)), atol=1e-4
    )


def test_lu_solve_shape_mismatch():
    a = dd_matrix(32, seed=1)
    with pytest.raises(ValueError, match="mismatch"):
        run_lu_solve(a, jnp.zeros((16, 4), jnp.float32), partitions=((2, 2),))


def test_lu_solve_single_drain_compile_once():
    """The whole factor+solve pipeline is ONE WaveProgram: one launch and
    one compile on the first drain, pure replay (0 recompiles) on repeats —
    the acceptance criterion for the composed LUSOLVE workload."""
    clear_compile_cache()
    n, p = 64, 4
    stats = []
    for seed in (1, 2, 3):
        d = Dispatcher(graph="g2")
        A = GData((n, n), partitions=((p, p),), dtype=jnp.float32,
                  value=dd_matrix(n, seed=seed))
        B = GData(
            (n, n), partitions=((p, p),), dtype=jnp.float32,
            value=jnp.asarray(
                np.random.default_rng(seed)
                .standard_normal((n, n)).astype(np.float32)
            ),
        )
        utp_lu_solve(d, A, B)
        k = d.run()
        stats.append(
            (k, d.executor.stats.get("launches", 0),
             d.executor.stats.get("compiles", 0))
        )
    # leaf count: factor 30 (see test_repeated_lu_drains_compile_once)
    # + forward 40 + backward 40 block-substitution tasks at p = m = 4
    assert stats[0] == (110, 1, 1)
    for rep in stats[1:]:
        assert rep == (110, 1, 0)


@pytest.mark.parametrize("graph", ["g1", "g2", "g2p"])
def test_run_inv(graph):
    n = 64
    a = dd_matrix(n, seed=15)
    inv = run_inv(a, graph=graph, partitions=((4, 4),))
    np.testing.assert_allclose(
        np.asarray(inv @ a), np.eye(n), atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(inv), np.asarray(jnp.linalg.inv(a)), atol=1e-4
    )


def test_lu_solve_ops_registered_and_memoizable():
    for name in ("trsmul", "lu_solve"):
        op = OpRegistry.get(name)
        assert op.memoizable  # geometry-pure splits ride the drain memo


# --------------------------------------------------------------------------
# Wave-program cache: repeated LU drains compile once (PR-1 drain memo)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("graph", ["g2", "g2p"])
def test_repeated_lu_drains_compile_once(graph):
    clear_compile_cache()
    stats = []
    for seed in (1, 2, 3):
        d = Dispatcher(graph=graph)
        A = GData((64, 64), partitions=((4, 4),), dtype=jnp.float32,
                  value=dd_matrix(64, seed=seed))
        utp_getrf(d, A)
        n = d.run()
        stats.append(
            (n, d.executor.stats.get("launches", 0),
             d.executor.stats.get("compiles", 0))
        )
    # 4x4 right-looking LU: sum_k 1 + 2*(3-k) + (3-k)^2 = 16+9+4+1 = 30
    assert stats[0] == (30, 1, 1)  # one compiled WaveProgram, one dispatch
    for rep in stats[1:]:
        assert rep == (30, 1, 0)  # replayed drains: 0 recompiles


def test_lu_ops_registered_and_memoizable():
    for name in ("getrf", "trsml", "trsmu", "gemmnn"):
        op = OpRegistry.get(name)
        assert op.memoizable  # geometry-pure splits ride the drain memo


# --------------------------------------------------------------------------
# Satellite: utp_get_parameters rejects non-positive sizes/partitions
# --------------------------------------------------------------------------
def test_utp_get_parameters_accepts_positive():
    assert utp_get_parameters(["1024", "8", "4"]) == (1024, 8, 4)
    assert utp_get_parameters([]) == (1024, 4, 4)


@pytest.mark.parametrize("argv", [["-4"], ["1024", "-8"], ["1024", "8", "0"], ["0"]])
def test_utp_get_parameters_rejects_nonpositive(argv):
    with pytest.raises(ValueError, match="positive"):
        utp_get_parameters(argv)
