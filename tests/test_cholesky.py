"""The paper's experimental vehicle: blocked Cholesky through every
task-flow graph must match jnp.linalg.cholesky (paper Fig. 2/3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GRAPHS, Dispatcher, GData, spd_matrix
from repro.linalg import run_cholesky


def _mesh_1d():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("graph", ["g1", "g2", "g2p"])
@pytest.mark.parametrize("n,parts", [(32, ((2, 2),)), (64, ((4, 4),))])
def test_cholesky_single_level(graph, n, parts):
    a = spd_matrix(n, seed=n)
    L = run_cholesky(a, graph=graph, partitions=parts)
    np.testing.assert_allclose(L, jnp.linalg.cholesky(a), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("graph", ["g3", "g4", "g3flat"])
def test_cholesky_distributed_graphs(graph):
    n = 64
    a = spd_matrix(n, seed=7)
    parts = ((2, 2), (2, 2)) if graph in ("g3", "g4") else ((4, 4),)
    L = run_cholesky(a, graph=graph, partitions=parts, mesh=_mesh_1d())
    np.testing.assert_allclose(L, jnp.linalg.cholesky(a), rtol=2e-4, atol=2e-4)


def test_hierarchical_two_level_matches_flat():
    """DuctTeip-over-SuperGlue hierarchy == flat (paper C5 vs C6 semantics)."""
    a = spd_matrix(64, seed=9)
    flat = run_cholesky(a, graph="g2", partitions=((4, 4),))
    hier = run_cholesky(a, graph="g3", partitions=((2, 2), (2, 2)), mesh=_mesh_1d())
    np.testing.assert_allclose(flat, hier, rtol=1e-5, atol=1e-5)


def test_same_program_all_graphs_identical_results():
    """The paper's portability claim: ONE program, any graph, same result."""
    a = spd_matrix(32, seed=11)
    outs = {}
    for g in ("g1", "g2", "g2p"):
        outs[g] = np.asarray(run_cholesky(a, graph=g, partitions=((2, 2),)))
    base = outs["g1"]
    for g, v in outs.items():
        np.testing.assert_allclose(v, base, rtol=1e-5, atol=1e-5)


def test_dispatcher_stats():
    a = spd_matrix(32, seed=3)
    d_stats = {}
    from repro.linalg.cholesky import utp_cholesky

    d = Dispatcher(graph="g2")
    A = GData(a.shape, partitions=((4, 4),), dtype=a.dtype, value=a)
    utp_cholesky(d, A)
    n = d.run()
    # 4x4 blocked cholesky: sum_i [i syrk + i*(3-i) gemm + 1 potrf + (3-i) trsm]
    # = 4 + 6 + 6 + 4 = 20 leaf tasks
    assert n == 20
    assert d.stats["submitted"] == 1
    assert d.stats["split"] == 1
