"""Launch layer: sharding resolver, step plans on a local mesh, hlo_cost."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_arch
from repro.configs.base import ShapeConfig
from repro.launch import sharding as sh
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.roofline import model_flops
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step


def mesh2():
    return jax.make_mesh((1, 1), ("data", "model"))


# --------------------------------------------------------------------------
# resolver
# --------------------------------------------------------------------------
def test_resolver_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = sh.Rules(table={"heads": ("model",), "embed": ("data",), None: ()})
    # divisible -> sharded (axis size 1 divides everything)
    spec = sh.resolve_pspec(("embed", "heads", None), (64, 8, 16), mesh, rules)
    assert spec == P("data", "model", None)


def test_resolver_nondivisible_replicates():
    # fake a larger mesh via the production mesh helper is not possible on
    # 1 device; test the pure logic with a mock mesh object instead.
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    rules = sh.Rules(table={"kv_heads": ("model",), "embed": ("data",), None: ()})
    spec = sh.resolve_pspec(("embed", "kv_heads"), (64, 8), FakeMesh(), rules)
    assert spec == P("data", None)  # kv=8 not divisible by 16 -> replicated
    spec = sh.resolve_pspec(("embed", "kv_heads"), (60, 32), FakeMesh(), rules)
    assert spec == P(None, "model")  # 60 % 16 != 0 -> embed replicated


def test_resolver_multi_axis_dim():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    rules = sh.Rules(table={"embed": ("pod", "data"), None: ()})
    spec = sh.resolve_pspec(("embed", None), (18432, 8), FakeMesh(), rules)
    assert spec == P(("pod", "data"), None)


def test_resolver_axis_used_once_per_leaf():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 4}

    rules = sh.Rules(
        table={"batch": ("data", "model"), "seq": ("data", "model"), None: ()}
    )
    spec = sh.resolve_pspec(("batch", "seq"), (16, 64), FakeMesh(), rules)
    # batch takes data+model; seq gets nothing (both consumed)
    assert spec == P(("data", "model"), None)


def test_vector_params_replicated():
    mesh = mesh2()
    rules = sh.train_rules(get_arch("qwen3-32b"))
    assert sh.resolve_pspec(("embed",), (5120,), mesh, rules) == P()


# --------------------------------------------------------------------------
# step plans lower + run on the local 1x1 mesh (real execution!)
# --------------------------------------------------------------------------
def tiny_shape(kind):
    return ShapeConfig(f"tiny_{kind}", seq_len=32, global_batch=2, kind=kind)


@pytest.mark.parametrize("arch", ["qwen3-32b", "granite-moe-1b-a400m", "rwkv6-3b",
                                  "zamba2-2.7b", "gemma3-12b"])
def test_train_plan_executes(arch):
    cfg = ARCHS[arch].reduced()
    mesh = mesh2()
    plan = make_train_step(cfg, mesh, tiny_shape("train"))
    fn = plan.jitted()
    from repro.models import build_model
    from repro import optim

    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = optim.init(params, optim.AdamWConfig(state_dtype=cfg.optim_state_dtype))
    batch = (
        {"embeds": jnp.ones((2, 32, cfg.d_model), cfg.compute_dtype) * 0.01}
        if cfg.frontend
        else {"tokens": jnp.ones((2, 32), jnp.int32)}
    )
    batch["labels"] = jnp.zeros((2, 32), jnp.int32)
    with mesh:
        p2, o2, metrics = fn(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", ["starcoder2-7b", "zamba2-2.7b"])
def test_decode_plan_executes(arch):
    cfg = ARCHS[arch].reduced()
    mesh = mesh2()
    plan = make_decode_step(cfg, mesh, tiny_shape("decode"))
    fn = plan.jitted()
    from repro.models import build_model

    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    cache = m.init_cache(2, 32)
    batch = (
        {"embeds": jnp.ones((2, 1, cfg.d_model), cfg.compute_dtype) * 0.01}
        if cfg.frontend
        else {"tokens": jnp.ones((2, 1), jnp.int32)}
    )
    with mesh:
        logits, cache2 = fn(params, cache, batch, jnp.asarray(3, jnp.int32))
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


# --------------------------------------------------------------------------
# hlo_cost: white-box validation against known programs
# --------------------------------------------------------------------------
def test_hlo_cost_scan_flops_exact():
    def f(x, ws):
        def body(c, w):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jnp.zeros((64, 64), jnp.float32)
    ws = jnp.zeros((7, 64, 64), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    cost = analyze_hlo(c.as_text())
    assert cost.flops == pytest.approx(7 * 2 * 64**3, rel=1e-6)
    assert cost.n_while == 1


def test_hlo_cost_nested_scan():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return ci @ w, ()
            c2, _ = jax.lax.scan(inner, c, jnp.arange(3))
            return c2, ()
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jnp.zeros((32, 32), jnp.float32)
    ws = jnp.zeros((5, 32, 32), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    cost = analyze_hlo(c.as_text())
    assert cost.flops == pytest.approx(5 * 3 * 2 * 32**3, rel=1e-6)


def test_model_flops_sane():
    for arch in ("qwen3-32b", "granite-moe-1b-a400m", "rwkv6-3b"):
        cfg = get_arch(arch)
        for s in SHAPES.values():
            f = model_flops(cfg, s)
            assert f > 0
    # train >= prefill >= decode per token
    cfg = get_arch("qwen3-32b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    assert tr > 0 and pf > 0
