"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512."""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
