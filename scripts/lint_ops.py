#!/usr/bin/env python
"""CLI gate: lint every registered Operation (DESIGN.md §11).

    PYTHONPATH=src python scripts/lint_ops.py            # full registry
    python scripts/lint_ops.py --no-execute              # static-only
    python scripts/lint_ops.py getrf trsml               # named subset

Exit status 0 iff every checked op is clean; issues print one per line.
Run by ``scripts/ci.sh`` over the full registry with smoke execution on.
"""

from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    # runnable from a clean checkout without PYTHONPATH
    repo_src = os.path.join(os.path.dirname(__file__), "..", "src")
    if os.path.isdir(repo_src) and repo_src not in sys.path:
        sys.path.insert(0, os.path.abspath(repo_src))

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "ops", nargs="*", help="op names to lint (default: full registry)"
    )
    parser.add_argument(
        "--no-execute",
        action="store_true",
        help="skip the leaf smoke evaluation (pure static checks)",
    )
    args = parser.parse_args(argv)

    import repro.linalg.ops  # noqa: F401 — populates the registry
    from repro.analysis import lint_registry
    from repro.core.operation import OpRegistry

    names = args.ops or OpRegistry.names()
    issues = lint_registry(names, execute=not args.no_execute)
    bad = {i.op for i in issues}
    for name in names:
        print(f"  {'FAIL' if name in bad else 'ok  '} {name}")
    if issues:
        print(f"\n{len(issues)} issue(s):")
        for issue in issues:
            print(f"  {issue}")
        return 1
    print(f"ops lint OK ({len(names)} operations, 0 issues)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
