#!/usr/bin/env bash
# Tier-1 gate + perf smoke.  Run from anywhere; cds to the repo root.
#   scripts/ci.sh          # tests + harness check (smoke) + fault gate
#   scripts/ci.sh --full   # also the full-mode harness run + benchmark suite
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== property tests: hypothesis (best-effort install; vendored fallback) =="
if python -c "import hypothesis" 2>/dev/null; then
  echo "hypothesis available"
else
  pip install -q "hypothesis>=6.80" 2>/dev/null \
    && echo "hypothesis installed" \
    || echo "hypothesis unavailable (offline); property tests run on the" \
            "vendored repro.testing.proptest engine (DESIGN.md §13)"
fi

echo "== tier-1: pytest (skip budget: 0) =="
# no -x: report every failure; set -e still fails the gate on any red test
PYTEST_OUT=$(mktemp)
python -m pytest -q -rs | tee "$PYTEST_OUT"
# skip-budget gate (DESIGN.md §13): the property suites fall back to the
# vendored engine when hypothesis is absent, so NOTHING in tier-1 may skip —
# a skip here means a test silently stopped running
SKIP_BUDGET=0
SKIPS=$(grep -Eo '[0-9]+ skipped' "$PYTEST_OUT" | grep -Eo '[0-9]+' || echo 0)
if [ "$SKIPS" -gt "$SKIP_BUDGET" ]; then
  echo "SKIP-BUDGET GATE FAILED: $SKIPS skipped > budget $SKIP_BUDGET"
  exit 1
fi
echo "skip-budget gate OK ($SKIPS skipped <= $SKIP_BUDGET)"

echo "== tier-1 under REPRO_VERIFY=1: every drain hazard-checked + plan-proven =="
REPRO_VERIFY=1 python -m pytest -q

echo "== gate: operation-algebra linter over the full registry (DESIGN.md §11) =="
python scripts/lint_ops.py

echo "== gate: ruff check baseline (skipped when ruff is not installed) =="
if command -v ruff >/dev/null 2>&1; then
  ruff check src
else
  echo "ruff not installed; skipping (config: ruff.toml)"
fi

echo "== gate: evaluation harness check --mode smoke (DESIGN.md §13) =="
# runs the five gated scenarios (overhead, serving incl. overload, chaos,
# cholesky, lm), appends unified records to BENCH_trend.jsonl, and diffs every
# declared gate against BENCH_baseline.json; BENCH_report.json is the CI
# artifact.  The chaos scenario (DESIGN.md §14) is invariant-only: no
# baseline entry, gates on lost_futures == 0 / wedged_ticks == 0 / breaker
# round-trip + watchdog + OOM witnesses / steady-state restoration
python -m benchmarks.harness check --mode smoke --report BENCH_report.json
echo "harness report artifact: BENCH_report.json"

echo "== gate: harness negative test — injected regression must fail check =="
python - <<'EOF'
import json, subprocess, sys, tempfile

# take the serving record just appended by the check above, violate the
# repeat-tick replay invariant, and feed it back through the differ: the
# check MUST exit nonzero, or the gate itself is broken
records = [json.loads(l) for l in open("BENCH_trend.jsonl") if l.strip()]
rec = [r for r in records
       if r["scenario"] == "serving" and r["mode"] == "smoke"][-1]
rec["counters"]["repeat_tick_compiles"] = 3  # synthetic regression
with tempfile.NamedTemporaryFile("w", suffix=".jsonl", delete=False) as f:
    f.write(json.dumps(rec) + "\n")
    tampered = f.name
proc = subprocess.run(
    [sys.executable, "-m", "benchmarks.harness", "check", "--mode", "smoke",
     "--scenario", "serving", "--record", tampered,
     "--report", tampered + ".report.json"],
    capture_output=True, text=True,
)
if proc.returncode == 0:
    print("NEGATIVE TEST FAILED: tampered record passed the check")
    print(proc.stdout)
    sys.exit(1)
if "repeat_tick_compiles" not in proc.stdout:
    print("NEGATIVE TEST FAILED: check failed but not on the injected metric")
    print(proc.stdout)
    sys.exit(1)
print("harness negative test OK (injected regression failed the check)")
EOF

echo "== gate: fault injection — every named site recovers (DESIGN.md §10) =="
python - <<'EOF'
import sys

import numpy as np

from repro.core import dd_matrix
from repro.core.executors import clear_compile_cache, drain_memo_stats
from repro.errors import DrainError, NumericalError
from repro.linalg import run_lu
from repro.serve import BatchServer
from repro.testing import faults

fail = []


def check(cond, msg):
    if not cond:
        fail.append(msg)


def lu_ok(a, **kw):
    l, u = run_lu(a, partitions=((2, 2),), **kw)
    return np.allclose(np.asarray(l) @ np.asarray(u), np.asarray(a), atol=2e-4)


a = dd_matrix(32, seed=0)
# leaf.fn / executor.launch / memo.capture: raise mid-drain, then the very
# next identical call must succeed with a clean memo (no half capture)
for site in ("leaf.fn", "executor.launch", "memo.capture"):
    clear_compile_cache()
    try:
        with faults.inject(site, RuntimeError("armed")):
            run_lu(a, partitions=((2, 2),))
        check(False, f"{site}: armed fault did not fire")
    except RuntimeError:
        pass
    check(drain_memo_stats()["entries"] == 0, f"{site}: half-captured memo entry")
    check(lu_ok(a), f"{site}: post-failure drain wrong or failed")
    check(drain_memo_stats()["entries"] == 1, f"{site}: recovery drain not memoized")

# executor.output: corruption is caught by check_finite as NumericalError
clear_compile_cache()
try:
    with faults.inject("executor.output"):
        run_lu(a, partitions=((2, 2),), check_finite=True)
    check(False, "executor.output: corruption not detected")
except NumericalError:
    pass
check(lu_ok(a, check_finite=True), "executor.output: post-corruption drain wrong")

# split.value_dependent: stacked drain falls back interleaved, same numerics
clear_compile_cache()
srv = BatchServer(graph="g2")
futs = [srv.lu(dd_matrix(32, seed=s), partitions=((2, 2),)) for s in range(4)]
with faults.inject("split.value_dependent", times=None):
    rep = srv.tick()
check(rep.stacked_drains == 0, "split.value_dependent: stacked path did not abort")
check(rep.resolved == 4, "split.value_dependent: fallback lost requests")
for s, f in enumerate(futs):
    l, u = f.result()
    check(
        np.allclose(np.asarray(l) @ np.asarray(u),
                    np.asarray(dd_matrix(32, seed=s)), atol=2e-4),
        f"split.value_dependent: fallback numerics wrong (request {s})",
    )

# plan.* mutation sites (DESIGN.md §11): each schedule corruption must be
# caught by the static verifier with the right invariant name
from repro.core import Dispatcher, GData
from repro.errors import ScheduleVerificationError
from repro.linalg.lu import run_lu_batched, utp_getrf

for site, expect in (
    ("plan.drop_edge", "hazards"),
    ("plan.merge_groups", "verify_plan.group_independence"),
):
    clear_compile_cache()
    d = Dispatcher(graph="g2", verify=True)
    A = GData(a.shape, partitions=((2, 2),), dtype=a.dtype, value=a)
    utp_getrf(d, A)
    try:
        with faults.inject(site):
            d.run()
        check(False, f"{site}: schedule corruption not caught")
    except ScheduleVerificationError as e:
        check(e.site == expect, f"{site}: wrong invariant {e.site}")
clear_compile_cache()
import os
os.environ["REPRO_VERIFY"] = "1"
try:
    with faults.inject("plan.alias_lane"):
        run_lu_batched(
            [dd_matrix(32, seed=s) for s in range(4)], partitions=((2, 2),)
        )
    check(False, "plan.alias_lane: lane aliasing not caught")
except ScheduleVerificationError as e:
    check(e.site == "verify_stacked.lane_alias",
          f"plan.alias_lane: wrong invariant {e.site}")
del os.environ["REPRO_VERIFY"]

# serve.drain: bisection isolates the poisoned request, tick never unwinds
clear_compile_cache()
srv = BatchServer(graph="g2", max_retries=0)
futs = [srv.lu(dd_matrix(32, seed=s), partitions=((2, 2),)) for s in range(8)]
rid = futs[2].rid
with faults.inject("serve.drain", RuntimeError("poisoned"),
                   when=lambda ctx: rid in ctx["rids"], times=None):
    rep = srv.tick()
check(rep.resolved == 7 and rep.failed == 1,
      f"serve.drain: isolation failed ({rep.resolved} ok, {rep.failed} bad)")
check(isinstance(futs[2].exception(), DrainError),
      "serve.drain: poisoned future lacks DrainError")

# drain.inflight (DESIGN.md §12): a failure surfacing only at the deferred
# fence of an overlapped tick is contained by synchronous half re-drains;
# every future ends the tick resolved — none half-resolved
clear_compile_cache()
srv = BatchServer(graph="g2", overlap=True, check_finite=True)
futs = [srv.lu(dd_matrix(32, seed=s), partitions=((2, 2),)) for s in range(4)]
with faults.inject("drain.inflight", RuntimeError("device lost mid-flight"),
                   when=lambda ctx: "rids" in ctx, times=1):
    rep = srv.tick()
check(rep.bisected >= 1 and rep.resolved == 4 and rep.failed == 0,
      f"drain.inflight: transient not isolated "
      f"({rep.resolved} ok, {rep.failed} bad, {rep.bisected} bisects)")
check(all(f.done for f in futs), "drain.inflight: half-resolved futures")
for f in futs:
    check(f.exception() is None, "drain.inflight: healthy request failed")

# drain.stall (DESIGN.md §14): a hung fence blows the watchdog budget —
# typed DrainStalledError on the stalled bucket only, the tick never blocks
# past budget + injected delay, and the next tick is healthy again
from repro.errors import DrainStalledError, ResourceExhausted

clear_compile_cache()
srv = BatchServer(graph="g2", watchdog_s=0.05)
futs = [srv.lu(dd_matrix(32, seed=s), partitions=((2, 2),)) for s in range(2)]
with faults.inject("drain.stall", delay_s=0.2):
    rep = srv.tick()
check(rep.watchdog_fires == 1, "drain.stall: watchdog did not fire")
check(all(isinstance(f.exception(), DrainStalledError) for f in futs),
      "drain.stall: stalled futures lack DrainStalledError")
futs = [srv.lu(dd_matrix(32, seed=10 + s), partitions=((2, 2),))
        for s in range(2)]
rep = srv.tick()
check(rep.resolved == 2 and rep.watchdog_fires == 0,
      "drain.stall: post-stall tick not healthy")

# launch.oom (DESIGN.md §14): device OOM on a stacked chunk re-drains as
# split halves the same tick (no request lost), halves the bucket's batch
# cap, and healthy drains recover it
clear_compile_cache()
srv = BatchServer(graph="g2", max_batch=4, degrade_recovery=3)
futs = [srv.lu(dd_matrix(32, seed=s), partitions=((2, 2),)) for s in range(4)]
with faults.inject("launch.oom",
                   lambda: ResourceExhausted("RESOURCE_EXHAUSTED")):
    rep = srv.tick()
check(rep.oom_events == 1 and rep.resolved == 4 and rep.failed == 0,
      f"launch.oom: split re-drain lost requests ({rep.resolved} ok, "
      f"{rep.failed} bad)")
check(srv.health() == "DEGRADED", "launch.oom: bucket not degraded after OOM")
srv.lu(dd_matrix(32, seed=50), partitions=((2, 2),))
srv.tick()
check(srv.health() == "HEALTHY", "launch.oom: degradation did not recover")

if fail:
    print("FAULT GATE FAILED:\n  " + "\n  ".join(fail))
    sys.exit(1)
print(f"fault gate OK ({len(faults.KNOWN_SITES)} sites armed and recovered)")
EOF

echo "== examples smoke (executable documentation) =="
python examples/quickstart.py 64 4 2
python examples/lu_solve.py 64 4 2

echo "== docs: README/DESIGN links + section references resolve =="
python - <<'EOF'
import os, re, sys

fail = []
# 1) relative markdown links in README/DESIGN point at real files
for path in ("README.md", "DESIGN.md"):
    text = open(path).read()
    for target in re.findall(r"\]\(([^)\s]+)\)", text):
        target = target.split("#")[0]
        if not target or "://" in target or target.startswith("mailto:"):
            continue
        if not os.path.exists(target):
            fail.append(f"{path}: broken link -> {target}")
# 2) every "DESIGN.md §N" citation (docs, source, tests, benchmarks)
#    resolves to a top-level DESIGN.md heading — this is what keeps the
#    load-bearing section numbering gap-free
secs = set(re.findall(r"^## (§\d+)", open("DESIGN.md").read(), flags=re.M))
cites = {}
scan = ["README.md", "DESIGN.md", "ROADMAP.md"]
for root in ("src", "tests", "benchmarks", "examples"):
    for dirpath, _, names in os.walk(root):
        scan += [os.path.join(dirpath, n) for n in names if n.endswith(".py")]
for path in scan:
    # compound citations ("DESIGN.md §4/§6") count every listed section
    for group in re.findall(r"DESIGN\.md ((?:§\d+[/,])*§\d+)", open(path).read()):
        for ref in re.findall(r"§\d+", group):
            cites.setdefault(ref, path)
for ref, path in sorted(cites.items()):
    if ref not in secs:
        fail.append(f"{path}: DESIGN.md {ref} cited but no such section")
if fail:
    print("DOCS LINK GATE FAILED:\n  " + "\n  ".join(fail))
    sys.exit(1)
print(f"docs link gate OK ({len(cites)} section citations, "
      f"{len(secs)} sections)")
EOF

if [[ "${1:-}" == "--full" ]]; then
  echo "== full-mode harness check (writes BENCH_*.json + trend records) =="
  python -m benchmarks.harness check --mode full --report BENCH_report.full.json
  echo "== full benchmark suite (harness scenarios + ad-hoc benches) =="
  python -m benchmarks.run --full
fi
