#!/usr/bin/env bash
# Tier-1 gate + perf smoke.  Run from anywhere; cds to the repo root.
#   scripts/ci.sh          # tests + overhead smoke + compile-counter gate
#   scripts/ci.sh --full   # also the full bench_overhead + benchmark suite
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
# no -x: report every failure; set -e still fails the gate on any red test
python -m pytest -q

echo "== perf smoke: bench_overhead --smoke (writes BENCH_overhead.smoke.json) =="
python -m benchmarks.bench_overhead --smoke

echo "== gate: compile-counter / fusion regressions =="
python - <<'EOF'
import json, sys

r = json.load(open("BENCH_overhead.smoke.json"))
fail = []
for case in ("stats", "lu_stats", "lu_multiroot_stats"):
    rep = r[case]["repeat_drain"]
    # repeated structurally-identical drains must replay: one program
    # dispatch, zero recompiles (DESIGN.md §2 drain memo)
    if rep["compiles"] != 0:
        fail.append(f"{case}: repeat drain recompiled ({rep['compiles']})")
    if rep["launches"] != 1:
        fail.append(f"{case}: repeat drain launches {rep['launches']} != 1")
# the dependency-exact pass must fuse the multi-root LU drain's
# same-signature groups across roots (DESIGN.md §2 fusion rule)
if not r["lu_groups_after_fusion"] < r["lu_groups_before"]:
    fail.append(
        f"multi-root LU fusion regressed: {r['lu_groups_after_fusion']} "
        f"!< {r['lu_groups_before']}"
    )
# single-root LU sits at its chain lower bound: fusing anything there
# would be a legality bug, not a win
lu = r["lu_stats"]["first_drain"]
if lu["groups"] != lu["groups_prefusion"]:
    fail.append(
        f"single-root LU group count changed: {lu['groups']} vs "
        f"{lu['groups_prefusion']} prefusion (legality bug?)"
    )
if fail:
    print("COMPILE/FUSION GATE FAILED:\n  " + "\n  ".join(fail))
    sys.exit(1)
print("compile-counter + fusion gate OK")
EOF

if [[ "${1:-}" == "--full" ]]; then
  echo "== full bench_overhead (writes BENCH_overhead.json) =="
  python -m benchmarks.bench_overhead
  echo "== full benchmark suite =="
  python -m benchmarks.run
fi
