#!/usr/bin/env bash
# Tier-1 gate + perf smoke.  Run from anywhere; cds to the repo root.
#   scripts/ci.sh          # tests + overhead smoke
#   scripts/ci.sh --full   # also the full benchmark suite
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
# no -x: report every failure; set -e still fails the gate on any red test
python -m pytest -q

echo "== perf smoke: bench_overhead (writes BENCH_overhead.json) =="
python -m benchmarks.bench_overhead

if [[ "${1:-}" == "--full" ]]; then
  echo "== full benchmark suite =="
  python -m benchmarks.run
fi
