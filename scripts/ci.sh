#!/usr/bin/env bash
# Tier-1 gate + perf smoke.  Run from anywhere; cds to the repo root.
#   scripts/ci.sh          # tests + overhead smoke + compile-counter gate
#   scripts/ci.sh --full   # also the full bench_overhead + benchmark suite
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
# no -x: report every failure; set -e still fails the gate on any red test
python -m pytest -q

echo "== tier-1 under REPRO_VERIFY=1: every drain hazard-checked + plan-proven =="
REPRO_VERIFY=1 python -m pytest -q

echo "== gate: operation-algebra linter over the full registry (DESIGN.md §11) =="
python scripts/lint_ops.py

echo "== gate: ruff check baseline (skipped when ruff is not installed) =="
if command -v ruff >/dev/null 2>&1; then
  ruff check src
else
  echo "ruff not installed; skipping (config: ruff.toml)"
fi

echo "== perf smoke: bench_overhead --smoke (writes BENCH_overhead.smoke.json) =="
python -m benchmarks.bench_overhead --smoke

echo "== gate: compile-counter / fusion regressions =="
python - <<'EOF'
import json, sys

r = json.load(open("BENCH_overhead.smoke.json"))
fail = []
for case in ("stats", "lu_stats", "lu_multiroot_stats", "lu_solve_stats"):
    rep = r[case]["repeat_drain"]
    # repeated structurally-identical drains must replay: one program
    # dispatch, zero recompiles (DESIGN.md §2 drain memo)
    if rep["compiles"] != 0:
        fail.append(f"{case}: repeat drain recompiled ({rep['compiles']})")
    if rep["launches"] != 1:
        fail.append(f"{case}: repeat drain launches {rep['launches']} != 1")
# the dependency-exact pass must fuse the multi-root LU drain's
# same-signature groups across roots (DESIGN.md §2 fusion rule)
if not r["lu_groups_after_fusion"] < r["lu_groups_before"]:
    fail.append(
        f"multi-root LU fusion regressed: {r['lu_groups_after_fusion']} "
        f"!< {r['lu_groups_before']}"
    )
# single-root LU sits at its chain lower bound: fusing anything there
# would be a legality bug, not a win
lu = r["lu_stats"]["first_drain"]
if lu["groups"] != lu["groups_prefusion"]:
    fail.append(
        f"single-root LU group count changed: {lu['groups']} vs "
        f"{lu['groups_prefusion']} prefusion (legality bug?)"
    )
# the composed factor+solve drain (DESIGN.md §4) is ONE WaveProgram and
# the case where single-root fusion MUST strictly reduce the group count
# (solve groups overlap independent same-signature factor groups)
ls = r["lu_solve_stats"]["first_drain"]
if ls["launches"] != 1 or ls["compiles"] != 1:
    fail.append(
        f"lu_solve first drain not one program: launches {ls['launches']}, "
        f"compiles {ls['compiles']}"
    )
if not ls["groups"] < ls["groups_prefusion"]:
    fail.append(
        f"lu_solve overlap fusion regressed: {ls['groups']} !< "
        f"{ls['groups_prefusion']} prefusion"
    )
# static verification (DESIGN.md §11): disabled = zero added work on the
# hot path; enabled = first drain proves, memo replay pays nothing
for case in ("stats", "lu_stats", "lu_multiroot_stats", "lu_solve_stats"):
    for which in ("first_drain", "repeat_drain"):
        s = r[case][which]
        if s["verified_scopes"] or s["verified_plans"]:
            fail.append(
                f"{case}.{which}: verify-off drain did verification work "
                f"({s['verified_scopes']} scopes, {s['verified_plans']} plans)"
            )
vf, vr = r["verify_stats"]["first_drain"], r["verify_stats"]["repeat_drain"]
if vf["verified_scopes"] < 1 or vf["verified_plans"] < 1:
    fail.append(
        f"verify-on first drain did not verify ({vf['verified_scopes']} "
        f"scopes, {vf['verified_plans']} plans)"
    )
if vr["compiles"] != 0 or vr["launches"] != 1:
    fail.append(
        f"verify-on repeat drain not pure replay ({vr['compiles']} "
        f"compiles, {vr['launches']} launches)"
    )
if vr["verified_scopes"] or vr["verified_plans"]:
    fail.append(
        f"verify-on replay paid verification work ({vr['verified_scopes']} "
        f"scopes, {vr['verified_plans']} plans)"
    )
if fail:
    print("COMPILE/FUSION GATE FAILED:\n  " + "\n  ".join(fail))
    sys.exit(1)
print("compile-counter + fusion + verification-cost gate OK")
EOF

echo "== gate: fault injection — every named site recovers (DESIGN.md §10) =="
python - <<'EOF'
import sys

import numpy as np

from repro.core import dd_matrix
from repro.core.executors import clear_compile_cache, drain_memo_stats
from repro.errors import DrainError, NumericalError
from repro.linalg import run_lu
from repro.serve import BatchServer
from repro.testing import faults

fail = []


def check(cond, msg):
    if not cond:
        fail.append(msg)


def lu_ok(a, **kw):
    l, u = run_lu(a, partitions=((2, 2),), **kw)
    return np.allclose(np.asarray(l) @ np.asarray(u), np.asarray(a), atol=2e-4)


a = dd_matrix(32, seed=0)
# leaf.fn / executor.launch / memo.capture: raise mid-drain, then the very
# next identical call must succeed with a clean memo (no half capture)
for site in ("leaf.fn", "executor.launch", "memo.capture"):
    clear_compile_cache()
    try:
        with faults.inject(site, RuntimeError("armed")):
            run_lu(a, partitions=((2, 2),))
        check(False, f"{site}: armed fault did not fire")
    except RuntimeError:
        pass
    check(drain_memo_stats()["entries"] == 0, f"{site}: half-captured memo entry")
    check(lu_ok(a), f"{site}: post-failure drain wrong or failed")
    check(drain_memo_stats()["entries"] == 1, f"{site}: recovery drain not memoized")

# executor.output: corruption is caught by check_finite as NumericalError
clear_compile_cache()
try:
    with faults.inject("executor.output"):
        run_lu(a, partitions=((2, 2),), check_finite=True)
    check(False, "executor.output: corruption not detected")
except NumericalError:
    pass
check(lu_ok(a, check_finite=True), "executor.output: post-corruption drain wrong")

# split.value_dependent: stacked drain falls back interleaved, same numerics
clear_compile_cache()
srv = BatchServer(graph="g2")
futs = [srv.lu(dd_matrix(32, seed=s), partitions=((2, 2),)) for s in range(4)]
with faults.inject("split.value_dependent", times=None):
    rep = srv.tick()
check(rep.stacked_drains == 0, "split.value_dependent: stacked path did not abort")
check(rep.resolved == 4, "split.value_dependent: fallback lost requests")
for s, f in enumerate(futs):
    l, u = f.result()
    check(
        np.allclose(np.asarray(l) @ np.asarray(u),
                    np.asarray(dd_matrix(32, seed=s)), atol=2e-4),
        f"split.value_dependent: fallback numerics wrong (request {s})",
    )

# plan.* mutation sites (DESIGN.md §11): each schedule corruption must be
# caught by the static verifier with the right invariant name
from repro.core import Dispatcher, GData
from repro.errors import ScheduleVerificationError
from repro.linalg.lu import run_lu_batched, utp_getrf

for site, expect in (
    ("plan.drop_edge", "hazards"),
    ("plan.merge_groups", "verify_plan.group_independence"),
):
    clear_compile_cache()
    d = Dispatcher(graph="g2", verify=True)
    A = GData(a.shape, partitions=((2, 2),), dtype=a.dtype, value=a)
    utp_getrf(d, A)
    try:
        with faults.inject(site):
            d.run()
        check(False, f"{site}: schedule corruption not caught")
    except ScheduleVerificationError as e:
        check(e.site == expect, f"{site}: wrong invariant {e.site}")
clear_compile_cache()
import os
os.environ["REPRO_VERIFY"] = "1"
try:
    with faults.inject("plan.alias_lane"):
        run_lu_batched(
            [dd_matrix(32, seed=s) for s in range(4)], partitions=((2, 2),)
        )
    check(False, "plan.alias_lane: lane aliasing not caught")
except ScheduleVerificationError as e:
    check(e.site == "verify_stacked.lane_alias",
          f"plan.alias_lane: wrong invariant {e.site}")
del os.environ["REPRO_VERIFY"]

# serve.drain: bisection isolates the poisoned request, tick never unwinds
clear_compile_cache()
srv = BatchServer(graph="g2", max_retries=0)
futs = [srv.lu(dd_matrix(32, seed=s), partitions=((2, 2),)) for s in range(8)]
rid = futs[2].rid
with faults.inject("serve.drain", RuntimeError("poisoned"),
                   when=lambda ctx: rid in ctx["rids"], times=None):
    rep = srv.tick()
check(rep.resolved == 7 and rep.failed == 1,
      f"serve.drain: isolation failed ({rep.resolved} ok, {rep.failed} bad)")
check(isinstance(futs[2].exception(), DrainError),
      "serve.drain: poisoned future lacks DrainError")

# drain.inflight (DESIGN.md §12): a failure surfacing only at the deferred
# fence of an overlapped tick is contained by synchronous half re-drains;
# every future ends the tick resolved — none half-resolved
clear_compile_cache()
srv = BatchServer(graph="g2", overlap=True, check_finite=True)
futs = [srv.lu(dd_matrix(32, seed=s), partitions=((2, 2),)) for s in range(4)]
with faults.inject("drain.inflight", RuntimeError("device lost mid-flight"),
                   when=lambda ctx: "rids" in ctx, times=1):
    rep = srv.tick()
check(rep.bisected >= 1 and rep.resolved == 4 and rep.failed == 0,
      f"drain.inflight: transient not isolated "
      f"({rep.resolved} ok, {rep.failed} bad, {rep.bisected} bisects)")
check(all(f.done for f in futs), "drain.inflight: half-resolved futures")
for f in futs:
    check(f.exception() is None, "drain.inflight: healthy request failed")

if fail:
    print("FAULT GATE FAILED:\n  " + "\n  ".join(fail))
    sys.exit(1)
print(f"fault gate OK ({len(faults.KNOWN_SITES)} sites armed and recovered)")
EOF

echo "== serving smoke: bench_serving --smoke --overload (writes BENCH_serving.smoke.json) =="
python -m benchmarks.bench_serving --smoke --overload

echo "== gate: batched-serving stacking regressions =="
python - <<'EOF'
import json, sys

r = json.load(open("BENCH_serving.smoke.json"))
fail = []
# O(log N) compiled programs across the batch-size sweep: one per pow2
# bucket plus the N=1 unstacked drain (DESIGN.md §7)
if r["sweep_compiles"] > r["sweep_compile_budget"]:
    fail.append(
        f"compile sweep: {r['sweep_compiles']} compiles over "
        f"N=1..{r['sweep_max']} (budget {r['sweep_compile_budget']})"
    )
# serving steady state: a structurally repeated tick is pure replay —
# zero recompiles, one launch per signature bucket
if r["repeat_tick_compiles"] != 0:
    fail.append(f"repeat ticks recompiled ({r['repeat_tick_compiles']})")
if any(l != 1 for l in r["repeat_tick_launches"]):
    fail.append(f"repeat tick launches {r['repeat_tick_launches']} != 1 each")
# throughput: at N=16 the stacked drain must beat 16 sequential drains
# (interleaved same-box timing; the segment-fused comparison is reported
# but not gated — it legitimately wins at small N on CPU)
n16 = r["by_batch"]["16"]
if n16["seq_over_stacked"] < 1.0:
    fail.append(
        f"stacked N=16 slower than sequential: "
        f"{n16['seq_over_stacked']:.2f}x"
    )
# steady-state latency percentiles must be recorded (DESIGN.md §10)
lat = r.get("latency", {})
if not (lat.get("samples", 0) > 0 and lat.get("p99_ms", 0) >= lat.get("p50_ms", 0) > 0):
    fail.append(f"steady-state latency percentiles missing/malformed: {lat}")
# overload scenario: shedding + retry + poisoned-request isolation, with
# every healthy request resolved — and none of it may leak into the
# repeat-tick replay contract gated above
ov = r.get("overload")
if ov is None:
    fail.append("overload section missing (bench_serving --overload)")
else:
    if ov["shed"] == 0:
        fail.append("overload: nothing shed past max_pending")
    if ov["retried"] < 1 or ov["failed"] < 1:
        fail.append(
            f"overload: poisoned request not retried+failed "
            f"(retried={ov['retried']}, failed={ov['failed']})"
        )
    want = ov["submitted"] - ov["shed"] - ov["failed"]
    if ov["resolved"] != want:
        fail.append(
            f"overload: {ov['resolved']} resolved != {want} expected"
        )
    olat = ov["latency"]
    if not (olat["samples"] > 0 and olat["p99_ms"] >= olat["p50_ms"] > 0):
        fail.append(f"overload latency percentiles malformed: {olat}")
# async drain overlap (DESIGN.md §12): a repeat tick without check_finite
# never fences, so its accumulated host idle must be exactly zero...
if r["repeat_tick_host_idle_us"] != 0:
    fail.append(
        f"repeat ticks blocked the host under overlap "
        f"({r['repeat_tick_host_idle_us']}us idle)"
    )
# ...and the interleaved A/B must show overlap-on no slower than off
# (0.9 tolerates smoke-mode noise; the full run reports the real win)
ol = r.get("overlap")
if ol is None:
    fail.append("overlap A/B section missing")
elif ol["off_over_on"] < 0.9:
    fail.append(
        f"overlap-on slower than overlap-off beyond noise: "
        f"{ol['off_over_on']:.2f}x (floor 0.9)"
    )
# TaPS-style trend file: append-per-run, last line carries the tracked keys
import os
if not os.path.exists("BENCH_serving.trend.jsonl"):
    fail.append("BENCH_serving.trend.jsonl missing (append-per-run trend)")
else:
    lines = open("BENCH_serving.trend.jsonl").read().strip().splitlines()
    try:
        t = json.loads(lines[-1])
        for k in ("t", "bench", "mode", "backend", "tick_req_per_s",
                  "repeat_tick_compiles", "repeat_tick_host_idle_us",
                  "overlap_off_over_on", "n16_seq_over_stacked"):
            if k not in t:
                fail.append(f"trend line missing key: {k}")
    except ValueError:
        fail.append("trend file last line is not valid JSON")
if fail:
    print("SERVING GATE FAILED:\n  " + "\n  ".join(fail))
    sys.exit(1)
print(
    f"serving gate OK (sweep {r['sweep_compiles']}/"
    f"{r['sweep_compile_budget']} compiles, N=16 stacked "
    f"{n16['seq_over_stacked']:.2f}x over sequential, "
    f"{n16['seg_over_stacked']:.2f}x over segment-fused, overlap A/B "
    f"{ol['off_over_on']:.2f}x, overload "
    f"{ov['resolved']}/{ov['submitted']} resolved with {ov['shed']} shed)"
)
EOF

echo "== examples smoke (executable documentation) =="
python examples/quickstart.py 64 4 2
python examples/lu_solve.py 64 4 2

echo "== docs: README/DESIGN links + section references resolve =="
python - <<'EOF'
import os, re, sys

fail = []
# 1) relative markdown links in README/DESIGN point at real files
for path in ("README.md", "DESIGN.md"):
    text = open(path).read()
    for target in re.findall(r"\]\(([^)\s]+)\)", text):
        target = target.split("#")[0]
        if not target or "://" in target or target.startswith("mailto:"):
            continue
        if not os.path.exists(target):
            fail.append(f"{path}: broken link -> {target}")
# 2) every "DESIGN.md §N" citation (docs, source, tests, benchmarks)
#    resolves to a top-level DESIGN.md heading — this is what keeps the
#    load-bearing section numbering gap-free
secs = set(re.findall(r"^## (§\d+)", open("DESIGN.md").read(), flags=re.M))
cites = {}
scan = ["README.md", "DESIGN.md", "ROADMAP.md"]
for root in ("src", "tests", "benchmarks", "examples"):
    for dirpath, _, names in os.walk(root):
        scan += [os.path.join(dirpath, n) for n in names if n.endswith(".py")]
for path in scan:
    # compound citations ("DESIGN.md §4/§6") count every listed section
    for group in re.findall(r"DESIGN\.md ((?:§\d+[/,])*§\d+)", open(path).read()):
        for ref in re.findall(r"§\d+", group):
            cites.setdefault(ref, path)
for ref, path in sorted(cites.items()):
    if ref not in secs:
        fail.append(f"{path}: DESIGN.md {ref} cited but no such section")
if fail:
    print("DOCS LINK GATE FAILED:\n  " + "\n  ".join(fail))
    sys.exit(1)
print(f"docs link gate OK ({len(cites)} section citations, "
      f"{len(secs)} sections)")
EOF

if [[ "${1:-}" == "--full" ]]; then
  echo "== full bench_overhead (writes BENCH_overhead.json) =="
  python -m benchmarks.bench_overhead
  echo "== full bench_serving (writes BENCH_serving.json) =="
  python -m benchmarks.bench_serving
  echo "== full benchmark suite =="
  python -m benchmarks.run
fi
